"""CI benchmark smoke: reads/s + intermediate HBM bytes/read per backend.

``python -m benchmarks.run --smoke`` (or ``python -m benchmarks.smoke``)
profiles one tiny synthetic sample through each hot-path backend and
writes a machine-readable ``BENCH_smoke.json``:

    {"schema": 1, "jax": ..., "platform": ...,
     "config": {...}, "num_reads": ...,
     "bit_exact": true,
     "backends": {
        "pallas_fused": {"reads_per_s": ..., "us_per_read": ...,
                         "relative_throughput": ...,
                         "intermediate_bytes_per_read": 0,
                         "prototype_bytes_per_read": ...}, ...}}

``relative_throughput`` is each backend's reads/s divided by the same
run's *family anchor* (jnp backends vs ``reference``, Pallas backends vs
``pallas_matmul`` — see ``ANCHORS``).  The regression gate
(:mod:`benchmarks.check_regression`) compares THIS ratio against
``benchmarks/baseline.json``, so absolute runner speed cancels and a >20%
relative slowdown of any backend fails CI no matter the machine.  The
anchors themselves are gated by the ``bit_exact`` check plus their
family partners' ratios (an anchor can't silently regress without every
partner's ratio moving).

``intermediate_bytes_per_read`` is the analytical HBM traffic of the
query path's *intermediates* — everything between raw tokens in and
agreement scores out (see :func:`intermediate_bytes_per_read`).  It is
deterministic, so the gate allows no increase at all: the fused
megakernel's 0 bytes/read is pinned forever.  ``prototype_bytes_per_read``
does the same for the prototype stream (the query path's only remaining
HBM traffic — see :func:`prototype_bytes_per_read`): any analytic growth
of any backend's prototype traffic fails CI, pinning the fused kernel's
chunk-reuse amortization the way fusion pinned the intermediates.

The payload also carries ``observability.enabled_over_disabled``: the
``reference`` backend's throughput with the metrics layer fully enabled
over the same session with it disabled (interleaved best-of rounds).
The gate requires this ratio to stay within 2% of 1.0 — the
instrumentation's zero-cost-when-disabled contract, measured, with the
enabled mode held to the same bar.

``fleet`` routes the same sample through a 1-host and a 3-host
:class:`~repro.serve.fleet.FleetController` over one in-memory source
registry.  ``relative_aggregate`` is the 3-host aggregate reads/s over
the 1-host figure (runner speed cancels); the gate flags a drop beyond
``--fleet-tolerance`` (coordination overhead regression), and
``fleet.bit_exact`` — every fleet-routed report bit-identical to a
sequential run — failing is a hard error at any tolerance.

Refresh the baseline after an intentional perf change with:

    PYTHONPATH=src python -m benchmarks.run --smoke
    PYTHONPATH=src python -m benchmarks.check_regression --update
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import dataclasses

import jax

from benchmarks import common
from repro import obs
from repro.core import HDSpace
from repro.pipeline import ArraySource, ProfilerConfig, ProfilingSession
from repro.serve import FleetController, RefDBRegistry

SCHEMA = 1

#: The hot-path lineup the gate tracks (pcm_sim is covered by accel-smoke;
#: sharded by shard-smoke — both are wrappers around these primitives).
BACKENDS = ("reference", "reference_packed", "pallas_matmul",
            "pallas_packed", "pallas_fused")

#: Normalization anchor per backend (its own execution family's
#: two-kernel baseline); see the comment at the normalization site.
ANCHORS = {
    "reference": "reference",
    "reference_packed": "reference",
    "pallas_matmul": "pallas_matmul",
    "pallas_packed": "pallas_matmul",
    "pallas_fused": "pallas_matmul",
}

# Small enough that interpret-mode Pallas stays in CI seconds, big enough
# that per-read timing dominates dispatch overhead.
SMOKE_SPACE = HDSpace(dim=512, ngram=8, z_threshold=3.0)
SMOKE_CONFIG = ProfilerConfig(space=SMOKE_SPACE, window=1024,
                              batch_size=64, backend="reference")


def intermediate_bytes_per_read(backend: str, space: HDSpace) -> int:
    """Analytical HBM bytes of query-path *intermediates*, per read.

    Counts only traffic the kernel organization itself creates between
    "tokens in" and "scores out" (what fusion can eliminate) — not the
    token read or score write every backend shares, and not the
    prototype stream (modelled separately by
    :func:`prototype_bytes_per_read`, since PR 9 it differs per backend):

      two-kernel ±1 matmul   packed query write+read (4B/word each) plus
                             the ±1 bf16 expansion write+read (2B/bit);
      two-kernel packed      packed query write+read only;
      pallas_fused           0 — the encoded tile never leaves VMEM.
    """
    w_bytes = space.num_words * 4
    if backend in ("reference", "pallas_matmul"):
        return 2 * w_bytes + 2 * space.dim * 2
    if backend in ("reference_packed", "pallas_packed"):
        return 2 * w_bytes
    if backend == "pallas_fused":
        return 0
    raise ValueError(f"no traffic model for backend {backend!r}")


def prototype_bytes_per_read(backend: str, space: HDSpace,
                             num_prototypes: int, batch: int) -> float:
    """Analytical HBM bytes of the *prototype stream*, per read.

    How many bytes of reference-DB prototypes the kernel organization
    pulls from HBM to score one batch, divided by the batch size — the
    traffic Acc-Demeter eliminates by keeping the AM inside the
    memristor array (PAPER.md §5), and what the fused kernel's
    chunk-axis grid amortizes in software.  Uses the backends' real
    padded shapes (what the DMA engine actually moves, not the logical
    prototype count):

      reference            ±1 bf16 expansion streamed once per batch;
      pallas_matmul        ±1 bf16 tiles, rows padded to 128, re-fetched
                           per 128-row batch tile;
      reference_packed     packed uint32, once per batch (32x packing);
      pallas_packed        packed tiles, rows padded to 128, re-fetched
                           per 8-row batch tile;
      pallas_fused         packed ``(bs, W)`` slabs fetched once per
                           chunk and reused across every batch tile —
                           once per batch total (``fused_tile_plan``).
    """
    from repro.kernels.ops import fused_tile_plan
    w_bytes = space.num_words * 4
    pad128 = -(-num_prototypes // 128) * 128
    if backend == "reference":
        return num_prototypes * space.dim * 2 / batch
    if backend == "pallas_matmul":
        return pad128 * space.dim * 2 * (-(-batch // 128)) / batch
    if backend == "reference_packed":
        return num_prototypes * w_bytes / batch
    if backend == "pallas_packed":
        return pad128 * w_bytes * (-(-batch // 8)) / batch
    if backend == "pallas_fused":
        plan = fused_tile_plan(batch, num_prototypes, space.num_words)
        return plan["proto_bytes_per_call"] / batch
    raise ValueError(f"no prototype-stream model for backend {backend!r}")


def run_smoke(out_path: str | pathlib.Path = "BENCH_smoke.json",
              num_reads: int = 256, rounds: int = 5,
              emit=common.emit) -> dict:
    """Time every backend on one shared sample; write ``out_path``."""
    community = common.make_community(
        "SMOKE", num_species=4, genome_len=12_000,
        reads_per_sample=num_reads, seed=7)
    toks, lens, *_ = community.samples["kylo"]
    source = ArraySource(toks, lens)

    sessions: dict[str, ProfilingSession] = {}
    reports: dict[str, str] = {}
    db = None
    for name in BACKENDS:
        session = ProfilingSession(
            dataclasses.replace(SMOKE_CONFIG, backend=name))
        if db is None:
            db = session.build_refdb(community.genomes)
        session.refdb = db            # bit-exact twins: one shared build
        reports[name] = session.profile(source).to_json()  # warmup+check
        sessions[name] = session

    # Timing rounds are INTERLEAVED across backends (round-robin, best
    # pass per backend): the gate compares throughput *ratios*, and with
    # per-backend timing windows any machine-speed drift between windows
    # lands straight in the ratio.  Interleaving puts every backend in
    # every window, so drift cancels and best-of-R converges per backend.
    # Fast (jnp) backends additionally repeat within each round until
    # ~0.25s has elapsed: a lone ~ms pass is granularity-and-GC noise.
    best = {name: float("inf") for name in BACKENDS}
    for _ in range(rounds):
        for name, session in sessions.items():
            spent = 0.0
            while spent < 0.25:
                secs, _ = common.timeit(lambda: session.profile(source))
                best[name] = min(best[name], secs)
                spent += secs

    results: dict[str, dict] = {}
    num_protos = int(db.prototypes.shape[0])
    for name, secs in best.items():
        us = secs / num_reads * 1e6
        results[name] = {
            "reads_per_s": num_reads / secs,
            "us_per_read": us,
            "intermediate_bytes_per_read":
                intermediate_bytes_per_read(name, SMOKE_SPACE),
            "prototype_bytes_per_read":
                prototype_bytes_per_read(name, SMOKE_SPACE, num_protos,
                                         SMOKE_CONFIG.batch_size),
        }
        emit(f"smoke.{name}.us_per_read", us,
             f"{num_reads / secs:.1f}reads/s")

    # Normalize each backend inside its own execution family: jnp
    # backends against `reference`, Pallas (interpret-mode on CPU)
    # against `pallas_matmul`.  Cross-family ratios mix two runtimes
    # that respond differently to runner load (BLAS threading vs the
    # Pallas interpreter) and are too volatile to gate at 20%;
    # within-family ratios are what a kernel regression actually moves.
    for name, r in results.items():
        anchor = ANCHORS[name]
        r["anchor"] = anchor
        r["relative_throughput"] = (r["reads_per_s"]
                                    / results[anchor]["reads_per_s"])

    observability = observability_overhead(db, source, num_reads,
                                           rounds=rounds, emit=emit)
    fleet = fleet_smoke(community, emit=emit)

    bit_exact = all(r == reports["reference"] for r in reports.values())
    payload = {
        "schema": SCHEMA,
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "config": SMOKE_CONFIG.to_dict(),
        "num_reads": num_reads,
        "bit_exact": bit_exact,
        "observability": observability,
        "fleet": fleet,
        "backends": results,
    }
    out = pathlib.Path(out_path)
    out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    emit("smoke.bit_exact", 0.0, str(bit_exact))
    emit("smoke.json", 0.0, str(out))
    if not bit_exact:
        raise SystemExit(
            "smoke FAILED: backend reports are not bit-identical")
    return payload


def observability_overhead(db, source, num_reads: int, *, rounds: int = 5,
                           emit=common.emit) -> dict:
    """Measure the metrics layer's cost on the ``reference`` hot path.

    Two twin sessions over the same RefDB — one recording into a live
    :class:`~repro.obs.metrics.MetricsRegistry`, one with observability
    disabled — timed with the same interleaved best-of discipline as the
    backend lineup, so machine drift cancels out of the ratio.  The
    twins' reports are also compared: enabling metrics must not move a
    single bit of output.
    """
    off = ProfilingSession(SMOKE_CONFIG)
    on = ProfilingSession(SMOKE_CONFIG, metrics=obs.MetricsRegistry())
    off.refdb = on.refdb = db
    rep_off = off.profile(source).to_json()     # warmup + parity check
    rep_on = on.profile(source).to_json()
    # Strict call-by-call alternation, best-of, over independent blocks;
    # the reported ratio is the best block's.  A real >2% overhead is
    # systematic and shows in every block; a lucky low sample on one
    # side is random and doesn't repeat — so a 2% gate on the best
    # block is stable where a single-window measurement flakes.
    ratios = []
    best = {"disabled": float("inf"), "enabled": float("inf")}
    for _ in range(3):
        block = {"disabled": float("inf"), "enabled": float("inf")}
        for _ in range(rounds * 5):
            for mode, session in (("disabled", off), ("enabled", on)):
                secs, _ = common.timeit(lambda: session.profile(source))
                block[mode] = min(block[mode], secs)
        ratios.append(block["disabled"] / block["enabled"])
        for mode in best:
            best[mode] = min(best[mode], block[mode])
    ratio = max(ratios)
    emit("smoke.observability.enabled_over_disabled", 0.0, f"{ratio:.4f}")
    return {
        "reads_per_s_disabled": num_reads / best["disabled"],
        "reads_per_s_enabled": num_reads / best["enabled"],
        "enabled_over_disabled": ratio,
        "bit_exact": rep_on == rep_off,
    }


def fleet_smoke(community, *, num_requests: int = 8,
                emit=common.emit) -> dict:
    """Route the smoke sample through a 1-host and a 3-host fleet.

    One in-memory source registry, two tenants, ``num_requests`` request
    slices.  Reports the 3-host aggregate throughput relative to the
    1-host cell (coordination overhead, runner speed cancelled) and
    whether every fleet-routed report came back bit-identical to a
    sequential profile of the same slice — the determinism contract
    that makes replicated serving and failover safe.
    """
    toks, lens, *_ = community.samples["kylo"]
    sources = [ArraySource(toks[i::num_requests], lens[i::num_requests])
               for i in range(num_requests)]
    registry = RefDBRegistry(root=None)
    snap = registry.create("smoke", community.genomes, SMOKE_CONFIG)
    seq = ProfilingSession(SMOKE_CONFIG)
    seq.adopt_refdb(snap.db)
    expected = [seq.profile(s).to_json() for s in sources]

    out: dict = {"bit_exact": True}
    for hosts in (1, 3):
        fleet = FleetController(registry, hosts=hosts)
        for t in range(2):
            fleet.add_tenant(f"t{t}", "smoke", max_active=8,
                             max_queue=num_requests)
        with fleet:
            for replica in fleet.hosts():      # warmup: compile per host
                replica.router.submit(sources[0], tenant="t0").result(
                    timeout=600)
            t0 = time.perf_counter()
            handles = [fleet.submit(s, tenant=f"t{i % 2}")
                       for i, s in enumerate(sources)]
            fleet_reports = [h.result(timeout=600) for h in handles]
            wall = time.perf_counter() - t0
        fleet.close()
        out["bit_exact"] &= all(
            r.to_json() == e for r, e in zip(fleet_reports, expected))
        reads = sum(r.total_reads for r in fleet_reports)
        out[f"h{hosts}"] = {"reads_per_s": reads / max(wall, 1e-9)}
    out["relative_aggregate"] = (out["h3"]["reads_per_s"]
                                 / out["h1"]["reads_per_s"])
    emit("smoke.fleet.relative_aggregate", 0.0,
         f"{out['relative_aggregate']:.3f}")
    emit("smoke.fleet.bit_exact", 0.0, str(out["bit_exact"]))
    return out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_smoke.json",
                    help="where to write the benchmark JSON")
    ap.add_argument("--reads", type=int, default=256)
    ap.add_argument("--rounds", type=int, default=5,
                    help="interleaved timing rounds (best pass counts)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run_smoke(args.out, num_reads=args.reads, rounds=args.rounds)


if __name__ == "__main__":
    main()
