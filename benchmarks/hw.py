"""Hardware constants for the TPU v5e target (per assignment) and the
first-principles energy model (Table 3 analogue).

Energy constants are order-of-magnitude literature values (Horowitz-style
accounting; 7nm-class logic, HBM2e) — clearly a *model*, not a
measurement; DESIGN.md §2 explains why PCM analog energy does not
transfer.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    bf16_flops: float     # peak bf16 FLOP/s
    hbm_bw: float         # HBM bytes/s
    hbm_gb: float         # HBM capacity
    ici_bw: float         # per-link bytes/s
    vpu_ops: float        # elementwise vector ops/s (int/fp alike, est.)
    # energy model (per op / per byte)
    pj_per_mac_bf16: float = 0.25
    pj_per_vpu_op: float = 0.10
    pj_per_hbm_byte: float = 30.0
    pj_per_vmem_byte: float = 1.0
    pj_per_ici_byte: float = 10.0


V5E = Chip(
    bf16_flops=197e12,
    hbm_bw=819e9,
    hbm_gb=16.0,
    ici_bw=50e9,
    vpu_ops=2.0e12,
)

CHIPS_PER_POD = 256
PODS = 2
