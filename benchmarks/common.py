"""Shared benchmark substrate: the synthetic AFS-analogue community, the
profiler lineup, timing helpers, and CSV emission.

The paper evaluates on AFS20/AFS31 (20/31 animal genomes, 12 MB-14 GB) with
calibrator-sausage Illumina reads.  Offline we reproduce the *structure*:
two reference databases (AFS-S: 12 species, AFS-L: 20 species — sized for
CPU), two read samples ("kylo", "kal") with disjoint present-species sets,
strain divergence and sequencing error.  All headline comparisons
(accuracy, memory, build/query time) use the same community for every
profiler, so ratios are apples-to-apples even though absolute scale is
laptop-bound.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro import obs
from repro.baselines import ClarkLike, Kraken2Like, MetaCacheLike
from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import ProfilerConfig, ProfilingSession

# Demeter production HD space (paper: D=40,000; ours is 128-lane aligned).
PROD_SPACE = HDSpace(dim=40960, ngram=16, z_threshold=5.0)
# CPU-sized space used by the software benchmarks (keeps run.py < minutes).
BENCH_SPACE = HDSpace(dim=8192, ngram=16, z_threshold=5.0)

# The same two setups as full profiling configs (window/batch/backend named).
PROD_CONFIG = ProfilerConfig(space=PROD_SPACE, window=8192, batch_size=4096,
                             backend="pallas_matmul")
BENCH_CONFIG = ProfilerConfig(space=BENCH_SPACE, window=4096, batch_size=256,
                              backend="reference")


@dataclasses.dataclass(frozen=True)
class BenchCommunity:
    name: str
    genomes: dict
    samples: dict          # sample name -> (tokens, lengths, truth, true_ab)

    @property
    def genome_lengths(self) -> np.ndarray:
        return np.array([len(g) for g in self.genomes.values()])


def make_community(name: str, *, num_species: int, genome_len: int,
                   reads_per_sample: int, seed: int) -> BenchCommunity:
    spec = synth.CommunitySpec(num_species=num_species, genome_len=genome_len,
                               homology_fraction=0.06, strain_snp_rate=0.002,
                               read_error_rate=0.002, seed=seed)
    genomes = synth.make_reference_genomes(spec)
    rng = np.random.default_rng(seed + 100)
    samples = {}
    for sname, present in (("kylo", list(range(0, num_species, 2))),
                           ("kal", list(range(1, num_species, 2)))):
        ab = np.zeros(num_species)
        ab[present] = rng.dirichlet(np.ones(len(present))) + 0.05
        ab = ab / ab.sum()
        toks, lens, truth = synth.sample_reads(
            genomes, ab, reads_per_sample, spec, rng)
        samples[sname] = (toks, lens, truth, ab)
    return BenchCommunity(name=name, genomes=genomes, samples=samples)


def afs_small() -> BenchCommunity:
    """AFS20-analogue sized for CPU benchmarking."""
    return make_community("AFS-S", num_species=12, genome_len=50_000,
                          reads_per_sample=2_000, seed=21)


def afs_large() -> BenchCommunity:
    """AFS31-analogue (more species, longer genomes)."""
    return make_community("AFS-L", num_species=20, genome_len=80_000,
                          reads_per_sample=2_000, seed=31)


def make_profilers(backend: str | None = None) -> dict:
    """The paper's lineup: Demeter (a ProfilingSession) vs 4 SOTA baselines."""
    config = (BENCH_CONFIG if backend is None
              else dataclasses.replace(BENCH_CONFIG, backend=backend))
    return {
        "demeter": ProfilingSession(config),
        "kraken2": Kraken2Like(k=21),
        "kraken2+bracken": Kraken2Like(k=21),   # + bracken redistribution
        "metacache": MetaCacheLike(),
        "clark": ClarkLike(k=21),
    }


def latency_percentiles_ms(latencies_s: "list[float]") -> tuple[float, float]:
    """``(p50_ms, p99_ms)`` via the serving stack's shared histogram.

    Folds per-request latencies into an
    :class:`~repro.obs.metrics.HistogramState` over the same
    ``TIME_BUCKETS_S`` the live ``serve_*`` metrics use, so benchmark
    percentiles and production-snapshot percentiles come from one
    estimator (bucketed linear interpolation) instead of two competing
    definitions of "p99".
    """
    state = obs.HistogramState(obs.TIME_BUCKETS_S)
    for s in latencies_s:
        state.observe(s)
    return state.percentile(50) * 1e3, state.percentile(99) * 1e3


def timeit(fn: Callable, *, repeats: int = 1) -> tuple[float, object]:
    """(best seconds, last result)."""
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV contract for benchmarks.run: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)
