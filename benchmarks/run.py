"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks.common.emit).

  accuracy    Fig 2/3   precision/recall per profiler per sample
  query_perf  Fig 4/5   software query time + throughput
  memory      Fig 6     working-structure bytes + reduction ratios
  build_time  Fig 11    reference build time
  acc_perf    Fig 12/13 accelerated (TPU-model) query time/throughput
  energy      Table 3   energy breakdown + Mbp/J
  accel_sim   §5/Table 3 PCM-substrate noise sweep + analytical cost model
  serve_perf  §1 system   ProfilingService reads/s + p50/p99 request latency
  tenant_serve §1 system  registry+router fleet reads/s + delta hot-swap
                          publish/drain latency
  fleet_serve  §1 system  multi-host aggregate reads/s vs host count +
                          fleet-swap flip/retire + host-kill failover
  shard_scaling  §scale   sharded-AM reads/s + RefDB bytes/device vs shards
                          (grow the sweep with
                          XLA_FLAGS=--xla_force_host_platform_device_count=N)
  roofline    §Roofline three-term analysis from dry-run artifacts

``--smoke`` switches to the CI benchmark smoke instead: a tiny sample
through every hot-path backend, emitting machine-readable
``BENCH_smoke.json`` for the regression gate
(``python -m benchmarks.check_regression``); see benchmarks/smoke.py.
"""

from __future__ import annotations

import sys

from benchmarks import (accel_sim, accuracy, acc_perf, build_time, common,
                        energy, fleet_serve, memory, query_perf, roofline,
                        serve_perf, shard_scaling, tenant_serve)


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        from benchmarks import smoke
        smoke.main([a for a in sys.argv[1:] if a != "--smoke"])
        return
    only = sys.argv[1] if len(sys.argv) > 1 else None
    community = common.afs_small()
    print("name,us_per_call,derived")

    def want(name):
        return only is None or only == name

    if want("accuracy"):
        accuracy.run(community)
    sw = None
    if want("query_perf"):
        sw = query_perf.run(community)
    if want("memory"):
        memory.run(community)
    if want("build_time"):
        build_time.run(community)
    if want("acc_perf"):
        acc_perf.run(community, software_query=sw)
    if want("energy"):
        energy.run(community)
    if want("accel_sim"):
        accel_sim.run(community)
    if want("serve_perf"):
        serve_perf.run(community)
    if want("tenant_serve"):
        tenant_serve.run(community)
    if want("fleet_serve"):
        fleet_serve.run(community)
    if want("shard_scaling"):
        shard_scaling.run(community)
    if want("roofline"):
        roofline.run()


if __name__ == "__main__":
    main()
