"""Fig. 6 analogue: working data-structure size per profiler.

Paper claim: Demeter's HD-RefDB is ~33-36x smaller than Kraken2/MetaCache
structures on food-scale databases; the reduction is what makes the
in-memory accelerator feasible (the whole AM fits in PCM arrays / VMEM).
"""

from __future__ import annotations

from benchmarks import common


def run(community=None, emit=common.emit) -> dict:
    community = community or common.afs_small()
    sizes = {}
    for pname, prof in common.make_profilers().items():
        if pname == "kraken2+bracken":
            continue
        if pname == "demeter":
            db = prof.build_refdb(community.genomes)
            sizes[pname] = db.memory_bytes()
        else:
            prof.build(community.genomes)
            sizes[pname] = prof.memory_bytes()
        emit(f"memory.{pname}.bytes", 0.0, str(sizes[pname]))
    for base in ("kraken2", "metacache", "clark"):
        ratio = sizes[base] / sizes["demeter"]
        emit(f"memory.reduction_vs_{base}", 0.0, f"{ratio:.1f}x")
    return sizes


if __name__ == "__main__":
    run()
