"""Fig. 6 analogue: working data-structure size per profiler.

Paper claim: Demeter's HD-RefDB is ~33-36x smaller than Kraken2/MetaCache
structures on food-scale databases; the reduction is what makes the
in-memory accelerator feasible (the whole AM fits in PCM arrays / VMEM).

The sharded deployment extends the claim: splitting the prototype axis
over N devices leaves ``memory.demeter.bytes_per_device.sN`` resident
per device (padded shard of prototypes + species tags, replicated genome
lengths), so ``memory.reduction_vs_*`` is reported both for the total
structure and against the per-device footprint at each shard count —
the number that decides whether a database fits one accelerator's HBM.
"""

from __future__ import annotations

from benchmarks import common
from repro.pipeline import per_device_bytes

#: Shard counts to report per-device footprints for (analytical — the
#: layout math of repro.pipeline.sharded, no mesh needed).
SHARD_COUNTS = (1, 2, 4, 8)


def run(community=None, emit=common.emit) -> dict:
    community = community or common.afs_small()
    sizes = {}
    demeter_db = None
    for pname, prof in common.make_profilers().items():
        if pname == "kraken2+bracken":
            continue
        if pname == "demeter":
            demeter_db = prof.build_refdb(community.genomes)
            sizes[pname] = demeter_db.memory_bytes()
        else:
            prof.build(community.genomes)
            sizes[pname] = prof.memory_bytes()
        emit(f"memory.{pname}.bytes", 0.0, str(sizes[pname]))
    for n in SHARD_COUNTS:
        bpd = per_device_bytes(demeter_db, n)
        sizes[f"demeter/device@{n}"] = bpd
        emit(f"memory.demeter.bytes_per_device.s{n}", 0.0, str(bpd))
    for base in ("kraken2", "metacache", "clark"):
        ratio = sizes[base] / sizes["demeter"]
        emit(f"memory.reduction_vs_{base}", 0.0, f"{ratio:.1f}x")
        # the per-device extension of the paper's Fig. 6 ratio: how much
        # smaller one *shard* is than the (unsharded) baseline structure
        for n in SHARD_COUNTS[1:]:
            r = sizes[base] / sizes[f"demeter/device@{n}"]
            emit(f"memory.reduction_vs_{base}.per_device.s{n}", 0.0,
                 f"{r:.1f}x")
    return sizes


if __name__ == "__main__":
    run()
