"""Fig. 6 analogue: working data-structure size per profiler.

Paper claim: Demeter's HD-RefDB is ~33-36x smaller than Kraken2/MetaCache
structures on food-scale databases; the reduction is what makes the
in-memory accelerator feasible (the whole AM fits in PCM arrays / VMEM).

The sharded deployment extends the claim: splitting the prototype axis
over N devices leaves ``memory.demeter.bytes_per_device.sN`` resident
per device (padded shard of prototypes + species tags, replicated genome
lengths), so ``memory.reduction_vs_*`` is reported both for the total
structure and against the per-device footprint at each shard count —
the number that decides whether a database fits one accelerator's HBM.

``memory.proto_stream.*`` reports the same structure as *traffic*: the
prototype-stream HBM bytes each kernel organization moves per profiled
read (the AM bytes Acc-Demeter never moves at all, PAPER.md §5) — the
±1 bf16 matmul operand, the bit-packed fused tile re-fetched per batch
tile (pre-PR-9), and the chunk-amortized fused slab — so the packing
and batch-amortization factors are visible side by side.
"""

from __future__ import annotations

from benchmarks import common
from repro.pipeline import per_device_bytes

#: Shard counts to report per-device footprints for (analytical — the
#: layout math of repro.pipeline.sharded, no mesh needed).
SHARD_COUNTS = (1, 2, 4, 8)


def run(community=None, emit=common.emit) -> dict:
    community = community or common.afs_small()
    sizes = {}
    demeter_db = None
    for pname, prof in common.make_profilers().items():
        if pname == "kraken2+bracken":
            continue
        if pname == "demeter":
            demeter_db = prof.build_refdb(community.genomes)
            sizes[pname] = demeter_db.memory_bytes()
        else:
            prof.build(community.genomes)
            sizes[pname] = prof.memory_bytes()
        emit(f"memory.{pname}.bytes", 0.0, str(sizes[pname]))
    for n in SHARD_COUNTS:
        bpd = per_device_bytes(demeter_db, n)
        sizes[f"demeter/device@{n}"] = bpd
        emit(f"memory.demeter.bytes_per_device.s{n}", 0.0, str(bpd))
    sizes["proto_stream"] = prototype_stream(demeter_db, emit=emit)
    for base in ("kraken2", "metacache", "clark"):
        ratio = sizes[base] / sizes["demeter"]
        emit(f"memory.reduction_vs_{base}", 0.0, f"{ratio:.1f}x")
        # the per-device extension of the paper's Fig. 6 ratio: how much
        # smaller one *shard* is than the (unsharded) baseline structure
        for n in SHARD_COUNTS[1:]:
            r = sizes[base] / sizes[f"demeter/device@{n}"]
            emit(f"memory.reduction_vs_{base}.per_device.s{n}", 0.0,
                 f"{r:.1f}x")
    return sizes


def prototype_stream(db, *, batch: int = 64, bb: int = 8,
                     emit=common.emit) -> dict:
    """Prototype-stream HBM bytes per read, per kernel organization.

    Three rows for the same database and batch:

      matmul_pm1_bf16         the AM streamed as its ±1 bf16 expansion
                              (2 bytes per HD bit), once per batch;
      fused_packed_per_tile   bit-packed uint32 tiles, but re-fetched
                              for every ``bb``-row batch tile — the
                              fused kernel's dataflow before the
                              chunk-axis grid (bytes ~ S*W*4/bb);
      fused_packed_amortized  the chunk-axis megakernel: each packed
                              ``(bs, W)`` slab fetched once per batch
                              (``fused_tile_plan`` padded shapes).

    The ratio of row 1 to row 2 is the packing factor; row 2 to row 3
    the batch-tile amortization factor.
    """
    from repro.kernels.ops import fused_tile_plan
    s, w = (int(x) for x in db.prototypes.shape)
    dim = w * 32
    plan = fused_tile_plan(batch, s, w, bb=bb)
    rows = {
        "matmul_pm1_bf16": s * dim * 2 / batch,
        "fused_packed_per_tile":
            plan["s_pad"] * plan["w_pad"] * 4 / plan["bb"],
        "fused_packed_amortized": plan["proto_bytes_per_call"] / batch,
    }
    for name, val in rows.items():
        emit(f"memory.proto_stream.{name}.bytes_per_read", 0.0,
             f"{val:.1f}")
    # The two factors, each isolated at a fixed cadence: bytes per
    # prototype row (±1 bf16 vs bit-packed), and slab fetches per batch
    # (once per bb-row tile vs once per batch).
    emit("memory.proto_stream.packing_factor", 0.0,
         f"{dim * 2 / (w * 4):.1f}x")
    emit("memory.proto_stream.amortization_factor", 0.0,
         f"{rows['fused_packed_per_tile'] / rows['fused_packed_amortized']:.1f}x")
    return rows


if __name__ == "__main__":
    run()
