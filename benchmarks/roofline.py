"""§Roofline: three-term analysis per (arch x shape) from dry-run artifacts.

    compute term    = HLO_FLOPs_per_dev / peak_bf16
    memory term     = HLO_bytes_per_dev / HBM_bw      (upper bound: XLA's
                      'bytes accessed' counts fusion-internal traffic)
    collective term = link_bytes_per_dev / ICI_link_bw

FLOPs/bytes come from the cost-exact depth extrapolation (while-loop
bodies are otherwise counted once — see launch/dryrun.py); collective
bytes from the partitioned HLO's collective ops with ring-algorithm
multipliers.  Also reports MODEL_FLOPS (6ND train / 2ND inference) over
HLO FLOPs — the "useful compute" ratio that catches remat/dispatch waste.

Besides the 10 LM archs this module computes the same three terms
*analytically* for the paper's own workload (demeter_hdc query step),
whose encoder math is closed-form (launch/dryrun_hdc.py proves its
sharding compiles).
"""

from __future__ import annotations

import json
import pathlib

from benchmarks import common
from benchmarks.hw import V5E

ARTIFACTS = pathlib.Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

# mesh factors of the production meshes
TP = 16          # 'model' axis
DP = 16          # 'data' axis


def model_flops(arch: str, shape_name: str, *, q_chunk: int = 2048) -> float:
    """MODEL_FLOPS: 6ND (train) / 2ND (inference) per-token matmul FLOPs
    PLUS the attention rectangle we actually compute (full masked S x Skv —
    see models/attention.py docstring) — without the attention term, long-
    context decode 'useful compute' ratios are meaningless."""
    from repro.configs import get_config
    from repro.configs import shapes as shapes_mod
    cfg = get_config(arch)
    shape = shapes_mod.SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    a = cfg.attn

    def attn_flops(tokens, skv):
        if a is None:
            return 0.0
        qk = (a.head_dim + a.rope_head_dim) if a.kind == "mla" else a.head_dim
        per_layer = 2.0 * tokens * skv * a.num_heads * (qk + a.vdim)
        n_att = cfg.n_layers + (cfg.n_enc_layers * 2 if cfg.is_encdec else 0)
        return per_layer * n_att

    nd = cfg.active_param_count()
    if shape.kind == "train":
        toks = b * (cfg.dec_len_train if cfg.family == "audio" else s)
        att_s = s if cfg.family != "audio" else s  # enc length dominates
        return 6.0 * nd * toks + 3.0 * attn_flops(b * att_s, att_s)
    if shape.kind == "prefill":
        toks = b * (cfg.dec_len_train if cfg.family == "audio" else s)
        return 2.0 * nd * toks + attn_flops(b * s, s)
    # decode: one token against an S-long cache
    return 2.0 * nd * b + attn_flops(b, s)


def analytic_hbm_bytes(arch: str, shape_name: str) -> float:
    """Per-device HBM traffic model (the memory-term numerator).

    XLA's 'bytes accessed' counts every op's operands (fusion-internal
    traffic included) and overcounts HBM by ~10x; this closed-form model
    counts only resident-state traffic: parameters (+optimizer), residual
    activations, attention KV re-reads per q-chunk pass (flash tiling),
    expert weights, decode caches, and loss logits.
    """
    from repro.configs import get_config
    from repro.configs import shapes as shapes_mod
    cfg = get_config(arch)
    shape = shapes_mod.SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    a = cfg.attn
    p_total = cfg.active_param_count()          # active weights touched
    if cfg.moe is not None:                     # all local experts stream in
        m = cfg.moe
        mult = 3 if cfg.glu else 2
        all_e = cfg.n_layers * m.num_experts * mult * cfg.d_model * m.d_expert
        act_e = cfg.n_layers * (m.top_k + m.num_shared) * mult * \
            cfg.d_model * m.d_expert
        p_total = p_total - act_e + all_e / TP * min(TP, m.num_experts)

    if shape.kind == "train":
        b_dev = max(b // DP, 1)
        s_eff = cfg.dec_len_train if cfg.family == "audio" else s
        # params: bf16 read fwd+bwd, grads fp32 w, m/v fp32 rw, p write
        p_bytes = p_total * (2 + 2 + 4 + 16 + 2) / (DP * TP)
        # residual stream per layer: fwd write + bwd read + remat reread
        act = cfg.n_layers * b_dev * s_eff * cfg.d_model * 2 * 4 / TP
        kv_pass = 0.0
        if a is not None:
            nq = max(s_eff // 512, 1)           # q_chunk=512 at train
            kv_w = a.kv_lora + a.rope_head_dim if a.kind == "mla" else \
                2 * a.num_kv_heads * a.head_dim
            kv_pass = 3 * cfg.n_layers * nq * b_dev * s_eff * kv_w * 2
        logits = b_dev * s_eff * cfg.vocab * 4 / TP * 2   # fwd+bwd chunks
        return p_bytes + act + kv_pass + logits
    if shape.kind == "prefill":
        b_dev = max(b // DP, 1)
        p_bytes = p_total * 2 / (DP * TP)
        act = cfg.n_layers * b_dev * s * cfg.d_model * 2 * 2 / TP
        kv_pass = 0.0
        if a is not None:
            nq = max(s // 512, 1)
            kv_w = a.kv_lora + a.rope_head_dim if a.kind == "mla" else \
                2 * a.num_kv_heads * a.head_dim
            kv_pass = cfg.n_layers * nq * b_dev * s * kv_w * 2
        return p_bytes + act + kv_pass
    # decode: weights + full local cache shard read once per token
    b_dev = max(b // DP, 1)
    p_bytes = p_total * 2 / TP                  # TP-only weight shards
    if a is None:
        cache_w = 0.0
    elif a.kind == "mla":
        cache_w = a.kv_lora + a.rope_head_dim
    else:
        cache_w = 2 * a.num_kv_heads * a.head_dim
    s_local = s // TP                           # kv_seq sharded over model
    cache = cfg.n_layers * b_dev * s_local * cache_w * 2
    if cfg.ssm is not None:
        dd = cfg.ssm.expand * cfg.d_model // cfg.ssm.head_dim
        cache += cfg.n_layers * b_dev * dd * cfg.ssm.head_dim * \
            cfg.ssm.d_state * 4
    return p_bytes + cache


def cell_terms(d: dict) -> dict | None:
    """Three roofline terms (seconds) for one artifact record."""
    if d.get("skip_reason") or not d.get("ok"):
        return None
    r = d.get("extra", {}).get("roofline")
    if not r:
        return None
    compute_t = r["flops"] / V5E.bf16_flops
    hbm = analytic_hbm_bytes(d["arch"], d["shape"])
    memory_t = hbm / V5E.hbm_bw
    coll_t = r["link_bytes"] / V5E.ici_bw
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    chips = 512 if d["mesh"] == "2x16x16" else 256
    model = model_flops(d["arch"], d["shape"])
    useful = model / (r["flops"] * chips) if r["flops"] else 0.0
    # roofline fraction: ideal compute time / dominant-term time
    frac = compute_t / bound if bound else 0.0
    return dict(terms, dominant=dominant.replace("_s", ""),
                roofline_fraction=frac, useful_flops_ratio=useful,
                flops_per_dev=r["flops"], link_gb=r["link_bytes"] / 1e9,
                hlo_bytes_per_dev=r["bytes"], analytic_hbm=hbm,
                temp_gb=d["memory"].get("temp_size_in_bytes", 0) / 1e9,
                arg_gb=d["memory"].get("argument_size_in_bytes", 0) / 1e9)


IMPROVE = {
    "compute": "compute-bound: raise MXU utilization (tile sizes, fusion) "
               "or cut redundant FLOPs (remat policy, causal early-exit)",
    "memory": "HBM-bound: fuse producers into consumers, shrink dtypes "
              "(bf16/int8 caches), re-tile for VMEM reuse",
    "collective": "ICI-bound: reshard to cut all-to-alls (SP boundaries), "
                  "overlap collectives with compute, compress payloads",
}


def demeter_hdc_terms(batch: int = 65536, read_len: int = 150,
                      num_protos: int = 2048, chips: int = 256,
                      variant: str = "d_contract") -> dict:
    """Analytic roofline for the paper's query step.

    d_contract (paper-faithful layout: reads over 'data', D-words over
    'model' — mirrors Acc-Demeter's word-slicing across PCM arrays):
    encoding is 256-way split over (reads x D); agreement contracts D
    -> one psum of (B_dev, S) partials over 'model'.

    read_parallel (beyond-paper, §Perf H-paper iteration 2, verified
    zero-collective by launch/dryrun_hdc.py `query_a2a`): reads sharded
    over ALL 256 chips end-to-end, D unsharded, prototypes replicated
    (10 MB) — no contraction collective exists at all.

    fused (kernels/fused_profile.py, the Acc-Demeter dataflow): the
    read_parallel layout run through the encode->search megakernel — the
    encoded queries never round-trip through HBM, so the ``d_dev/8 * 2``
    intermediate term of the memory numerator vanishes and the per-read
    HBM traffic drops to tokens-in + the prototype stream.

    The prototype-stream term is variant-specific (the second traffic
    class fusion + chunk reuse attacks, PR 9): the matmul variants
    stream the AM as its ±1 bf16 expansion (2 bytes/bit), while the
    fused megakernel streams bit-packed words (1/16 the bytes) and its
    chunk-axis grid fetches each ``(bs, W)`` slab once per *batch* —
    not once per batch tile — so the term no longer scales with
    ``b_dev / bb``.
    """
    sp = common.PROD_SPACE
    g = read_len - sp.ngram + 1
    if variant == "d_contract":
        b_dev = batch / (chips / 16)       # reads over data axis=16
        d_dev = sp.dim / 16                # D over model axis=16
        # one psum of partial agreements (B_dev x S int32) over model=16
        link = 2 * b_dev * num_protos * 4 * (15 / 16)
    else:                                  # read_parallel / fused
        b_dev = batch / chips
        d_dev = sp.dim
        link = 0.0
    enc_ops = b_dev * g * d_dev * 1.25
    mm_flops = 2.0 * b_dev * num_protos * d_dev
    compute_t = enc_ops / V5E.vpu_ops + mm_flops / V5E.bf16_flops
    q_intermediate = 0.0 if variant == "fused" else b_dev * d_dev / 8 * 2
    if variant == "fused":
        proto_bytes = num_protos * d_dev / 8       # packed, once per batch
    else:
        proto_bytes = num_protos * d_dev * 2       # ±1 bf16 MXU operand
    hbm = b_dev * read_len + q_intermediate + proto_bytes
    memory_t = hbm / V5E.hbm_bw
    coll_t = link / V5E.ici_bw
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    return dict(terms, dominant=dominant.replace("_s", ""),
                roofline_fraction=compute_t / max(terms.values()),
                proto_bytes_per_read=proto_bytes / b_dev,
                reads_per_s_per_chip=batch / chips / max(terms.values()))


def markdown_table() -> str:
    rows = ["| arch | shape | mesh | compute s | memory s | collective s | "
            "dominant | roofline frac | useful FLOPs |",
            "|---|---|---|---|---|---|---|---|---|"]
    for f in sorted(ARTIFACTS.glob("*.json")):
        d = json.loads(f.read_text())
        if "arch" not in d:        # dryrun_hdc variant records
            continue
        if d.get("skip_reason"):
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — "
                        f"| — | skipped | — | — |")
            continue
        t = cell_terms(d)
        if t is None:
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                        f"FAILED | | | | | |")
            continue
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {t['dominant']} "
            f"| {t['roofline_fraction']:.2f} | {t['useful_flops_ratio']:.2f} |")
    for variant in ("d_contract", "read_parallel", "fused"):
        h = demeter_hdc_terms(variant=variant)
        rows.append(
            f"| demeter_hdc ({variant}) | query_64k | 16x16 "
            f"| {h['compute_s']:.3e} | {h['memory_s']:.3e} "
            f"| {h['collective_s']:.3e} | {h['dominant']} "
            f"| {h['roofline_fraction']:.2f} | 1.00 |")
    return "\n".join(rows)


def run(emit=common.emit) -> None:
    n = ok = 0
    for f in sorted(ARTIFACTS.glob("*.json")):
        d = json.loads(f.read_text())
        if "arch" not in d or d.get("skip_reason"):
            continue
        n += 1
        t = cell_terms(d)
        if t is None:
            continue
        ok += 1
        emit(f"roofline.{d['arch']}.{d['shape']}.{d['mesh']}", 0.0,
             f"dom={t['dominant']};frac={t['roofline_fraction']:.2f};"
             f"useful={t['useful_flops_ratio']:.2f}")
    for variant in ("d_contract", "read_parallel", "fused"):
        h = demeter_hdc_terms(variant=variant)
        emit(f"roofline.demeter_hdc.query_64k.{variant}", 0.0,
             f"dom={h['dominant']};frac={h['roofline_fraction']:.2f};"
             f"mem_us={h['memory_s'] * 1e6:.1f};"
             f"proto_B/read={h['proto_bytes_per_read']:.1f};"
             f"reads/s/chip={h['reads_per_s_per_chip']:.0f}")
    emit("roofline.cells_analyzed", 0.0, f"{ok}/{n}")


if __name__ == "__main__":
    print(markdown_table())
