"""Shard-scaling benchmark: throughput and per-device RefDB footprint vs
prototype-shard count.

The paper's capacity argument made measurable: Demeter's AM search scales
by partitioning the prototype axis across devices (crossbar arrays in
Acc-Demeter, mesh devices here).  For each shard count ``n`` that fits
the local device set this sweeps the ``sharded`` backend over the same
community/sample and emits

  shard_scaling.{base}.s{n}.reads_per_s    sustained classified reads/s
  shard_scaling.{base}.s{n}.bytes_per_device
                                           RefDB bytes resident per device
                                           (padded prototype rows + tags
                                           + replicated genome lengths)
  shard_scaling.{base}.s{n}.speedup        vs the same base unsharded

plus one ``shard_scaling.check.s{n} ok`` row per shard count asserting
the report is bit-identical to the unsharded reference — a benchmark
that silently diverged would be measuring a different computation.

On a single-CPU host every sweep point is n=1; grow the mesh with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.shard_scaling --smoke

``--smoke`` shrinks the community and read count so CI exercises the
full pad/place/shard_map/merge cycle in seconds.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from benchmarks import common
from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession,
                            per_device_bytes)

SMOKE_SPACE = HDSpace(dim=512, ngram=8, z_threshold=3.0)


def shard_counts(max_devices: int | None = None) -> list[int]:
    """1, 2, 4, ... up to the local device count (always includes the max)."""
    n = len(jax.devices()) if max_devices is None else max_devices
    counts = [c for c in (1, 2, 4, 8, 16, 32) if c <= n]
    if n not in counts:
        counts.append(n)
    return counts


def _profile_once(config: ProfilerConfig, genomes, source):
    session = ProfilingSession(config)
    session.build_refdb(genomes)
    session.profile(source)                       # warmup: compile + place
    t0 = time.perf_counter()
    rep = session.profile(source)
    wall = time.perf_counter() - t0
    return session, rep, rep.total_reads / max(wall, 1e-9)


def run(community=None, emit=common.emit, *, smoke: bool = False,
        base: str = "reference") -> dict:
    if smoke:
        spec = synth.CommunitySpec(num_species=6, genome_len=12_000, seed=17)
        genomes, toks, lens, _, _ = synth.make_sample(spec, num_reads=512)
        config = ProfilerConfig(space=SMOKE_SPACE, window=1024,
                                batch_size=64, backend=base)
    else:
        community = community or common.afs_small()
        genomes = community.genomes
        toks, lens, _, _ = community.samples["kylo"]
        config = common.BENCH_CONFIG
        base = config.backend
    source = ArraySource(toks, lens)

    _, ref_rep, ref_rps = _profile_once(config, genomes, source)
    out = {}
    for n in shard_counts():
        # replace(), not field-by-field: stride and any base backend
        # options must carry over or the bit-exactness check below would
        # compare runs of two different configs.
        cfg = dataclasses.replace(
            config, backend="sharded",
            backend_options={**dict(config.backend_options),
                             "base": base, "shards": n})
        session, rep, rps = _profile_once(cfg, genomes, source)
        assert rep.to_json() == ref_rep.to_json(), \
            f"sharded x{n} diverged from unsharded {base}"
        bpd = per_device_bytes(session.refdb, n)
        emit(f"shard_scaling.{base}.s{n}.reads_per_s", 0.0, f"{rps:.0f}")
        emit(f"shard_scaling.{base}.s{n}.bytes_per_device", 0.0, str(bpd))
        emit(f"shard_scaling.{base}.s{n}.speedup", 0.0,
             f"{rps / max(ref_rps, 1e-9):.2f}x")
        emit(f"shard_scaling.check.s{n}", 0.0, "ok")
        out[n] = {"reads_per_s": rps, "bytes_per_device": bpd}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small community, few reads)")
    ap.add_argument("--base", default="reference",
                    help="base backend to shard (smoke mode only)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    print(f"# devices: {len(jax.devices())}", flush=True)
    run(smoke=args.smoke, base=args.base)


if __name__ == "__main__":
    main()
