"""Fleet serving benchmark: aggregate throughput vs host count, plus the
cost of a fleet-wide two-phase swap and a mid-run host kill.

The aggregate-scale reading of the paper's line-rate claim: many
replicated hosts behind one controller.  Measures, per host-count cell
over one source database:

  fleet.{backend}.h{H}.reads_per_s   aggregate sustained reads/s
  fleet.{backend}.h{H}.p50_ms        median request latency
  fleet.{backend}.h{H}.p99_ms        tail request latency

for the fleet-coordinated swap under traffic:

  fleet.swap.flip_ms     prepare (all hosts pin) -> all routers flipped
  fleet.swap.retire_ms   flip -> every host drained the old version
                         (all source pins released; gc-eligible)

and for the failover path (one host killed mid-run):

  fleet.kill.rerouted    requests re-submitted on surviving replicas
  fleet.kill.wall_ms     total wall to drain everything anyway

``--smoke`` shrinks the community and the sweep so CI runs the full
replicate/route/kill/swap/retire cycle in seconds.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks import common
from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import ArraySource, ProfilerConfig
from repro.serve import FleetController, RefDBRegistry
from repro.serve.fleet import HostState

SMOKE_SPACE = HDSpace(dim=512, ngram=8, z_threshold=3.0)


def _fleet(registry: RefDBRegistry, *, hosts: int, tenants: int,
           queue: int) -> FleetController:
    fleet = FleetController(registry, hosts=hosts)
    for i in range(tenants):
        fleet.add_tenant(f"t{i}", "bench", max_active=8, max_queue=queue)
    return fleet


def _host_cell(registry: RefDBRegistry, sources, *, hosts: int,
               tenants: int) -> dict:
    """One host-count measurement: route all requests, collect."""
    fleet = _fleet(registry, hosts=hosts, tenants=tenants,
                   queue=len(sources))
    # warmup: compile the cohort shapes once per host
    with fleet:
        for replica in fleet.hosts():
            replica.router.submit(sources[0], tenant="t0").result(
                timeout=600)
        handles = []
        t0 = time.perf_counter()
        for i, src in enumerate(sources):
            handles.append(fleet.submit(src, tenant=f"t{i % tenants}"))
        reports = [h.result(timeout=600) for h in handles]
        wall = time.perf_counter() - t0
    fleet.close()
    p50, p99 = common.latency_percentiles_ms(
        [h._attempts[-1][1].latency_s for h in handles])
    reads = sum(r.total_reads for r in reports)
    return {"reads_per_s": reads / max(wall, 1e-9),
            "p50_ms": p50, "p99_ms": p99}


def _swap_cell(registry: RefDBRegistry, sources, delta_genomes,
               *, hosts: int) -> dict:
    """Fleet-wide two-phase swap under traffic; time flip + retire."""
    fleet = _fleet(registry, hosts=hosts, tenants=1, queue=len(sources))
    old = registry.current("bench").version
    with fleet:
        handles = [fleet.submit(s, tenant="t0") for s in sources]
        snap = registry.apply_delta("bench", add=delta_genomes)
        t0 = time.perf_counter()
        fleet.fleet_swap("bench", version=snap.version)
        flip_s = time.perf_counter() - t0     # all hosts now admit new
        fleet.wait_retired("bench", old, timeout=600)
        retire_s = time.perf_counter() - t0 - flip_s
        for h in handles:
            h.result(timeout=600)
        assert old not in registry.pins("bench")
    fleet.close()
    return {"flip_ms": flip_s * 1e3, "retire_ms": max(retire_s, 0) * 1e3}


def _kill_cell(registry: RefDBRegistry, sources, *, hosts: int) -> dict:
    """Kill the busiest host mid-run; everything must still complete."""
    fleet = _fleet(registry, hosts=hosts, tenants=1, queue=len(sources))
    with fleet:
        t0 = time.perf_counter()
        handles = [fleet.submit(s, tenant="t0") for s in sources]
        live: dict[str, int] = {}
        for h in handles:
            if not h.done:
                live[h.host] = live.get(h.host, 0) + 1
        victim = max(live or {fleet.healthy_hosts()[0]: 0}, key=live.get) \
            if live else fleet.healthy_hosts()[0]
        rerouted = fleet.kill_host(victim)
        for h in handles:
            h.result(timeout=600)
        wall = time.perf_counter() - t0
    assert fleet.host(victim).state is HostState.DOWN
    fleet.close()
    return {"rerouted": len(rerouted), "wall_ms": wall * 1e3}


def run(community=None, emit=common.emit, *, smoke: bool = False) -> dict:
    if smoke:
        spec = synth.CommunitySpec(num_species=4, genome_len=8_000, seed=13)
        genomes = synth.make_reference_genomes(spec)
        ab = np.full(4, 0.25)
        toks, lens, _ = synth.sample_reads(genomes, ab, 256, spec)
        config = ProfilerConfig(space=SMOKE_SPACE, window=1024,
                                batch_size=32)
        host_cells = [1, 3]
        num_requests = 8
        tenants = 2
    else:
        community = community or common.afs_small()
        genomes = community.genomes
        toks, lens, *_ = community.samples["kylo"]
        config = common.BENCH_CONFIG
        host_cells = [1, 2, 3]
        num_requests = 16
        tenants = 2

    registry = RefDBRegistry(root=None)
    registry.create("bench", genomes, config)
    sources = [ArraySource(toks[i::num_requests], lens[i::num_requests])
               for i in range(num_requests)]
    rng = np.random.default_rng(14)
    glen = len(next(iter(genomes.values())))
    delta = {"sp_delta": rng.integers(0, 4, glen, dtype=np.int32)}

    out: dict = {}
    for hosts in host_cells:
        cell = _host_cell(registry, sources, hosts=hosts, tenants=tenants)
        out[hosts] = cell
        tag = f"fleet.{config.backend}.h{hosts}"
        emit(f"{tag}.reads_per_s", cell["reads_per_s"],
             f"{num_requests}req/{tenants}tenant")
        emit(f"{tag}.p50_ms", cell["p50_ms"], f"p99={cell['p99_ms']:.1f}ms")

    kill = _kill_cell(registry, sources, hosts=max(host_cells))
    out["kill"] = kill
    emit("fleet.kill.rerouted", kill["rerouted"],
         "requests failed over to surviving hosts")
    emit("fleet.kill.wall_ms", kill["wall_ms"],
         "all requests still completed")

    swap = _swap_cell(registry, sources, delta, hosts=max(host_cells))
    out["swap"] = swap
    emit("fleet.swap.flip_ms", swap["flip_ms"],
         "prepare (all pinned) -> all routers flipped")
    emit("fleet.swap.retire_ms", swap["retire_ms"],
         "old version drained fleet-wide (gc-eligible)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny community + reduced sweep (CI-sized)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
