"""Fig. 11 analogue: reference-database build time per profiler.

Demeter builds through a ProfilingSession (``benchmarks.common``'s
BENCH_CONFIG), so the timed path is the same backend-routed encode the
query benchmarks use.
"""

from __future__ import annotations

from benchmarks import common


def run(community=None, emit=common.emit) -> dict:
    community = community or common.afs_small()
    out = {}
    for pname, prof in common.make_profilers().items():
        if pname == "kraken2+bracken":
            continue
        if pname == "demeter":
            secs, _ = common.timeit(
                lambda: prof.build_refdb(community.genomes))
        else:
            secs, _ = common.timeit(lambda: prof.build(community.genomes))
        out[pname] = secs
        emit(f"build.{pname}.seconds", secs * 1e6, f"{secs:.3f}s")
    return out


if __name__ == "__main__":
    run()
