"""Acc-Demeter device-model benchmark: accuracy-vs-noise + Table 3 costs.

Two artifacts, both through the simulated PCM substrate in ``repro.accel``:

1. **Noise sweep** (Karunaratne-style robustness curve): the AFS-analogue
   sample profiled through the ``pcm_sim`` backend while stepping read
   noise (and, in full mode, programming noise), emitting
   precision/recall/L1/unmapped at every level.  Level 0 doubles as the
   zero-noise bit-exactness check: its metrics equal the digital
   reference's by construction.
2. **Cost model** (Table 3 analogue): the analytical 65nm/PCM
   latency/energy/area breakdown of the same AM at the production HD
   dimension, including the paper's headline Mbp/J metric.

``--smoke`` shrinks the community and sweep so CI can run this end to
end in seconds.
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from benchmarks import common
from repro.accel import CrossbarConfig, accel_cost, noise_sweep
from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import ProfilerConfig, ProfilingSession

READ_LEN = 150

SMOKE_SPACE = HDSpace(dim=512, ngram=8, z_threshold=3.0)
SMOKE_CONFIG = ProfilerConfig(space=SMOKE_SPACE, window=1024, batch_size=64,
                              backend="pcm_sim")


def _smoke_workload():
    """Tiny synthetic community: seconds on CPU, exercises every path."""
    spec = synth.CommunitySpec(num_species=4, genome_len=8_000, seed=13)
    genomes = synth.make_reference_genomes(spec)
    ab = np.array([0.5, 0.0, 0.5, 0.0])
    toks, lens, _ = synth.sample_reads(genomes, ab, 200, spec)
    return genomes, toks, lens, ab


def run(community=None, emit=common.emit, *, smoke: bool = False) -> dict:
    if smoke:
        genomes, toks, lens, true_ab = _smoke_workload()
        config = SMOKE_CONFIG
        sweeps = {"read_sigma": (0.0, 0.1)}
    else:
        community = community or common.afs_small()
        genomes = community.genomes
        toks, lens, _, true_ab = community.samples["kylo"]
        config = ProfilerConfig(space=common.BENCH_SPACE, window=4096,
                                batch_size=256, backend="pcm_sim")
        sweeps = {"read_sigma": (0.0, 0.02, 0.05, 0.1, 0.2),
                  "prog_sigma": (0.0, 0.05, 0.1, 0.2)}

    # -- 1. accuracy vs device non-ideality --------------------------------
    # One digital build shared by every knob and level (encode is
    # bit-exact across backends, so the prototypes never change).
    builder = ProfilingSession(dataclasses.replace(config,
                                                   backend="reference"))
    refdb = builder.build_refdb(genomes)

    results: dict = {}
    for knob, levels in sweeps.items():
        points = noise_sweep(genomes, toks, lens, true_ab, config=config,
                             knob=knob, levels=levels, refdb=refdb)
        results[knob] = points
        for p in points:
            tag = f"accel.sweep.{knob}_{p.value:g}"
            emit(f"{tag}.precision", p.metrics.precision,
                 f"recall={p.metrics.recall:.4f}")
            emit(f"{tag}.l1", p.metrics.l1_error,
                 f"unmapped={p.unmapped_frac:.4f}")

    # -- 2. Table-3-style analytical cost at the production design point ---
    window = 8192
    num_protos = int(sum(-(-len(g) // window) for g in genomes.values()))
    sp = common.PROD_SPACE
    cost = accel_cost(num_protos=num_protos, dim=sp.dim, read_len=READ_LEN,
                      ngram=sp.ngram, xcfg=CrossbarConfig())
    for name, pj, pct in cost.energy_rows():
        emit(f"accel.energy.{name}.pj_per_read", pj, f"{pct:.1f}%")
    emit("accel.energy.total.pj_per_read", cost.total_pj,
         f"program_once={cost.program_pj:.0f}pJ")
    emit("accel.energy.total.mbp_per_joule", cost.mbp_per_joule(READ_LEN),
         "paper:9.45Mbp/J(PCM)")
    emit("accel.latency.ns_per_read", cost.latency_ns,
         f"{cost.reads_per_s:.0f}reads/s")
    emit("accel.area.total_mm2", cost.total_area_mm2,
         f"arrays={cost.num_arrays}")
    results["cost"] = cost
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny community + short sweep (CI-sized)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
