"""Acc-Demeter device-model benchmark: noise sweeps, MLC recovery, co-design.

Four artifacts, all through the simulated substrates in ``repro.accel``,
written to ``BENCH_accel.json``:

1. **Noise sweep** (Karunaratne-style robustness curve, PCM): the
   AFS-analogue sample profiled through ``pcm_sim`` while stepping read
   noise (and, in full mode, programming noise), emitting
   precision/recall/L1/unmapped at every level.  Level 0 doubles as the
   zero-noise bit-exactness check: its metrics equal the digital
   reference's by construction.
2. **Multi-bit recovery** (PCM): the same workload at a read-noise point
   chosen so *binary* cells degrade, re-run with 4- and 8-level MLC
   cells — whose per-level noise shrinks by ``levels - 1`` — recovering
   the accuracy the binary AM lost.
3. **Noise-aware co-design** (racetrack): the shift-faulted sweep point
   profiled against the naive RefDB and against the noise-aware build
   (``ProfilerConfig(noise_aware_refdb=True)``), demonstrating the
   write-verify + retraining pass recovering reads the naive build
   loses to track misalignment.
4. **Cost comparison** (Table 3 analogue): the analytical 65nm/PCM and
   domain-wall/racetrack latency/energy/area breakdowns of the same AM
   at the production HD dimension, including the paper's headline Mbp/J.

``--smoke`` shrinks the communities and sweeps so CI can run end to end
in seconds; ``--substrate`` restricts the run to one substrate's
sections (CI runs both).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

import numpy as np

from benchmarks import common
from repro.accel import (CrossbarConfig, accel_cost, noise_sweep,
                         racetrack_cost)
from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import ArraySource, ProfilerConfig, ProfilingSession

READ_LEN = 150

SMOKE_SPACE = HDSpace(dim=512, ngram=8, z_threshold=3.0)
SMOKE_CONFIG = ProfilerConfig(space=SMOKE_SPACE, window=1024, batch_size=64,
                              backend="pcm_sim")

#: Device-study community (full mode): small enough that a dozen profiled
#: sweep points stay cheap, large enough that the margins behave like the
#: production design point.
DEVICE_SPACE = HDSpace(dim=2048, ngram=12, z_threshold=4.0)
DEVICE_CONFIG = ProfilerConfig(space=DEVICE_SPACE, window=2048,
                               batch_size=128, backend="pcm_sim")


def _smoke_workload():
    """Tiny synthetic community: seconds on CPU, exercises every path."""
    spec = synth.CommunitySpec(num_species=4, genome_len=8_000, seed=13)
    genomes = synth.make_reference_genomes(spec)
    ab = np.array([0.5, 0.0, 0.5, 0.0])
    toks, lens, _ = synth.sample_reads(genomes, ab, 200, spec)
    return genomes, toks, lens, ab


def _device_workload():
    spec = synth.CommunitySpec(num_species=8, genome_len=20_000, seed=5)
    genomes, toks, lens, _, true_ab = synth.make_sample(
        spec, num_reads=400, present=[1, 3, 5])
    return genomes, toks, lens, true_ab


def _profile_l1(config: ProfilerConfig, genomes, toks, lens, true_ab,
                refdb=None) -> dict:
    session = ProfilingSession(config)
    db = refdb if refdb is not None else session.build_refdb(genomes)
    report = session.profile(ArraySource(toks, lens), refdb=db)
    ab = np.asarray(report.abundance)
    return {"l1": float(np.abs(ab - true_ab).sum()),
            "unmapped_frac": report.unmapped_reads / report.total_reads,
            "multi_frac": report.multi_reads / report.total_reads}


def _multibit_section(config, genomes, toks, lens, true_ab, sigmas,
                      emit) -> list[dict]:
    """Accuracy at each (read noise, cell levels) pair; binary degrades
    at the high-noise points, MLC cells recover (noise scales 1/(L-1))."""
    points = []
    for sigma in sigmas:
        for levels in (2, 4, 8):
            opts = dict(config.options)
            opts.update(read_sigma=sigma, levels=levels, seed=3)
            cfg = dataclasses.replace(
                config, backend="pcm_sim",
                backend_options=tuple(sorted(opts.items())))
            row = _profile_l1(cfg, genomes, toks, lens, true_ab)
            row.update(read_sigma=sigma, levels=levels)
            points.append(row)
            emit(f"accel.multibit.sigma_{sigma:g}.levels_{levels}",
                 row["l1"], f"unmapped={row['unmapped_frac']:.3f}")
    return points


def _codesign_section(config, genomes, toks, lens, true_ab, emit,
                      shift: float = 0.5) -> dict:
    """Naive vs noise-aware RefDB at the shift-faulted racetrack point."""
    opts = (("seed", 3), ("shift_fault_rate", shift))
    naive_cfg = dataclasses.replace(config, backend="racetrack_sim",
                                    backend_options=opts)
    aware_cfg = dataclasses.replace(naive_cfg, noise_aware_refdb=True,
                                    noise_aware_iters=2)
    naive = _profile_l1(naive_cfg, genomes, toks, lens, true_ab)
    aware = _profile_l1(aware_cfg, genomes, toks, lens, true_ab)
    emit("accel.codesign.naive.l1", naive["l1"],
         f"unmapped={naive['unmapped_frac']:.3f}")
    emit("accel.codesign.noise_aware.l1", aware["l1"],
         f"unmapped={aware['unmapped_frac']:.3f}")
    return {"backend": "racetrack_sim", "options": dict(opts),
            "naive": naive, "noise_aware": aware}


def _cost_json(cost) -> dict:
    return {"substrate": cost.substrate,
            "rows": [[n, round(pj, 3), round(pct, 2)]
                     for n, pj, pct in cost.energy_rows()],
            "total_pj_per_read": cost.total_pj,
            "program_pj": cost.program_pj,
            "latency_ns_per_read": cost.latency_ns,
            "area_mm2": cost.total_area_mm2,
            "mbp_per_joule": cost.mbp_per_joule(READ_LEN),
            "num_arrays": cost.num_arrays}


def run(community=None, emit=common.emit, *, smoke: bool = False,
        substrate: str = "both",
        out: str | pathlib.Path = "BENCH_accel.json") -> dict:
    run_pcm = substrate in ("pcm", "both")
    run_rt = substrate in ("racetrack", "both")
    if smoke:
        genomes, toks, lens, true_ab = _smoke_workload()
        config = SMOKE_CONFIG
        sweeps = {"read_sigma": (0.0, 0.1)}
        mb_sigmas = (0.0, 1.2)
        device = (genomes, toks, lens, true_ab)
        device_config = config
    else:
        community = community or common.afs_small()
        genomes = community.genomes
        toks, lens, _, true_ab = community.samples["kylo"]
        config = ProfilerConfig(space=common.BENCH_SPACE, window=4096,
                                batch_size=256, backend="pcm_sim")
        sweeps = {"read_sigma": (0.0, 0.02, 0.05, 0.1, 0.2),
                  "prog_sigma": (0.0, 0.05, 0.1, 0.2)}
        mb_sigmas = (0.0, 0.6, 1.2, 1.8)
        device = _device_workload()
        device_config = DEVICE_CONFIG

    results: dict = {"mode": "smoke" if smoke else "full",
                     "substrates": [s for s, on in
                                    (("pcm", run_pcm), ("racetrack", run_rt))
                                    if on]}

    # -- 1. accuracy vs device non-ideality (PCM) --------------------------
    # One digital build shared by every knob and level (encode is
    # bit-exact across backends, so the prototypes never change).
    if run_pcm:
        builder = ProfilingSession(dataclasses.replace(
            config, backend="reference", backend_options=(),
            noise_aware_refdb=False))
        refdb = builder.build_refdb(genomes)
        results["sweeps"] = {}
        for knob, levels in sweeps.items():
            points = noise_sweep(genomes, toks, lens, true_ab,
                                 config=config, knob=knob, levels=levels,
                                 refdb=refdb)
            results["sweeps"][knob] = [
                {"value": p.value, "l1": p.metrics.l1_error,
                 "precision": p.metrics.precision,
                 "recall": p.metrics.recall,
                 "unmapped_frac": p.unmapped_frac} for p in points]
            for p in points:
                tag = f"accel.sweep.{knob}_{p.value:g}"
                emit(f"{tag}.precision", p.metrics.precision,
                     f"recall={p.metrics.recall:.4f}")
                emit(f"{tag}.l1", p.metrics.l1_error,
                     f"unmapped={p.unmapped_frac:.4f}")

        # -- 2. multi-bit cells recover what binary cells lose -------------
        results["multibit"] = _multibit_section(
            device_config, *device, mb_sigmas, emit)

    # -- 3. noise-aware RefDB co-design on the shift-faulted racetrack -----
    if run_rt:
        results["codesign"] = _codesign_section(
            device_config, *device, emit)

    # -- 4. Table-3-style analytical cost, both substrates -----------------
    window = 8192
    num_protos = int(sum(-(-len(g) // window) for g in genomes.values()))
    sp = common.PROD_SPACE
    results["cost"] = {}
    for name, on, fn in (("pcm", run_pcm, accel_cost),
                         ("racetrack", run_rt, racetrack_cost)):
        if not on:
            continue
        cost = fn(num_protos=num_protos, dim=sp.dim, read_len=READ_LEN,
                  ngram=sp.ngram, xcfg=CrossbarConfig())
        results["cost"][name] = _cost_json(cost)
        for row, pj, pct in cost.energy_rows():
            emit(f"accel.{name}.energy.{row}.pj_per_read", pj, f"{pct:.1f}%")
        emit(f"accel.{name}.energy.total.pj_per_read", cost.total_pj,
             f"program_once={cost.program_pj:.0f}pJ")
        emit(f"accel.{name}.energy.total.mbp_per_joule",
             cost.mbp_per_joule(READ_LEN), "paper:9.45Mbp/J(PCM)")
        emit(f"accel.{name}.latency.ns_per_read", cost.latency_ns,
             f"{cost.reads_per_s:.0f}reads/s")
        emit(f"accel.{name}.area.total_mm2", cost.total_area_mm2,
             f"arrays={cost.num_arrays}")

    pathlib.Path(out).write_text(json.dumps(results, indent=2))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny community + short sweep (CI-sized)")
    ap.add_argument("--substrate", choices=("pcm", "racetrack", "both"),
                    default="both", help="restrict to one substrate's "
                    "sections (the cost table always names its substrate)")
    ap.add_argument("--out", default="BENCH_accel.json",
                    help="machine-readable results path")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, substrate=args.substrate, out=args.out)


if __name__ == "__main__":
    main()
