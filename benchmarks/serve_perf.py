"""Serving benchmark: sustained reads/s + request latency through the
ProfilingService, vs cohort batch size and backend.

The paper's system framing (real-time food monitoring under heavy query
load) measured at the serving seam: many concurrent requests over one
shared RefDB, reads interleaved into fixed-shape cohorts.  Emits, per
``(backend, batch_size)`` cell:

  serve.{backend}.bs{B}.reads_per_s   sustained classified reads/s
  serve.{backend}.bs{B}.p50_ms        median request latency
  serve.{backend}.bs{B}.p99_ms        tail request latency

``--smoke`` shrinks the community, request count, and sweep so CI runs
the full admit/interleave/demux cycle in seconds.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks import common
from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import ArraySource, ProfilerConfig, ProfilingSession
from repro.serve import ProfilingService

SMOKE_SPACE = HDSpace(dim=512, ngram=8, z_threshold=3.0)


def _serve_cell(config: ProfilerConfig, refdb, sources, *,
                max_active: int) -> dict:
    """One (backend, batch) measurement: submit all, pump, collect stats."""
    session = ProfilingSession(config)
    session.refdb = refdb                 # shared database: built once
    service = ProfilingService(session, max_active=max_active,
                               max_queue=len(sources))
    # warmup: compile the cohort shapes on a throwaway request
    service.submit(sources[0])
    service.run_until_idle()
    service.reads_classified = 0

    handles = [service.submit(s) for s in sources]
    t0 = time.perf_counter()
    service.run_until_idle()
    wall = time.perf_counter() - t0
    reports = [h.result(timeout=0) for h in handles]
    p50, p99 = common.latency_percentiles_ms(
        [h.latency_s for h in handles])
    reads = sum(r.total_reads for r in reports)
    return {"reads_per_s": reads / max(wall, 1e-9),
            "p50_ms": p50, "p99_ms": p99}


def run(community=None, emit=common.emit, *, smoke: bool = False) -> dict:
    if smoke:
        spec = synth.CommunitySpec(num_species=4, genome_len=8_000, seed=13)
        genomes = synth.make_reference_genomes(spec)
        ab = np.full(4, 0.25)
        toks, lens, _ = synth.sample_reads(genomes, ab, 384, spec)
        base = ProfilerConfig(space=SMOKE_SPACE, window=1024, batch_size=32)
        cells = {"reference": (32,)}
        read_cap = {}
        num_requests, max_active = 8, 4
    else:
        community = community or common.afs_small()
        genomes = community.genomes
        toks, lens, *_ = community.samples["kylo"]
        base = common.BENCH_CONFIG
        # Pallas interpret mode on CPU is ~100ms/read at bench dims: one
        # read-capped cell keeps the kernel path measured without turning
        # the sweep into minutes (real TPU runs lift the cap).
        cells = {"reference": (64, 256, 1024),
                 "reference_packed": (64, 256, 1024),
                 "pallas_matmul": (256,)}
        read_cap = {"pallas_matmul": 256}
        num_requests, max_active = 16, 8

    builder = ProfilingSession(dataclasses.replace(base, backend="reference"))
    refdb = builder.build_refdb(genomes)

    def make_sources(cap: int | None):
        t = toks if cap is None else toks[:cap]
        l = lens if cap is None else lens[:cap]
        return [ArraySource(t[i::num_requests], l[i::num_requests])
                for i in range(num_requests)]

    out: dict = {}
    for backend, batch_sizes in cells.items():
        sources = make_sources(read_cap.get(backend))
        for bs in batch_sizes:
            config = dataclasses.replace(base, backend=backend,
                                         batch_size=bs)
            cell = _serve_cell(config, refdb, sources,
                               max_active=max_active)
            out[(backend, bs)] = cell
            tag = f"serve.{backend}.bs{bs}"
            emit(f"{tag}.reads_per_s", cell["reads_per_s"],
                 f"{num_requests}req/{max_active}active")
            emit(f"{tag}.p50_ms", cell["p50_ms"],
                 f"p99={cell['p99_ms']:.1f}ms")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny community + single cell (CI-sized)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
