"""Fig. 4/5 analogue: software query time per read + throughput (Mreads/min).

C-Demeter's role is played by the "reference" backend of a
ProfilingSession (jit'd, batched); baselines run their numpy hash
pipelines.  The paper's observation to reproduce: the *software* Demeter
is memory-bound and does NOT beat Kraken2 on CPU — that gap is the
motivation for Acc-Demeter (benchmarks/acc_perf.py projects the
accelerated version).
"""

from __future__ import annotations

from benchmarks import common
from repro.pipeline import ArraySource


def run(community=None, emit=common.emit, sample: str = "kylo") -> dict:
    community = community or common.afs_small()
    toks, lens, *_ = community.samples[sample]
    out = {}
    for pname, prof in common.make_profilers().items():
        if pname == "kraken2+bracken":
            continue                      # same classify path as kraken2
        if pname == "demeter":
            db = prof.build_refdb(community.genomes)
            batch = prof.config.batch_size
            # warmup (compile)
            res = prof.classify_batch(toks[:batch], lens[:batch], refdb=db)
            res.classification.scores.block_until_ready()

            def job():
                for b in ArraySource(toks, lens).batches(batch):
                    r = prof.classify_batch(b.tokens, b.lengths, refdb=db,
                                            num_valid=b.num_valid)
                    r.classification.scores.block_until_ready()
            secs, _ = common.timeit(job)
        else:
            prof.build(community.genomes)
            secs, _ = common.timeit(
                lambda: prof.classify_reads(toks, lens))
        n = len(toks)
        us_per_read = secs / n * 1e6
        mreads_per_min = n / secs * 60 / 1e6
        out[pname] = (us_per_read, mreads_per_min)
        emit(f"query.{pname}.us_per_read", us_per_read,
             f"{mreads_per_min:.4f}Mreads/min")
    return out


if __name__ == "__main__":
    run()
