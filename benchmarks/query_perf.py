"""Fig. 4/5 analogue: software query time per read + throughput (Mreads/min).

C-Demeter's role is played by the "reference" backend of a
ProfilingSession (jit'd, batched); baselines run their numpy hash
pipelines.  The paper's observation to reproduce: the *software* Demeter
is memory-bound and does NOT beat Kraken2 on CPU — that gap is the
motivation for Acc-Demeter (benchmarks/acc_perf.py projects the
accelerated version).
"""

from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.core import HDSpace
from repro.pipeline import ArraySource, ProfilerConfig, ProfilingSession


def fused_vs_two_kernel(community=None, emit=common.emit,
                        sample: str = "kylo", cap: int = 128) -> dict:
    """Fused megakernel vs the two-kernel Pallas path, same reads.

    The comparison the fused backend exists for: identical encode math,
    identical agreement — the only difference is whether the encoded
    ``(B, W)`` matrix round-trips through HBM between the kernels.  Reads
    are capped (interpret mode on CPU is orders slower than real TPU
    kernels; ratios, bytes/read, and the bit-exactness check are what
    transfer).  Emits per-backend us/read plus the analytic intermediate
    HBM bytes/read (see ``benchmarks.smoke.intermediate_bytes_per_read``).
    """
    from benchmarks.smoke import intermediate_bytes_per_read

    community = community or common.afs_small()
    toks, lens, *_ = community.samples[sample]
    toks, lens = toks[:cap], lens[:cap]
    # CPU-sane space: the W-axis still tiles (W=64 words, bw=64).
    space = HDSpace(dim=2048, ngram=16, z_threshold=5.0)
    config = ProfilerConfig(space=space, window=4096, batch_size=cap,
                            backend="reference")
    out, reports = {}, {}
    db = None
    for name in ("reference", "pallas_matmul", "pallas_fused"):
        prof = ProfilingSession(dataclasses.replace(config, backend=name))
        if db is None:
            db = prof.build_refdb(community.genomes)
        prof.refdb = db               # bit-exact twins: one shared build
        src = ArraySource(toks, lens)
        prof.profile(src)             # warmup (compile)
        secs, rep = common.timeit(lambda: prof.profile(src))
        reports[name] = rep.to_json()
        us = secs / len(toks) * 1e6
        bytes_per_read = intermediate_bytes_per_read(name, space)
        out[name] = (us, bytes_per_read)
        emit(f"query.fused_cmp.{name}.us_per_read", us,
             f"{bytes_per_read}B/read-intermediate")
    assert reports["pallas_fused"] == reports["reference"], \
        "pallas_fused report diverged from reference"
    assert reports["pallas_matmul"] == reports["reference"], \
        "pallas_matmul report diverged from reference"
    emit("query.fused_cmp.bit_exact", 0.0, "True")
    return out


def run(community=None, emit=common.emit, sample: str = "kylo") -> dict:
    community = community or common.afs_small()
    toks, lens, *_ = community.samples[sample]
    out = {}
    for pname, prof in common.make_profilers().items():
        if pname == "kraken2+bracken":
            continue                      # same classify path as kraken2
        if pname == "demeter":
            db = prof.build_refdb(community.genomes)
            batch = prof.config.batch_size
            # warmup (compile)
            res = prof.classify_batch(toks[:batch], lens[:batch], refdb=db)
            res.classification.scores.block_until_ready()

            def job():
                for b in ArraySource(toks, lens).batches(batch):
                    r = prof.classify_batch(b.tokens, b.lengths, refdb=db,
                                            num_valid=b.num_valid)
                    r.classification.scores.block_until_ready()
            secs, _ = common.timeit(job)
        else:
            prof.build(community.genomes)
            secs, _ = common.timeit(
                lambda: prof.classify_reads(toks, lens))
        n = len(toks)
        us_per_read = secs / n * 1e6
        mreads_per_min = n / secs * 60 / 1e6
        out[pname] = (us_per_read, mreads_per_min)
        emit(f"query.{pname}.us_per_read", us_per_read,
             f"{mreads_per_min:.4f}Mreads/min")
    fused_vs_two_kernel(community, emit, sample)
    return out


if __name__ == "__main__":
    run()
