"""Table 3 analogue: per-query energy breakdown of the accelerated profiler.

The paper reports PCM-array area/energy from synthesis; analog in-memory
energy does not transfer to TPU (DESIGN.md §2), so this benchmark applies
the first-principles digital model in hw.py to the same workload and
reports (a) the per-unit breakdown (encoder / AM search / IO) and (b) the
paper's headline efficiency metric, Mbp per joule.
"""

from __future__ import annotations

from benchmarks import common
from benchmarks.hw import V5E


def run(community=None, emit=common.emit, *, read_len: int = 150) -> dict:
    sp = common.PROD_SPACE
    community = community or common.afs_small()
    num_protos = int(sum(-(-len(g) // 8192)
                         for g in community.genomes.values()))
    g = read_len - sp.ngram + 1
    d = sp.dim

    # encoder: c_enc VPU ops per bit per gram + majority
    enc_ops = g * d * 1.25 + d
    e_encoder = enc_ops * V5E.pj_per_vpu_op
    # AM search: 2*S*D MACs on the MXU + score readout
    e_search = 2 * num_protos * d * 0.5 * V5E.pj_per_mac_bf16
    # IO: packed query to/from HBM + scores
    io_bytes = d / 8 * 2 + num_protos * 4
    e_io = io_bytes * V5E.pj_per_hbm_byte
    total_pj = e_encoder + e_search + e_io

    for name, e in (("encoder", e_encoder), ("am_search", e_search),
                    ("io", e_io)):
        emit(f"energy.{name}.pj_per_read", e,
             f"{100 * e / total_pj:.1f}%")
    emit("energy.total.pj_per_read", total_pj, "digital-model")
    mbp_per_joule = read_len / (total_pj * 1e-12) / 1e6
    emit("energy.total.mbp_per_joule", mbp_per_joule, f"{mbp_per_joule:.2f}")
    emit("energy.paper_reference", 9.45,
         "paper:9.45Mbp/J(PCM);kraken2:<=0.6Mbp/J")
    return {"encoder_pj": e_encoder, "search_pj": e_search, "io_pj": e_io,
            "mbp_per_joule": mbp_per_joule}


if __name__ == "__main__":
    run()
