"""Multi-tenant serving benchmark: fleet throughput through the
registry + router control plane, and the cost of a live delta hot-swap.

The paper's system framing is continuous food monitoring — in
production that means several tenants sharing reference databases that
are updated under live traffic.  Measures, per ``(tenants, workers)``
cell over one shared database:

  tenant.{backend}.t{T}.w{W}.reads_per_s   fleet sustained reads/s
  tenant.{backend}.t{T}.w{W}.p50_ms        median request latency
  tenant.{backend}.t{T}.w{W}.p99_ms        tail request latency

and for the live-update path (one tenant submitting while an
add-species delta publishes):

  tenant.swap.publish_ms    registry apply_delta -> new version serving
                            (delta build + atomic publish + router swap)
  tenant.swap.drain_ms      old version in-flight work fully drained
                            after the swap (the zero-downtime window)

``--smoke`` shrinks the community and the sweep so CI runs the full
create/route/swap/drain cycle in seconds.
"""

from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from benchmarks import common
from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import ArraySource, ProfilerConfig
from repro.serve import RefDBRegistry, TenantRouter

SMOKE_SPACE = HDSpace(dim=512, ngram=8, z_threshold=3.0)


def _fleet_cell(registry: RefDBRegistry, sources, *, tenants: int,
                workers: int) -> dict:
    """One (tenants, workers) measurement: route all requests, collect."""
    router = TenantRouter(registry)
    names = [f"t{i}" for i in range(tenants)]
    per_tenant = {n: sources[i::tenants] for i, n in enumerate(names)}
    for n in names:
        router.add_tenant(n, database="bench", max_active=8,
                          max_queue=len(per_tenant[n]))
    # warmup: compile the cohort shapes on a throwaway request
    w = router.submit(per_tenant[names[0]][0], tenant=names[0])
    router.run_until_idle()
    w.result(timeout=0)

    handles = []
    router.start(workers)
    try:
        t0 = time.perf_counter()
        for n in names:
            for src in per_tenant[n]:
                handles.append(router.submit(src, tenant=n, block=True,
                                             timeout=600))
        reports = [h.result(timeout=600) for h in handles]
        wall = time.perf_counter() - t0
    finally:
        router.stop()
        router.close()
    p50, p99 = common.latency_percentiles_ms(
        [h.latency_s for h in handles])
    reads = sum(r.total_reads for r in reports)
    return {"reads_per_s": reads / max(wall, 1e-9),
            "p50_ms": p50, "p99_ms": p99}


def _swap_cell(registry: RefDBRegistry, sources, delta_genomes) -> dict:
    """Publish an add-species delta under traffic; time publish + drain."""
    router = TenantRouter(registry)
    router.add_tenant("t0", database="bench", max_active=8,
                      max_queue=len(sources))
    old = router.serving_version("bench")
    handles = [router.submit(s, tenant="t0") for s in sources]
    router.start(1)
    try:
        t0 = time.perf_counter()
        registry.apply_delta("bench", add=delta_genomes)
        publish_s = time.perf_counter() - t0          # serving is now new
        assert router.serving_version("bench") > old
        while router.draining_versions("bench"):      # old version drains
            time.sleep(0.002)
        drain_s = time.perf_counter() - t0 - publish_s
        for h in handles:
            h.result(timeout=600)
    finally:
        router.stop()
        router.close()
    return {"publish_ms": publish_s * 1e3, "drain_ms": max(drain_s, 0) * 1e3}


def run(community=None, emit=common.emit, *, smoke: bool = False) -> dict:
    if smoke:
        spec = synth.CommunitySpec(num_species=4, genome_len=8_000, seed=13)
        genomes = synth.make_reference_genomes(spec)
        ab = np.full(4, 0.25)
        toks, lens, _ = synth.sample_reads(genomes, ab, 256, spec)
        config = ProfilerConfig(space=SMOKE_SPACE, window=1024,
                                batch_size=32)
        cells = [(2, 1)]
        num_requests = 8
    else:
        community = community or common.afs_small()
        genomes = community.genomes
        toks, lens, *_ = community.samples["kylo"]
        config = common.BENCH_CONFIG
        cells = [(1, 1), (4, 1), (4, 2)]
        num_requests = 16

    registry = RefDBRegistry(
        root=tempfile.mkdtemp(prefix="bench-registry-"))
    registry.create("bench", genomes, config)
    sources = [ArraySource(toks[i::num_requests], lens[i::num_requests])
               for i in range(num_requests)]
    rng = np.random.default_rng(14)
    glen = len(next(iter(genomes.values())))
    delta = {"sp_delta": rng.integers(0, 4, glen, dtype=np.int32)}

    out: dict = {}
    for tenants, workers in cells:
        cell = _fleet_cell(registry, sources, tenants=tenants,
                           workers=workers)
        out[(tenants, workers)] = cell
        tag = f"tenant.{config.backend}.t{tenants}.w{workers}"
        emit(f"{tag}.reads_per_s", cell["reads_per_s"],
             f"{num_requests}req/{workers}worker")
        emit(f"{tag}.p50_ms", cell["p50_ms"],
             f"p99={cell['p99_ms']:.1f}ms")

    swap = _swap_cell(registry, sources, delta)
    out["swap"] = swap
    emit("tenant.swap.publish_ms", swap["publish_ms"],
         "delta build+publish+router swap")
    emit("tenant.swap.drain_ms", swap["drain_ms"],
         "old version drained under traffic")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny community + single cell (CI-sized)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
