"""Benchmark-regression gate: BENCH_smoke.json vs benchmarks/baseline.json.

CI runs this right after ``benchmarks/run.py --smoke``::

    PYTHONPATH=src python -m benchmarks.check_regression

It fails (exit 1) when, for any backend present in the baseline,

* ``relative_throughput`` (reads/s normalized to the same run's
  ``reference`` backend, so runner speed cancels) dropped more than
  ``--tolerance`` (default 20%) below the baseline ratio, or
* ``intermediate_bytes_per_read`` increased at all — the traffic model
  is deterministic, so any increase is a real dataflow regression (e.g.
  the fused path re-materializing the encoded matrix), or
* ``prototype_bytes_per_read`` increased at all — same determinism
  argument for the prototype stream: growth means a kernel re-fetches
  prototype tiles it used to amortize (old baselines without the field
  skip this check until refreshed), or
* ``observability.enabled_over_disabled`` fell below ``1 -
  --obs-tolerance`` (default 2%) — the metrics layer's overhead guard:
  turning observability ON must not cost the hot path more than 2%, and
  its report must stay bit-identical (which also pins the disabled mode,
  a strict subset of the enabled one, at zero measurable cost), or
* ``fleet.relative_aggregate`` (3-host aggregate reads/s over the same
  run's 1-host cell) dropped more than ``--fleet-tolerance`` (default
  50% — thread-scheduling noise on shared runners is real) below the
  baseline ratio, or ``fleet.bit_exact`` is false — a fleet-routed
  report diverging from its sequential twin breaks the determinism
  contract behind replication and failover, and fails hard at ANY
  tolerance.

Backends in the current run but not the baseline are reported and pass
(new backends enter the gate when the baseline is refreshed).

Refresh after an intentional perf change::

    PYTHONPATH=src python -m benchmarks.run --smoke
    PYTHONPATH=src python -m benchmarks.check_regression --update
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

BASELINE = pathlib.Path(__file__).resolve().parent / "baseline.json"
#: Per-backend fields carried into the baseline (the stable, comparable
#: subset — absolute reads/s is runner-dependent and deliberately left out).
BASELINE_FIELDS = ("relative_throughput", "intermediate_bytes_per_read",
                   "prototype_bytes_per_read")


def load(path: pathlib.Path) -> dict:
    try:
        return json.loads(path.read_text())
    except FileNotFoundError:
        raise SystemExit(f"missing {path}; run "
                         f"`python -m benchmarks.run --smoke` first")


def update_baseline(current: dict, path: pathlib.Path = BASELINE) -> dict:
    """Write the comparable subset of ``current`` as the new baseline."""
    baseline = {
        "schema": current["schema"],
        "backends": {
            name: {f: r[f] for f in BASELINE_FIELDS if f in r}
            for name, r in current["backends"].items()
        },
    }
    if "fleet" in current:
        baseline["fleet"] = {
            "relative_aggregate": current["fleet"]["relative_aggregate"],
        }
    path.write_text(json.dumps(baseline, indent=2, sort_keys=True) + "\n")
    return baseline


def check(current: dict, baseline: dict, tolerance: float = 0.20,
          obs_tolerance: float = 0.02,
          fleet_tolerance: float = 0.50) -> list[str]:
    """All regression messages (empty == gate green)."""
    problems = []
    cur = current["backends"]
    for name, base in baseline["backends"].items():
        if name not in cur:
            problems.append(f"{name}: present in baseline but not measured")
            continue
        got = cur[name]
        floor = base["relative_throughput"] * (1.0 - tolerance)
        if got["relative_throughput"] < floor:
            problems.append(
                f"{name}: relative throughput {got['relative_throughput']:.4f}"
                f" < {floor:.4f} (baseline "
                f"{base['relative_throughput']:.4f} - {tolerance:.0%})")
        if got["intermediate_bytes_per_read"] \
                > base["intermediate_bytes_per_read"]:
            problems.append(
                f"{name}: intermediate bytes/read grew "
                f"{base['intermediate_bytes_per_read']} -> "
                f"{got['intermediate_bytes_per_read']}")
        # Pre-PR-9 baselines have no prototype-stream field; they start
        # gating it on the next --update.
        base_proto = base.get("prototype_bytes_per_read")
        if base_proto is not None \
                and got.get("prototype_bytes_per_read", 0) > base_proto:
            problems.append(
                f"{name}: prototype bytes/read grew "
                f"{base_proto} -> {got['prototype_bytes_per_read']}")
    if not current.get("bit_exact", False):
        problems.append("backend reports were not bit-identical")
    observability = current.get("observability")
    if observability is not None:
        ratio = observability["enabled_over_disabled"]
        floor = 1.0 - obs_tolerance
        if ratio < floor:
            problems.append(
                f"observability: enabled/disabled throughput ratio "
                f"{ratio:.4f} < {floor:.4f} (metrics layer costs more "
                f"than {obs_tolerance:.0%} on the hot path)")
        if not observability.get("bit_exact", False):
            problems.append(
                "observability: enabling metrics changed the report")
    fleet = current.get("fleet")
    if fleet is not None:
        # Bit-exactness is the hard gate — no tolerance applies: a
        # rerouted or replicated report must match its sequential twin.
        if not fleet.get("bit_exact", False):
            problems.append(
                "fleet: routed reports diverged from sequential runs "
                "(determinism contract broken — no tolerance applies)")
        base_fleet = baseline.get("fleet")
        if base_fleet is not None:
            floor = base_fleet["relative_aggregate"] * \
                (1.0 - fleet_tolerance)
            if fleet["relative_aggregate"] < floor:
                problems.append(
                    f"fleet: 3-host/1-host aggregate throughput "
                    f"{fleet['relative_aggregate']:.4f} < {floor:.4f} "
                    f"(baseline {base_fleet['relative_aggregate']:.4f} "
                    f"- {fleet_tolerance:.0%})")
    return problems


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bench", nargs="?", default="BENCH_smoke.json",
                    help="benchmark JSON produced by run.py --smoke")
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed relative-throughput drop (0.20 = 20%%)")
    ap.add_argument("--obs-tolerance", type=float, default=0.02,
                    help="allowed throughput cost of enabling the"
                         " metrics layer (0.02 = 2%%)")
    ap.add_argument("--fleet-tolerance", type=float, default=0.50,
                    help="allowed drop in the 3-host/1-host aggregate"
                         " throughput ratio (0.50 = 50%%; bit-exactness"
                         " failures ignore this and always fail)")
    ap.add_argument("--update", action="store_true",
                    help="refresh the baseline from the current run "
                         "instead of gating")
    args = ap.parse_args(argv)

    current = load(pathlib.Path(args.bench))
    if args.update:
        update_baseline(current, pathlib.Path(args.baseline))
        print(f"baseline refreshed from {args.bench} -> {args.baseline}")
        return
    baseline = load(pathlib.Path(args.baseline))
    for name, r in sorted(current["backends"].items()):
        marker = "" if name in baseline["backends"] else "  (not gated yet)"
        print(f"{name}: rel={r['relative_throughput']:.4f} "
              f"bytes/read={r['intermediate_bytes_per_read']} "
              f"proto_bytes/read={r.get('prototype_bytes_per_read', '-')}"
              f"{marker}")
    if "observability" in current:
        print(f"observability: enabled/disabled="
              f"{current['observability']['enabled_over_disabled']:.4f}")
    if "fleet" in current:
        print(f"fleet: 3-host/1-host aggregate="
              f"{current['fleet']['relative_aggregate']:.4f} "
              f"bit_exact={current['fleet']['bit_exact']}")
    problems = check(current, baseline, args.tolerance, args.obs_tolerance,
                     args.fleet_tolerance)
    if problems:
        print("\nREGRESSION GATE FAILED:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        raise SystemExit(1)
    print("\nregression gate: green")


if __name__ == "__main__":
    main()
