"""Fig. 2/3 analogue: precision & recall per profiler, per sample.

Paper claim being reproduced: Demeter stays within ~2% precision / ~3%
recall of MetaCache (the most accurate profiler) on both samples.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.baselines import bracken_like
from repro.core import UNIQUE
from repro.eval import score_profile
from repro.pipeline import ArraySource


def run(community=None, emit=common.emit) -> dict:
    community = community or common.afs_small()
    glens = community.genome_lengths
    results = {}
    for pname, prof in common.make_profilers().items():
        if pname == "demeter":
            db = prof.build_refdb(community.genomes)
        else:
            prof.build(community.genomes)
        for sname, (toks, lens, truth, true_ab) in community.samples.items():
            if pname == "demeter":
                rep = prof.profile(ArraySource(toks, lens), refdb=db)
                est = rep.abundance
            else:
                hits, cat = prof.classify_reads(toks, lens)
                if pname == "kraken2":
                    # plain kraken2: species abundance from unique
                    # assignments only — multi-mapped reads stay at the
                    # ambiguous rank until bracken redistributes them
                    uniq = np.asarray(hits)[np.asarray(cat) == UNIQUE]
                    counts = uniq.sum(axis=0).astype(np.float64)
                    est = counts / max(counts.sum(), 1e-30)
                else:
                    est = np.asarray(bracken_like.estimate_abundance(
                        hits, cat, glens).abundance)
            m = score_profile(est, true_ab)
            results[(pname, sname)] = m
            emit(f"accuracy.{pname}.{sname}.precision", 0.0,
                 f"{m.precision:.4f}")
            emit(f"accuracy.{pname}.{sname}.recall", 0.0, f"{m.recall:.4f}")
            emit(f"accuracy.{pname}.{sname}.l1", 0.0, f"{m.l1_error:.4f}")
    # the paper's headline delta vs the most accurate baseline
    for sname in community.samples:
        dp = results[("demeter", sname)].precision \
            - results[("metacache", sname)].precision
        dr = results[("demeter", sname)].recall \
            - results[("metacache", sname)].recall
        emit(f"accuracy.delta_vs_metacache.{sname}", 0.0,
             f"dP={dp:+.4f};dR={dr:+.4f}")
    return results


if __name__ == "__main__":
    run()
