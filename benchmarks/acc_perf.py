"""Fig. 12/13 analogue: Acc-Demeter query time & throughput — TPU projection.

No TPU exists in this container, so this benchmark does what the paper does
with its RTL model: drive a calibrated performance model of the
*accelerated* pipeline with the real workload parameters, and cross-check
kernel correctness in interpret mode (bit-exact vs ref — test suite).

Model (per v5e chip; constants in hw.py):
  encoder  (VPU): rolling-gram XOR/select + per-bit counter accumulate
                  ~ c_enc vector-ops per HD bit per gram.
  AM search(MXU): +-1 matmul, 2*B*S*D flops (kernels/am_matmul.py).
  majority (VPU): D ops per read.
Encode and search pipeline (paper pipelines steps 3 and 4), so chip
throughput = 1 / max(stage times) — the paper's own bottleneck analysis
(encoder-bound, §7.3) is reproduced by the model.
"""

from __future__ import annotations

from benchmarks import common
from benchmarks.hw import V5E


def stage_times(read_len: int, n: int, dim: int, num_protos: int,
                batch: int = 4096) -> dict:
    g = read_len - n + 1
    c_enc = 1.25         # ops/bit/gram: 1 add (counter) + rolling-gram XORs
    enc_ops = batch * g * dim * c_enc + batch * dim        # + majority
    enc_t = enc_ops / V5E.vpu_ops
    mm_flops = 2.0 * batch * num_protos * dim
    mm_t = mm_flops / V5E.bf16_flops
    # HBM traffic: packed queries out + scores; prototypes resident in VMEM
    hbm_bytes = batch * (dim / 8) * 2 + batch * num_protos * 4
    hbm_t = hbm_bytes / V5E.hbm_bw
    return {"encode_s": enc_t, "search_s": max(mm_t, hbm_t),
            "per_read_us": max(enc_t, mm_t, hbm_t) / batch * 1e6,
            "reads_per_s": batch / max(enc_t, mm_t, hbm_t)}


def run(community=None, emit=common.emit, software_query=None) -> dict:
    community = community or common.afs_small()
    cfg = common.PROD_CONFIG      # the accelerated deployment config
    sp = cfg.space
    # prototype count at the production window size for this community
    num_protos = int(sum(-(-len(g) // cfg.window)
                         for g in community.genomes.values()))
    st = stage_times(150, sp.ngram, sp.dim, max(num_protos, 128),
                     batch=cfg.batch_size)
    emit("acc.model.encode_us_per_read",
         st["encode_s"] / cfg.batch_size * 1e6, "VPU-bound")
    emit("acc.model.search_us_per_read",
         st["search_s"] / cfg.batch_size * 1e6, "MXU")
    emit("acc.model.query_us_per_read", st["per_read_us"],
         f"{st['reads_per_s'] * 60 / 1e6:.2f}Mreads/min")
    bottleneck = "encoder" if st["encode_s"] >= st["search_s"] else "search"
    emit("acc.model.bottleneck", 0.0, bottleneck)

    # speedup vs our own software measurements (paper Fig12/13 structure)
    if software_query:
        for base, (us, _) in software_query.items():
            emit(f"acc.speedup_vs_{base}", 0.0,
                 f"{us / st['per_read_us']:.1f}x")
    return st


if __name__ == "__main__":
    run()
