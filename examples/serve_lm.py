"""Serve a small LM with batched requests (prefill + lockstep decode).

LEGACY: this exercises the seed repo's LM serving stack.  The
profiler-first serving path — the one new work targets — is
``python -m repro.launch.serve_profiler`` (see docs/API.md "Serving").

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch import serve as serve_mod

print("cohort 1: mamba2 (SSM decode, O(1) state)")
serve_mod.serve("mamba2_1_3b", num_requests=4, decode_steps=12,
                prompt_len=16)

print("\ncohort 2: deepseek-v2-lite (MLA absorbed decode + MoE)")
serve_mod.serve("deepseek_v2_lite", num_requests=4, decode_steps=12,
                prompt_len=16)

print("\ncohort 3: hymba (hybrid SWA ring buffer + SSM state)")
serve_mod.serve("hymba_1_5b", num_requests=4, decode_steps=12,
                prompt_len=16, temperature=0.8)
