"""Quickstart: profile a synthetic food sample with Demeter in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import ProfilerConfig, ProfilingSession, SyntheticSource

# 1. one frozen config: HD space (paper step 1), windowing, named backend
config = ProfilerConfig(
    space=HDSpace(dim=4096, ngram=16, z_threshold=5.0),
    window=4096, batch_size=128, backend="reference")

# 2. a tiny synthetic reference database + food sample (with ground truth)
sample = SyntheticSource(
    synth.CommunitySpec(num_species=6, genome_len=30_000),
    num_reads=500, present=[0, 2, 4])

# 3. build the HD reference DB (step 2) and profile (steps 3-5)
session = ProfilingSession(config)
refdb = session.build_refdb(sample.genomes)
report = session.profile(sample)

print(f"AM size: {refdb.memory_bytes() / 1e3:.0f} KB "
      f"({refdb.num_prototypes} prototypes)")
print("estimated abundance vs truth:")
for i, name in enumerate(report.species_names):
    print(f"  {name:14s} est {100 * report.abundance[i]:6.2f}%   "
          f"true {100 * sample.true_abundance[i]:6.2f}%")
