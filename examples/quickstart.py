"""Quickstart: profile a synthetic food sample with Demeter in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import HDSpace, Demeter, batch_reads
from repro.genomics import synth

# 1. define the HD space (paper step 1)
space = HDSpace(dim=4096, ngram=16, z_threshold=5.0)

# 2. a tiny synthetic reference database + food sample
spec = synth.CommunitySpec(num_species=6, genome_len=30_000)
genomes, reads, lengths, truth, true_ab = synth.make_sample(
    spec, num_reads=500, present=[0, 2, 4])

# 3. build the HD reference DB (step 2) and profile (steps 3-5)
demeter = Demeter(space, window=4096)
refdb = demeter.build_refdb(genomes)
report = demeter.profile(refdb, batch_reads(reads, lengths, 128))

print(f"AM size: {refdb.memory_bytes() / 1e3:.0f} KB "
      f"({refdb.num_prototypes} prototypes)")
print("estimated abundance vs truth:")
for i, name in enumerate(report.species_names):
    print(f"  {name:14s} est {100 * report.abundance[i]:6.2f}%   "
          f"true {100 * true_ab[i]:6.2f}%")
