"""End-to-end driver example: FASTA/FASTQ in, abundance report out.

Writes a synthetic community to disk as FASTA/FASTQ, then runs the
production profiling driver on the files — the full five-step pipeline
through a ProfilingSession with a named backend and fingerprint-keyed
RefDB caching, exactly as a lab would use it.

    PYTHONPATH=src python examples/profile_food_sample.py
"""

import pathlib
import tempfile

from repro.core import HDSpace
from repro.genomics import fasta, synth
from repro.launch import profile_run
from repro.pipeline import FastqSource, ProfilerConfig

spec = synth.CommunitySpec(num_species=8, genome_len=40_000, seed=3)
genomes, reads, lengths, truth, true_ab = synth.make_sample(
    spec, num_reads=1_000, present=[1, 4, 6])

config = ProfilerConfig(
    space=HDSpace(dim=8192, ngram=16, z_threshold=5.0),
    window=4096, batch_size=256, backend="reference")

with tempfile.TemporaryDirectory() as d:
    ref = pathlib.Path(d) / "ref.fasta"
    sample = pathlib.Path(d) / "sample.fastq"
    fasta.write_fasta(ref, genomes)
    fasta.write_fastq(sample, reads, lengths)

    g = fasta.read_fasta(ref)
    profile_run.profile(
        g, FastqSource(sample, spec.read_len), config=config, cache_dir=d)

print("\ntrue composition:")
for i, name in enumerate(genomes):
    if true_ab[i] > 0:
        print(f"  {name:24s} {100 * true_ab[i]:6.2f}%")
