"""Train a ~100M-param LM for a few hundred steps on synthetic data.

Uses the production training driver (checkpointing + deterministic data
replay included). On CPU this takes a few minutes; loss should drop
markedly on the structured corpus.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import sys

from repro.config import AttnConfig, ModelConfig
from repro.launch import train as train_mod

# ~100M params: 12L x d768 (GPT-2-small-ish), GQA 12H/4KV
CFG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    d_ff=3072,
    vocab=1024,
    attn=AttnConfig(num_heads=12, num_kv_heads=4, head_dim=64),
    act="silu",
    glu=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # register the config under a private name so the driver can find it
    mod = type(sys)("repro.configs.repro_100m")
    mod.CONFIG = CFG_100M
    mod.SMOKE = dataclasses.replace(CFG_100M, n_layers=2, d_model=64,
                                    d_ff=256, vocab=512,
                                    attn=AttnConfig(num_heads=4,
                                                    num_kv_heads=2,
                                                    head_dim=16))
    sys.modules["repro.configs.repro_100m"] = mod

    out = train_mod.train("repro_100m", steps=args.steps,
                          global_batch=args.batch, seq_len=args.seq_len,
                          smoke=False, mesh_kind="none",
                          ckpt_dir=args.ckpt_dir, peak_lr=1e-3)
    first = sum(out["losses"][:10]) / 10
    last = sum(out["losses"][-10:]) / 10
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({100 * (1 - last / first):.0f}% drop)")


if __name__ == "__main__":
    main()
