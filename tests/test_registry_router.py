"""RefDB registry + tenant router: the live-serving control plane.

Acceptance contract (ISSUE 6): under traffic on the ``reference``,
``pallas_fused``, and ``sharded`` backends, requests admitted before a
hot-swap produce reports bit-identical to a sequential run on the old
database version, requests admitted after see the new version, and
per-tenant quota overflow raises ``ServiceOverloaded`` without
disturbing other tenants.  Plus: delta add/remove correctness against
fresh builds, atomic versioned persistence, and registry reopen.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import assoc_memory
from repro.core.assoc_memory import build_refdb
from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession,
                            SyntheticSource)
from repro.serve import (RefDBRegistry, RouterClosed, ServiceOverloaded,
                         TenantRouter)

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=4, genome_len=6_000, seed=11)


def _config(**kw):
    kw.setdefault("space", SP)
    kw.setdefault("window", 1024)
    kw.setdefault("batch_size", 16)
    return ProfilerConfig(**kw)


@pytest.fixture(scope="module")
def sample():
    return SyntheticSource(SPEC, num_reads=144, present=[0, 2])


@pytest.fixture(scope="module")
def extra():
    """One genuinely new species for add-deltas."""
    rng = np.random.default_rng(99)
    return {"sp_new": rng.integers(0, 4, 6_000, dtype=np.int32)}


def _slices(sample, n):
    return [ArraySource(sample.tokens[i::n], sample.lengths[i::n])
            for i in range(n)]


def _same_db(a, b):
    np.testing.assert_array_equal(np.asarray(a.prototypes),
                                  np.asarray(b.prototypes))
    np.testing.assert_array_equal(np.asarray(a.proto_species),
                                  np.asarray(b.proto_species))
    np.testing.assert_array_equal(np.asarray(a.genome_lengths),
                                  np.asarray(b.genome_lengths))
    assert a.num_species == b.num_species
    assert a.species_names == b.species_names


# -- acceptance: zero-downtime swap, bit for bit ----------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas_fused", "sharded"])
def test_swap_under_traffic_bit_exact(tmp_path, sample, extra, backend):
    """Admitted-before requests run on v1 exactly; admitted-after on v2.

    The pre-swap requests are still queued/in-flight when the delta
    publishes — the strongest form of the contract: admission version,
    not completion time, decides what a request sees.
    """
    cfg = _config(backend=backend)
    reg = RefDBRegistry(root=tmp_path / backend)
    snap1 = reg.create("food", sample.genomes, cfg)
    router = TenantRouter(reg)
    router.add_tenant("acme", database="food", max_active=8, max_queue=8)

    srcs = _slices(sample, 6)
    pre = [router.submit(s, tenant="acme") for s in srcs[:3]]
    snap2 = reg.apply_delta("food", add=extra)      # auto hot-swap
    assert router.serving_version("food") == snap2.version == 2
    post = [router.submit(s, tenant="acme") for s in srcs[3:]]
    router.run_until_idle()

    seq1 = ProfilingSession(cfg)
    seq1.adopt_refdb(snap1.db)
    seq2 = ProfilingSession(cfg)
    seq2.adopt_refdb(snap2.db)
    for h, src in zip(pre, srcs[:3]):
        assert h.version == 1
        assert h.result(timeout=300).to_json() == seq1.profile(src).to_json()
    for h, src in zip(post, srcs[3:]):
        assert h.version == 2
        assert h.result(timeout=300).to_json() == seq2.profile(src).to_json()
        # the new species is visible to post-swap requests
        assert "sp_new" in h.result(timeout=0).species_names
    assert ("food", 1) in router.retired            # old version drained
    router.close()


def test_swap_under_live_worker_traffic(tmp_path, sample, extra):
    """Same contract with background pump workers racing the swap."""
    cfg = _config(backend="reference")
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, cfg)
    router = TenantRouter(reg)
    router.add_tenant("acme", database="food", max_active=2, max_queue=2)

    srcs = _slices(sample, 8)
    swapped = threading.Event()
    handles = []
    router.start(2)
    try:
        for i, src in enumerate(srcs):
            if i == len(srcs) // 2:
                reg.apply_delta("food", add=extra)
                swapped.set()
            handles.append(router.submit(src, tenant="acme",
                                         block=True, timeout=300))
        reports = [h.result(timeout=300) for h in handles]
    finally:
        router.stop()
    sessions = {}
    for h, src, rep in zip(handles, srcs, reports):
        if h.version not in sessions:
            s = ProfilingSession(cfg)
            s.adopt_refdb(reg.snapshot("food", h.version).db)
            sessions[h.version] = s
        assert rep.to_json() == sessions[h.version].profile(src).to_json()
    versions = {h.version for h in handles}
    assert versions == {1, 2}                       # both sides exercised
    router.close()


# -- per-tenant quotas -------------------------------------------------------

def test_quota_overflow_isolated(tmp_path, sample):
    cfg = _config(backend="reference")
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, cfg)
    router = TenantRouter(reg)
    router.add_tenant("small", database="food", max_active=1, max_queue=0)
    router.add_tenant("big", database="food", max_active=4, max_queue=4)

    srcs = _slices(sample, 6)
    h0 = router.submit(srcs[0], tenant="small")
    with pytest.raises(ServiceOverloaded, match="small"):
        router.submit(srcs[1], tenant="small")
    # the other tenant — same database — is untouched by the overflow
    big = [router.submit(s, tenant="big") for s in srcs[2:6]]
    router.run_until_idle()
    for h in [h0, *big]:
        assert h.result(timeout=300).total_reads > 0
    # quota frees as requests reach a terminal state
    h1 = router.submit(srcs[1], tenant="small")
    router.run_until_idle()
    assert h1.result(timeout=300).total_reads > 0
    router.close()


def test_unknown_tenant_and_duplicate_registration(tmp_path, sample):
    cfg = _config(backend="reference")
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, cfg)
    router = TenantRouter(reg)
    router.add_tenant("a", database="food")
    with pytest.raises(KeyError, match="nope"):
        router.submit(_slices(sample, 1)[0], tenant="nope")
    with pytest.raises(ValueError, match="already registered"):
        router.add_tenant("a", database="food")
    router.close()


# -- delta correctness -------------------------------------------------------

def test_add_delta_matches_fresh_build(tmp_path, sample, extra):
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, _config())
    snap2 = reg.apply_delta("food", add=extra)
    fresh = build_refdb({**sample.genomes, **extra}, SP, window=1024)
    _same_db(snap2.db, fresh)
    assert snap2.parent_version == 1
    assert snap2.delta == {"added": ["sp_new"], "removed": []}


def test_remove_delta_matches_fresh_build(tmp_path, sample):
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, _config())
    victim = list(sample.genomes)[1]
    snap2 = reg.apply_delta("food", remove=[victim])
    fresh = build_refdb(
        {k: v for k, v in sample.genomes.items() if k != victim},
        SP, window=1024)
    _same_db(snap2.db, fresh)
    assert snap2.delta == {"added": [], "removed": [victim]}


def test_genome_refresh_is_one_delta(tmp_path, sample):
    """Remove-then-add in a single delta = refreshing a species' genome."""
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, _config())
    name = list(sample.genomes)[0]
    rng = np.random.default_rng(7)
    refreshed = {name: rng.integers(0, 4, 6_000, dtype=np.int32)}
    snap2 = reg.apply_delta("food", add=refreshed, remove=[name])
    rest = {k: v for k, v in sample.genomes.items() if k != name}
    _same_db(snap2.db, build_refdb({**rest, **refreshed}, SP, window=1024))


def test_delta_rejects_bad_names(tmp_path, sample, extra):
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, _config())
    with pytest.raises(KeyError):
        reg.apply_delta("food", remove=["no_such_species"])
    with pytest.raises(ValueError, match="collide|already"):
        reg.apply_delta("food", add={list(sample.genomes)[0]:
                                     extra["sp_new"]})
    with pytest.raises(ValueError):
        reg.apply_delta("food", remove=list(sample.genomes))  # remove all
    assert reg.current("food").version == 1          # nothing published


def test_apply_delta_core_roundtrip(sample, extra):
    """core.assoc_memory.apply_delta keeps the sorted-proto_species
    invariant and composes add+remove as remove-then-add."""
    db = build_refdb(sample.genomes, SP, window=1024)
    addition = build_refdb(extra, SP, window=1024)
    out = assoc_memory.apply_delta(db, add=addition,
                                   remove=[list(sample.genomes)[2]])
    ps = np.asarray(out.proto_species)
    assert (np.diff(ps) >= 0).all()
    assert out.num_species == db.num_species         # -1 +1
    assert "sp_new" in out.species_names
    assert list(sample.genomes)[2] not in out.species_names


# -- versioned persistence ---------------------------------------------------

def test_registry_reopen_resumes_versioning(tmp_path, sample, extra):
    root = tmp_path / "r"
    reg = RefDBRegistry(root=root)
    reg.create("food", sample.genomes, _config())
    snap2 = reg.apply_delta("food", add=extra)

    back = RefDBRegistry.open(root)
    assert back.databases() == ("food",)
    cur = back.current("food")
    assert cur.version == 2
    _same_db(cur.db, snap2.db)
    # versioning continues where it left off, against the loaded state
    snap3 = back.apply_delta("food", remove=["sp_new"])
    assert snap3.version == 3 and snap3.parent_version == 2
    _same_db(snap3.db, build_refdb(sample.genomes, SP, window=1024))


def test_registry_snapshot_history(tmp_path, sample, extra):
    reg = RefDBRegistry(root=tmp_path / "r")
    snap1 = reg.create("food", sample.genomes, _config())
    reg.apply_delta("food", add=extra)
    assert reg.versions("food") == (1, 2)
    _same_db(reg.snapshot("food", 1).db, snap1.db)   # old version retained
    with pytest.raises(KeyError):
        reg.snapshot("food", 9)
    with pytest.raises(KeyError):
        reg.current("nope")


def test_registry_rejects_bad_database_names(tmp_path, sample):
    reg = RefDBRegistry(root=tmp_path / "r")
    for bad in ("", "../evil", "a/b", ".hidden"):
        with pytest.raises(ValueError):
            reg.create(bad, sample.genomes, _config())


# -- gc dry-run + recovery paths ---------------------------------------------

def test_gc_dry_run_previews_without_deleting(tmp_path, sample, extra):
    """dry_run reports exactly what a real sweep would take, and takes
    nothing — versions, files, and gc metrics are all untouched."""
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, _config())
    reg.apply_delta("food", add=extra)
    reg.apply_delta("food", remove=["sp_new"])
    preview = reg.gc("food", keep_last=1, dry_run=True)
    assert preview.dry_run
    assert preview.collected == (("food", 1), ("food", 2))
    assert preview.reclaimed_bytes > 0
    assert reg.versions("food") == (1, 2, 3)         # nothing deleted
    assert reg.snapshot("food", 1).path.exists()
    swept = reg.gc("food", keep_last=1)
    assert not swept.dry_run
    assert swept.collected == preview.collected
    assert swept.reclaimed_bytes == preview.reclaimed_bytes
    assert reg.versions("food") == (3,)


def test_reopen_after_gc_resumes_chain(tmp_path, sample, extra):
    """A registry reopened after gc sees only the retained versions and
    keeps numbering from the survivor — deltas apply onto a chain whose
    base was collected."""
    root = tmp_path / "r"
    reg = RefDBRegistry(root=root)
    reg.create("food", sample.genomes, _config())
    snap2 = reg.apply_delta("food", add=extra)
    assert reg.gc("food", keep_last=1).collected == (("food", 1),)

    back = RefDBRegistry.open(root)
    assert back.versions("food") == (2,)
    _same_db(back.current("food").db, snap2.db)
    snap3 = back.apply_delta("food", remove=["sp_new"])
    assert snap3.version == 3 and snap3.parent_version == 2
    _same_db(snap3.db, build_refdb(sample.genomes, SP, window=1024))


def test_publish_while_reader_pins_old_version(tmp_path, sample, extra):
    """A pinned old version survives publishes and gc sweeps until the
    reader releases it; then it is collectable."""
    reg = RefDBRegistry(root=tmp_path / "r")
    snap1 = reg.create("food", sample.genomes, _config())
    reg.pin("food", 1)                               # long-lived reader
    reg.apply_delta("food", add=extra)
    assert reg.gc("food", keep_last=1).collected == ()
    _same_db(reg.snapshot("food", 1).db, snap1.db)   # reader unharmed
    reg.release("food", 1)
    assert reg.gc("food", keep_last=1).collected == (("food", 1),)


# -- stop/submit race: closed admissions fail clean, never hang --------------

def test_submit_after_stop_raises_router_closed(tmp_path, sample):
    cfg = _config(backend="reference")
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, cfg)
    router = TenantRouter(reg)
    router.add_tenant("acme", database="food", max_active=4, max_queue=4)
    router.start(1)
    h = router.submit(_slices(sample, 2)[0], tenant="acme")
    router.stop()                                    # drains h first
    assert h.result(timeout=0).total_reads > 0
    with pytest.raises(RouterClosed, match="stopped"):
        router.submit(_slices(sample, 2)[1], tenant="acme")
    router.close()


def test_stop_wakes_quota_blocked_submit(tmp_path, sample):
    """A submit blocked on a full tenant quota when stop() lands must
    raise RouterClosed within a bounded wait — not sleep out its own
    timeout, and never hang."""
    cfg = _config(backend="reference")
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, cfg)
    router = TenantRouter(reg)
    router.add_tenant("acme", database="food", max_active=1, max_queue=0)
    srcs = _slices(sample, 2)
    router.submit(srcs[0], tenant="acme")    # fills the quota; no workers
    outcome: dict = {}

    def blocked():
        try:
            outcome["handle"] = router.submit(srcs[1], tenant="acme",
                                              block=True, timeout=300)
        except BaseException as e:           # noqa: BLE001 - recorded
            outcome["error"] = e

    t = threading.Thread(target=blocked)
    t.start()
    time.sleep(0.2)                          # let it block on the quota
    router.stop(drain=False)
    t.join(timeout=10)
    assert not t.is_alive()                  # bounded: woke well before 300s
    assert isinstance(outcome.get("error"), RouterClosed)
    router.close()


def test_stop_drain_races_live_submitters(tmp_path, sample):
    """Submits racing stop(drain=True) each either get a handle whose
    request then completes, or raise RouterClosed — no third outcome,
    no hang."""
    cfg = _config(backend="reference")
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, cfg)
    router = TenantRouter(reg)
    router.add_tenant("acme", database="food", max_active=2, max_queue=32)
    srcs = _slices(sample, 8)
    admitted, closed = [], []

    def submitter():
        for src in srcs:
            try:
                admitted.append(router.submit(src, tenant="acme",
                                              block=True, timeout=300))
            except RouterClosed:
                closed.append(src)

    router.start(2)
    t = threading.Thread(target=submitter)
    t.start()
    time.sleep(0.05)                         # land mid-stream
    router.stop(drain=True)
    t.join(timeout=30)
    assert not t.is_alive()
    assert len(admitted) + len(closed) == len(srcs)
    for h in admitted:                       # drain finished all admitted
        assert h.result(timeout=0).total_reads > 0
    router.close()


# -- shared backend across swaps ---------------------------------------------

def test_swap_reuses_backend_instance(tmp_path, sample, extra):
    """Hot-swap must not rebuild the backend (jit caches, device state)."""
    cfg = _config(backend="reference")
    reg = RefDBRegistry(root=tmp_path / "r")
    reg.create("food", sample.genomes, cfg)
    router = TenantRouter(reg)
    router.add_tenant("a", database="food")
    before = router._dbs["food"].current.session.backend
    reg.apply_delta("food", add=extra)
    after = router._dbs["food"].current.session.backend
    assert after is before
    router.close()
