"""Cohort scheduler: admission, lockstep decode, budgets, refill — plus
unit coverage of the generic FixedShapeScheduler both the LM loop and the
profiler service admit through."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import serve_step
from repro.serve.batching import CohortScheduler, Request
from repro.serve.scheduler import FixedShapeScheduler, pow2_buckets


def test_cohort_scheduler_end_to_end():
    cfg = get_config("stablelm_3b", smoke=True)
    params = lm.init_lm(jax.random.key(0), cfg)
    max_len = 32
    prefill = jax.jit(serve_step.make_prefill_step(cfg, max_len,
                                                   q_chunk=8, kv_chunk=8))
    decode = jax.jit(serve_step.make_decode_step(cfg))
    sched = CohortScheduler(
        slots=2, max_len=max_len,
        prefill_fn=lambda p: prefill(params, p),
        decode_fn=lambda t, c, pos: decode(params, t, c, pos),
        sample_fn=lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))

    rng = np.random.default_rng(0)
    for uid in range(5):                       # 5 requests -> 3 cohorts
        sched.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, 6 + uid).astype(np.int32),
            max_new_tokens=4 + uid % 3))
    done = sched.run()
    assert len(done) == 5
    for r in done:
        assert r.done and 1 <= len(r.out) <= r.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_cohort_matches_unbatched_greedy():
    """A single-slot cohort must reproduce serve_step.generate exactly."""
    cfg = get_config("mamba2_1_3b", smoke=True)
    params = lm.init_lm(jax.random.key(1), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    want = serve_step.generate(params, jnp.asarray(prompt[None]), cfg,
                               steps=5, max_len=32, q_chunk=8, kv_chunk=8)
    prefill = jax.jit(serve_step.make_prefill_step(cfg, 32,
                                                   q_chunk=8, kv_chunk=8))
    decode = jax.jit(serve_step.make_decode_step(cfg))
    sched = CohortScheduler(
        slots=1, max_len=32,
        prefill_fn=lambda p: prefill(params, p),
        decode_fn=lambda t, c, pos: decode(params, t, c, pos),
        sample_fn=lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
    sched.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    done = sched.run()
    np.testing.assert_array_equal(np.asarray(done[0].out),
                                  np.asarray(want[0]))


# -- FixedShapeScheduler (the generic admission core) -----------------------

def test_scheduler_fifo_cohorts_and_slot_cap():
    s = FixedShapeScheduler(slots=3)
    for i in range(7):
        s.submit(f"item{i}", size=10 + i)
    cohorts = s.drain()
    assert [list(c.items) for c in cohorts] == [
        ["item0", "item1", "item2"], ["item3", "item4", "item5"], ["item6"]]
    # exact-max padding when buckets=None
    assert [c.length for c in cohorts] == [12, 15, 16]
    assert s.next_cohort() is None and len(s) == 0


def test_scheduler_buckets_bound_the_shape_set():
    s = FixedShapeScheduler(slots=4, buckets=(64, 128, 256))
    for size in (10, 60, 64, 65):
        s.submit(size, size=size)
    (c,) = s.drain()
    assert c.length == 128                   # bucket of the largest item
    assert s.bucket_for(1) == 64 and s.bucket_for(256) == 256
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        s.submit("too-big", size=300)


def test_scheduler_validation():
    with pytest.raises(ValueError):
        FixedShapeScheduler(slots=0)
    with pytest.raises(ValueError):
        FixedShapeScheduler(slots=1, buckets=())
    s = FixedShapeScheduler(slots=1)
    with pytest.raises(ValueError):
        s.submit("x", size=-1)
    s.submit("x", size=0)                    # zero-size items are admitted
    assert s.next_cohort().length == 1


def test_pow2_buckets():
    assert pow2_buckets(16, 150) == (16, 32, 64, 128, 256)
    assert pow2_buckets(100, 100) == (128,)
    with pytest.raises(ValueError):
        pow2_buckets(0, 10)


def test_lm_cohorts_can_bucket_prompt_lengths():
    """The rewired LM scheduler accepts a bounded prompt-shape set."""
    calls = []

    def prefill(prompts):
        calls.append(prompts.shape)
        b = prompts.shape[0]
        return jnp.zeros((b, 7)), None

    sched = CohortScheduler(
        slots=2, max_len=64, buckets=(8, 16),
        prefill_fn=prefill,
        decode_fn=lambda t, c, pos: (jnp.zeros((t.shape[0], 7)), c),
        sample_fn=lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
    rng = np.random.default_rng(0)
    for uid, plen in enumerate((3, 8, 11, 5)):
        sched.submit(Request(uid=uid,
                             prompt=rng.integers(0, 7, plen).astype(np.int32),
                             max_new_tokens=2))
    done = sched.run()
    assert len(done) == 4 and all(r.done for r in done)
    assert [s[1] for s in calls] == [8, 16]  # two bucketed prefill shapes
