"""Cohort scheduler: admission, lockstep decode, budgets, refill."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve import serve_step
from repro.serve.batching import CohortScheduler, Request


def test_cohort_scheduler_end_to_end():
    cfg = get_config("stablelm_3b", smoke=True)
    params = lm.init_lm(jax.random.key(0), cfg)
    max_len = 32
    prefill = jax.jit(serve_step.make_prefill_step(cfg, max_len,
                                                   q_chunk=8, kv_chunk=8))
    decode = jax.jit(serve_step.make_decode_step(cfg))
    sched = CohortScheduler(
        slots=2, max_len=max_len,
        prefill_fn=lambda p: prefill(params, p),
        decode_fn=lambda t, c, pos: decode(params, t, c, pos),
        sample_fn=lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))

    rng = np.random.default_rng(0)
    for uid in range(5):                       # 5 requests -> 3 cohorts
        sched.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, 6 + uid).astype(np.int32),
            max_new_tokens=4 + uid % 3))
    done = sched.run()
    assert len(done) == 5
    for r in done:
        assert r.done and 1 <= len(r.out) <= r.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_cohort_matches_unbatched_greedy():
    """A single-slot cohort must reproduce serve_step.generate exactly."""
    cfg = get_config("mamba2_1_3b", smoke=True)
    params = lm.init_lm(jax.random.key(1), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    want = serve_step.generate(params, jnp.asarray(prompt[None]), cfg,
                               steps=5, max_len=32, q_chunk=8, kv_chunk=8)
    prefill = jax.jit(serve_step.make_prefill_step(cfg, 32,
                                                   q_chunk=8, kv_chunk=8))
    decode = jax.jit(serve_step.make_decode_step(cfg))
    sched = CohortScheduler(
        slots=1, max_len=32,
        prefill_fn=lambda p: prefill(params, p),
        decode_fn=lambda t, c, pos: decode(params, t, c, pos),
        sample_fn=lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
    sched.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    done = sched.run()
    np.testing.assert_array_equal(np.asarray(done[0].out),
                                  np.asarray(want[0]))
