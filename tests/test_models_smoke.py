"""Per-architecture smoke tests: reduced config, forward + one train step.

Required by the assignment: every arch instantiates a same-family reduced
config and runs one forward/train step on CPU asserting shapes + no NaNs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config
from repro.models import lm
from repro.train import train_step as ts
from repro.train.optimizer import OptConfig

RNG = np.random.default_rng(0)


def _frontend_kwargs(cfg, b):
    kw = {}
    if cfg.family == "audio":
        kw["enc_embeds"] = jnp.asarray(
            RNG.normal(size=(b, 16, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        kw["prefix_embeds"] = jnp.asarray(
            RNG.normal(size=(b, cfg.vlm_prefix, cfg.d_model)), jnp.float32)
    return kw


@pytest.mark.parametrize("arch", all_archs())
def test_forward_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_lm(jax.random.key(0), cfg)
    b, s = 2, 16
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32)
    logits, aux, _ = lm.forward(params, tokens, cfg, q_chunk=8, kv_chunk=8,
                                **_frontend_kwargs(cfg, b))
    s_out = s + (cfg.vlm_prefix if cfg.family == "vlm" else 0)
    assert logits.shape == (b, s_out, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", all_archs())
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    # warmup_steps=0 so lr(step 0) = peak (params must visibly move)
    tc = ts.TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=0,
                                      total_steps=10),
                        loss_chunk=8, q_chunk=8, kv_chunk=8)
    state = ts.init_train_state(jax.random.key(0), cfg, tc)
    step = ts.make_train_step(cfg, tc)
    b, s = 2, 16
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    batch.update(_frontend_kwargs(cfg, b))
    new_state, metrics = jax.jit(step)(state, batch)
    assert int(new_state["step"]) == 1
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: loss NaN"
    assert float(metrics["grad_norm"]) > 0, f"{arch}: zero gradients"
    # at least one parameter changed
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(new_state["params"])[0]
    assert not np.array_equal(np.asarray(before), np.asarray(after))


def test_grad_accumulation_matches_single_batch():
    cfg = dataclasses.replace(get_config("stablelm_3b", smoke=True),
                              param_dtype="float32")
    opt = OptConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    b, s = 4, 16
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    outs = {}
    for mb in (1, 2):
        tc = ts.TrainConfig(opt=opt, microbatches=mb, loss_chunk=8,
                            q_chunk=8, kv_chunk=8)
        state = ts.init_train_state(jax.random.key(0), cfg, tc)
        step = ts.make_train_step(cfg, tc)
        new_state, m = jax.jit(step)(state, batch)
        outs[mb] = new_state["params"]
    a = jax.tree.leaves(outs[1])
    bl = jax.tree.leaves(outs[2])
    for x, y in zip(a, bl):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-4, atol=2e-5)


def test_head_padding_plans_and_equivalence():
    """TP-divisibility padding (§Perf H1) is numerically exact."""
    import jax.numpy as jnp
    from repro.models import attention

    assert attention.head_padding_plan(64, 8, 16) is None      # divisible
    assert attention.head_padding_plan(36, 4, 1) is None       # no TP
    hp, kvp, slots = attention.head_padding_plan(36, 4, 16)
    assert hp % 16 == 0 and hp % kvp == 0 and len(set(slots.tolist())) == 36

    rng = np.random.default_rng(0)
    b, s, h, kv, dh = 2, 16, 6, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    base = attention.blockwise_attention(q, k, v, q_chunk=8, kv_chunk=8)
    plan = attention.head_padding_plan(h, kv, 4)
    qp, kp, vp = attention.pad_heads(q, k, v, plan)
    out = attention.unpad_heads(
        attention.blockwise_attention(qp, kp, vp, q_chunk=8, kv_chunk=8),
        plan)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
