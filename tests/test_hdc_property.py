"""Property-based tests (hypothesis) for HDC system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import HDSpace, bitops, encoder, item_memory


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_xor_binding_is_self_inverse(seed):
    """bind(bind(x, b), b) == x — XOR binding is an involution."""
    a = bitops.random_packed(jax.random.key(seed), (), 512)
    b = bitops.random_packed(jax.random.key(seed + 1), (), 512)
    back = jnp.bitwise_xor(jnp.bitwise_xor(a, b), b)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(a))


@given(st.integers(0, 500), st.integers(1, 15))
@settings(max_examples=20, deadline=None)
def test_permutation_preserves_distances(seed, k):
    a = bitops.random_packed(jax.random.key(seed), (), 512)
    b = bitops.random_packed(jax.random.key(seed + 7), (), 512)
    d0 = int(bitops.hamming_packed(a, b))
    d1 = int(bitops.hamming_packed(bitops.rho(a, k), bitops.rho(b, k)))
    assert d0 == d1


@given(st.integers(0, 200))
@settings(max_examples=15, deadline=None)
def test_random_vectors_are_quasi_orthogonal(seed):
    """Agreement of random HD vectors concentrates around D/2 (±5 sigma)."""
    dim = 4096
    a = bitops.random_packed(jax.random.key(seed), (), dim)
    b = bitops.random_packed(jax.random.key(seed + 1), (), dim)
    agree = dim - int(bitops.hamming_packed(a, b))
    sigma = (dim ** 0.5) / 2
    assert abs(agree - dim / 2) < 5 * sigma


@given(st.integers(0, 100), st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_encode_is_deterministic(seed, n):
    sp = HDSpace(dim=512, ngram=n)
    im = item_memory.make_item_memory(sp)
    tie = item_memory.make_tie_break(sp)
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, 4, (2, 20)), jnp.int32)
    lens = jnp.full((2,), 20, jnp.int32)
    h1 = encoder.encode(toks, lens, im, tie, sp)
    h2 = encoder.encode(toks, lens, im, tie, sp)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_similar_sequences_have_similar_encodings(seed):
    """One substitution moves the HD vector less than a fresh random read."""
    sp = HDSpace(dim=2048, ngram=6)
    im = item_memory.make_item_memory(sp)
    tie = item_memory.make_tie_break(sp)
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 4, 60)
    mut = base.copy()
    mut[30] = (mut[30] + 1) % 4
    rand = rng.integers(0, 4, 60)
    toks = jnp.asarray(np.stack([base, mut, rand]), jnp.int32)
    lens = jnp.full((3,), 60, jnp.int32)
    hv = encoder.encode(toks, lens, im, tie, sp)
    d_mut = int(bitops.hamming_packed(hv[0], hv[1]))
    d_rand = int(bitops.hamming_packed(hv[0], hv[2]))
    assert d_mut < d_rand


def test_bundle_majority_recovers_members():
    """A bundled vector stays closer to its members than to noise."""
    sp = HDSpace(dim=4096, ngram=4)
    im = item_memory.make_item_memory(sp)
    tie = item_memory.make_tie_break(sp)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 4, (1, 40)), jnp.int32)
    lens = jnp.full((1,), 40, jnp.int32)
    hv = encoder.encode(toks, lens, im, tie, sp)[0]
    im_rolled = item_memory.rolled(im, sp.ngram)
    grams = encoder.encode_grams(toks, im_rolled)[0]
    member_d = int(bitops.hamming_packed(hv, grams[0]))
    noise = bitops.random_packed(jax.random.key(5), (), sp.dim)
    noise_d = int(bitops.hamming_packed(hv, noise))
    assert member_d < noise_d
