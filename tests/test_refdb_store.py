"""The persistent RefDB store: versioned format, manifest, atomic write,
and the auto-rebuild contract (every defect reads as a cache miss)."""

import json
import pickle

import numpy as np
import pytest

from repro.core.hd_space import HDSpace
from repro.core.assoc_memory import RefDBBuilder, build_refdb
from repro.genomics import synth
from repro.pipeline import ProfilerConfig, ProfilingSession, refdb_store

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=3, genome_len=4_000, seed=7)


@pytest.fixture(scope="module")
def genomes():
    return synth.make_reference_genomes(SPEC)


@pytest.fixture(scope="module")
def db(genomes):
    return build_refdb(genomes, SP, window=1024)


def _assert_same_db(a, b):
    np.testing.assert_array_equal(np.asarray(a.prototypes),
                                  np.asarray(b.prototypes))
    np.testing.assert_array_equal(np.asarray(a.proto_species),
                                  np.asarray(b.proto_species))
    np.testing.assert_array_equal(np.asarray(a.genome_lengths),
                                  np.asarray(b.genome_lengths))
    assert a.num_species == b.num_species
    assert a.species_names == b.species_names


# -- roundtrip + manifest ---------------------------------------------------

def test_save_load_roundtrip(tmp_path, db):
    path = tmp_path / "refdb_x.npz"
    refdb_store.save(path, db, refdb_fingerprint="fp", genomes_digest="gd")
    back = refdb_store.load(path)
    assert back is not None
    _assert_same_db(back, db)


def test_manifest_fields(tmp_path, db):
    path = tmp_path / "refdb_x.npz"
    refdb_store.save(path, db, refdb_fingerprint="fp", genomes_digest="gd")
    m = refdb_store.manifest(path)
    assert m["format_version"] == refdb_store.FORMAT_VERSION
    assert m["refdb_fingerprint"] == "fp" and m["genomes_digest"] == "gd"
    assert m["num_species"] == db.num_species
    assert m["num_prototypes"] == db.prototypes.shape[0]
    assert m["dim_words"] == db.prototypes.shape[1]
    assert tuple(m["species_names"]) == db.species_names
    assert m["genome_lengths"] == [int(x) for x in
                                   np.asarray(db.genome_lengths)]


def test_atomic_write_leaves_no_partial_entry(tmp_path, db):
    """The published path appears only complete; staging files are temp-
    named so a reader can never open a half-written entry."""
    path = tmp_path / "refdb_x.npz"
    refdb_store.save(path, db)
    entries = [p.name for p in tmp_path.iterdir()]
    assert entries == ["refdb_x.npz"]           # no stray tmp files
    assert refdb_store.load(path) is not None
    refdb_store.save(path, db)                  # overwrite is atomic too
    assert refdb_store.load(path) is not None


# -- the auto-rebuild contract: every defect is a miss ----------------------

def test_load_missing_returns_none(tmp_path):
    assert refdb_store.load(tmp_path / "nope.npz") is None


def test_load_legacy_pickle_returns_none(tmp_path, db):
    """A pickle cache from before this format must read as a miss, not
    crash (the pre-PR cache files were raw pickles)."""
    path = tmp_path / "refdb_x.npz"
    path.write_bytes(pickle.dumps(db))
    assert refdb_store.load(path) is None
    assert refdb_store.manifest(path) is None


def test_load_truncated_returns_none(tmp_path, db):
    path = tmp_path / "refdb_x.npz"
    refdb_store.save(path, db)
    blob = path.read_bytes()
    path.write_bytes(blob[:len(blob) // 2])
    assert refdb_store.load(path) is None


def test_load_garbage_returns_none(tmp_path):
    path = tmp_path / "refdb_x.npz"
    path.write_bytes(b"not an archive at all")
    assert refdb_store.load(path) is None


def test_load_future_format_version_returns_none(tmp_path, db):
    import io
    path = tmp_path / "refdb_x.npz"
    refdb_store.save(path, db)
    with np.load(path) as z:
        m = json.loads(bytes(z["manifest"]).decode())
        arrays = {k: z[k] for k in z.files if k != "manifest"}
    m["format_version"] = refdb_store.FORMAT_VERSION + 1
    buf = io.BytesIO()
    np.savez(buf, manifest=np.frombuffer(
        json.dumps(m).encode(), dtype=np.uint8), **arrays)
    path.write_bytes(buf.getvalue())
    assert refdb_store.load(path) is None


def test_load_inconsistent_arrays_returns_none(tmp_path, db):
    """Arrays that disagree with their manifest (bit-rot, hand edits)
    must not load into a half-plausible RefDB."""
    import io
    path = tmp_path / "refdb_x.npz"
    refdb_store.save(path, db)
    with np.load(path) as z:
        m = bytes(z["manifest"])
        arrays = {k: z[k] for k in z.files if k != "manifest"}
    arrays["proto_species"] = arrays["proto_species"][:-1]   # truncate one
    buf = io.BytesIO()
    np.savez(buf, manifest=np.frombuffer(m, dtype=np.uint8), **arrays)
    path.write_bytes(buf.getvalue())
    assert refdb_store.load(path) is None


# -- concurrent hot-swap: publish racing load -------------------------------

def test_concurrent_load_during_publish(tmp_path, genomes, db):
    """A loader racing a publisher always sees a complete old-or-new
    version — never a partial read, never a spurious cache miss.

    This is the property the serving registry's hot-swap rests on:
    ``save`` stages to a temp file and ``os.replace``s into place, so
    every ``load`` observes exactly one fully-written snapshot.
    """
    import threading

    db_b = build_refdb({k: v for k, v in list(genomes.items())[:2]},
                       SP, window=1024)
    path = tmp_path / "refdb_hot.npz"
    refdb_store.save(path, db, refdb_fingerprint="a")
    stop = threading.Event()
    failures: list[str] = []

    def publisher():
        for i in range(30):
            new, fp = (db_b, "b") if i % 2 == 0 else (db, "a")
            refdb_store.save(path, new, refdb_fingerprint=fp)
        stop.set()

    def loader():
        while True:
            got = refdb_store.load(path)
            if got is None:                       # spurious miss
                failures.append("load returned None mid-publish")
                return
            if got.num_species == db.num_species:
                want = db
            elif got.num_species == db_b.num_species:
                want = db_b
            else:
                failures.append(f"torn read: {got.num_species} species")
                return
            try:
                _assert_same_db(got, want)
            except AssertionError as e:           # partial content
                failures.append(f"mixed versions: {e}")
                return
            if stop.is_set():
                return

    readers = [threading.Thread(target=loader) for _ in range(2)]
    writer = threading.Thread(target=publisher)
    for t in readers:
        t.start()
    writer.start()
    writer.join(120)
    for t in readers:
        t.join(120)
    assert not failures, failures[0]
    m = refdb_store.manifest(path)
    assert m["refdb_fingerprint"] in ("a", "b")   # last publish intact


# -- streaming build --------------------------------------------------------

def test_build_streaming_matches_build_refdb(tmp_path, genomes, db):
    seen = []
    builder = RefDBBuilder(SP, window=1024)
    path = tmp_path / "refdb_s.npz"
    out = refdb_store.build_streaming(
        genomes, builder, path=path,
        on_genome=lambda name, total: seen.append((name, total)))
    _assert_same_db(out, db)
    _assert_same_db(refdb_store.load(path), db)
    assert [n for n, _ in seen] == list(genomes)
    assert seen[-1][1] == db.prototypes.shape[0]    # monotone running total
    assert [t for _, t in seen] == sorted(t for _, t in seen)


def test_builder_rejects_duplicates_and_empty():
    builder = RefDBBuilder(SP, window=1024)
    with pytest.raises(ValueError, match="no genomes"):
        builder.finish()
    builder.add_genome("a", np.zeros(100, np.int32))
    with pytest.raises(ValueError, match="already added"):
        builder.add_genome("a", np.zeros(100, np.int32))


def test_builder_failed_add_leaves_state_clean(genomes):
    """A genome whose encode raises commits nothing: it can be retried,
    and finish() never books a species with zero prototype rows."""
    calls = {"n": 0}
    good_encode = RefDBBuilder(SP, window=1024)._encode

    def flaky(tokens, lengths):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("device fell over")
        return good_encode(tokens, lengths)

    builder = RefDBBuilder(SP, window=1024, encode_fn=flaky)
    name, toks = next(iter(genomes.items()))
    with pytest.raises(RuntimeError, match="fell over"):
        builder.add_genome(name, toks)
    builder.add_genome(name, toks)              # retry works: not "already added"
    db = builder.finish()
    assert db.num_species == 1
    assert db.species_names == (name,)
    assert (np.asarray(db.proto_species) == 0).all()


# -- session integration ----------------------------------------------------

def _config(**kw):
    kw.setdefault("space", SP)
    kw.setdefault("window", 1024)
    kw.setdefault("batch_size", 16)
    return ProfilerConfig(**kw)


def test_session_rebuilds_over_poisoned_cache(tmp_path, genomes):
    """A legacy-pickle (or corrupt) entry at the exact cache path triggers
    a clean rebuild that replaces it with a valid store entry."""
    s = ProfilingSession(_config())
    path = s.refdb_cache_path(tmp_path, genomes)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(pickle.dumps({"legacy": "pickle"}))
    db = s.build_or_load_refdb(genomes, cache_dir=tmp_path)
    assert not s.refdb_loaded_from_cache            # rebuilt, no crash
    assert refdb_store.load(path) is not None       # and repaired on disk
    s2 = ProfilingSession(_config())
    s2.build_or_load_refdb(genomes, cache_dir=tmp_path)
    assert s2.refdb_loaded_from_cache
    np.testing.assert_array_equal(np.asarray(s2.refdb.prototypes),
                                  np.asarray(db.prototypes))


def test_session_cache_entry_carries_provenance(tmp_path, genomes):
    s = ProfilingSession(_config())
    s.build_or_load_refdb(genomes, cache_dir=tmp_path)
    m = refdb_store.manifest(s.refdb_cache_file)
    assert m["refdb_fingerprint"] == s.config.refdb_fingerprint()
    assert m["genomes_digest"]                      # non-empty digest half
    # the content-determining config rides along, human-readable
    assert m["window"] == s.config.window
    assert m["stride"] == s.config.effective_stride
    assert m["space"]["dim"] == SP.dim
