"""Unified profiling API: backend registry parity, ProfilerConfig identity
and serialization, ReadSource streaming, the ProfilingSession facade, and
the legacy Demeter shim."""

import dataclasses
import pickle

import numpy as np
import pytest

from repro.core.hd_space import HDSpace
from repro.genomics import fasta, synth
from repro.pipeline import (ArraySource, FastqSource, IterableSource,
                            ProfilerConfig, ProfilingSession, SyntheticSource,
                            as_source, available_backends, prefetch,
                            resolve_backend)

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=4, genome_len=6_000, seed=11)


def _config(**kw):
    kw.setdefault("space", SP)
    kw.setdefault("window", 1024)
    kw.setdefault("batch_size", 16)
    return ProfilerConfig(**kw)


@pytest.fixture(scope="module")
def sample():
    return SyntheticSource(SPEC, num_reads=96, present=[0, 2])


# -- backend registry ------------------------------------------------------

def test_registry_names():
    assert {"reference", "reference_packed", "pallas_matmul",
            "pallas_packed", "pallas_fused", "pcm_sim",
            "sharded"} <= set(available_backends())


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        ProfilingSession(_config(backend="no_such_backend"))


def test_backend_parity_encode_and_agreement(sample):
    """Every registered backend matches the reference bit-exactly."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 4, (16, 60)).astype(np.int32)
    lens = np.full(16, 60, np.int32)
    ref = resolve_backend("reference", _config())
    q_ref = np.asarray(ref.encode(toks, lens))
    protos = q_ref[:7]  # any packed (S, W) array works as prototypes
    a_ref = np.asarray(ref.agreement(q_ref, protos))
    for name in available_backends():
        be = resolve_backend(name, _config(backend=name))
        np.testing.assert_array_equal(
            np.asarray(be.encode(toks, lens)), q_ref, err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(be.agreement(q_ref, protos)), a_ref, err_msg=name)


def test_profile_report_is_backend_invariant(sample):
    """Swapping the backend changes no ProfileReport field (acceptance)."""
    reports = {}
    for name in available_backends():
        s = ProfilingSession(_config(backend=name))
        s.build_refdb(sample.genomes)
        reports[name] = s.profile(sample)
    ref = reports["reference"]
    for name, rep in reports.items():
        for f in dataclasses.fields(rep):
            np.testing.assert_array_equal(
                np.asarray(getattr(rep, f.name)),
                np.asarray(getattr(ref, f.name)),
                err_msg=f"{name}.{f.name}")


# -- ProfilerConfig --------------------------------------------------------

def test_config_json_roundtrip():
    cfg = _config(stride=512, backend="pallas_packed")
    back = ProfilerConfig.from_json(cfg.to_json())
    assert back == cfg
    assert hash(back) == hash(cfg)          # frozen => jit-static usable
    assert back.fingerprint() == cfg.fingerprint()


def test_fingerprint_covers_every_field():
    base = _config()
    assert _config(stride=512).fingerprint() != base.fingerprint()
    assert _config(window=2048).fingerprint() != base.fingerprint()
    assert _config(batch_size=8).fingerprint() != base.fingerprint()
    assert _config(backend="pallas_matmul").fingerprint() != base.fingerprint()
    assert _config(space=HDSpace(dim=1024, ngram=5)).fingerprint() \
        != base.fingerprint()
    # stride=None is canonically stride=window: same database, same key
    assert _config(stride=1024).fingerprint() == base.fingerprint()


def test_refdb_fingerprint_covers_content_fields_only():
    """Cache key part: content fields change it, host/backend knobs don't."""
    base = _config()
    assert _config(stride=512).refdb_fingerprint() != base.refdb_fingerprint()
    assert _config(window=2048).refdb_fingerprint() != base.refdb_fingerprint()
    assert _config(space=HDSpace(dim=1024, ngram=5)).refdb_fingerprint() \
        != base.refdb_fingerprint()
    # batch_size and backend cannot change the prototypes (bit-exact twins)
    assert _config(batch_size=8).refdb_fingerprint() == base.refdb_fingerprint()
    assert _config(backend="pallas_matmul").refdb_fingerprint() \
        == base.refdb_fingerprint()


def test_cache_reused_across_backends(tmp_path, sample):
    """Switching to a bit-exact backend must hit, not rebuild, the cache."""
    s1 = ProfilingSession(_config())
    s1.build_or_load_refdb(sample.genomes, cache_dir=tmp_path)
    s2 = ProfilingSession(_config(backend="pallas_matmul", batch_size=32))
    db = s2.build_or_load_refdb(sample.genomes, cache_dir=tmp_path)
    assert s2.refdb_loaded_from_cache
    assert len(list(tmp_path.glob("refdb_*.npz"))) == 1
    np.testing.assert_array_equal(np.asarray(db.prototypes),
                                  np.asarray(s1.refdb.prototypes))


def test_accumulator_categories_match_classifier():
    """ProfileAccumulator rebinds the category encoding (import cycle keeps
    it from importing classifier); this pins the two definitions together."""
    from repro.core import classifier
    from repro.pipeline import ProfileAccumulator
    assert (ProfileAccumulator.UNMAPPED, ProfileAccumulator.UNIQUE,
            ProfileAccumulator.MULTI) == (classifier.UNMAPPED,
                                          classifier.UNIQUE, classifier.MULTI)


def test_config_validation():
    with pytest.raises(ValueError):
        _config(window=0)
    with pytest.raises(ValueError):
        _config(stride=0)
    with pytest.raises(ValueError):
        _config(batch_size=0)
    with pytest.raises(ValueError):
        _config(backend="")


def test_stride_gets_distinct_cache_entries(tmp_path, sample):
    """The stale-cache bug: configs differing only in stride must not
    share a RefDB cache entry."""
    s1 = ProfilingSession(_config())
    s2 = ProfilingSession(_config(stride=512))
    db1 = s1.build_or_load_refdb(sample.genomes, cache_dir=tmp_path)
    db2 = s2.build_or_load_refdb(sample.genomes, cache_dir=tmp_path)
    assert s1.refdb_cache_path(tmp_path, sample.genomes) \
        != s2.refdb_cache_path(tmp_path, sample.genomes)
    assert len(list(tmp_path.glob("refdb_*.npz"))) == 2
    # overlapping stride really does build a different database
    assert db2.num_prototypes > db1.num_prototypes
    # and the second call with an equal config loads from cache, bit-exact
    s3 = ProfilingSession(_config(stride=512))
    db3 = s3.build_or_load_refdb(sample.genomes, cache_dir=tmp_path)
    assert s3.refdb_loaded_from_cache
    np.testing.assert_array_equal(np.asarray(db3.prototypes),
                                  np.asarray(db2.prototypes))


def test_cache_key_ignores_genome_insertion_order(tmp_path, sample):
    """Regression: the same reference set in a different dict order must
    hit the same cache entry (the digest used to hash in iteration
    order, so a reordered FASTA rebuilt an identical database)."""
    s1 = ProfilingSession(_config())
    s1.build_or_load_refdb(sample.genomes, cache_dir=tmp_path)
    reordered = dict(reversed(list(sample.genomes.items())))
    assert list(reordered) != list(sample.genomes)
    s2 = ProfilingSession(_config())
    assert s2.refdb_cache_path(tmp_path, reordered) \
        == s1.refdb_cache_path(tmp_path, sample.genomes)
    db = s2.build_or_load_refdb(reordered, cache_dir=tmp_path)
    assert s2.refdb_loaded_from_cache
    assert len(list(tmp_path.glob("refdb_*.npz"))) == 1
    # the cached entry is self-describing: species order is the original
    # build's, recorded in species_names, so reports stay name-correct
    assert db.species_names == tuple(sample.genomes.keys())
    np.testing.assert_array_equal(np.asarray(db.prototypes),
                                  np.asarray(s1.refdb.prototypes))


def test_cache_key_covers_genome_content(tmp_path, sample):
    """Same config + different reference genomes must not share a cache
    entry (the config fingerprint alone cannot see the genomes)."""
    s = ProfilingSession(_config())
    s.build_or_load_refdb(sample.genomes, cache_dir=tmp_path)
    other = {k: v.copy() for k, v in sample.genomes.items()}
    next(iter(other.values()))[0] += 1  # one mutated base
    assert s.refdb_cache_path(tmp_path, sample.genomes) \
        != s.refdb_cache_path(tmp_path, other)
    s2 = ProfilingSession(_config())
    s2.build_or_load_refdb(other, cache_dir=tmp_path)
    assert not s2.refdb_loaded_from_cache
    assert len(list(tmp_path.glob("refdb_*.npz"))) == 2


# -- ReadSource ------------------------------------------------------------

def test_array_source_pads_tail():
    toks = np.arange(10 * 4, dtype=np.int32).reshape(10, 4)
    lens = np.full(10, 4, np.int32)
    batches = list(ArraySource(toks, lens).batches(4))
    assert [b.num_valid for b in batches] == [4, 4, 2]
    assert all(b.tokens.shape == (4, 4) for b in batches)
    assert batches[-1].lengths[2:].sum() == 0
    np.testing.assert_array_equal(
        np.concatenate([b.tokens[:b.num_valid] for b in batches]), toks)


def test_fastq_source_streams_file(tmp_path, sample):
    path = tmp_path / "reads.fastq"
    fasta.write_fastq(path, sample.tokens, sample.lengths)
    got = list(FastqSource(path, SPEC.read_len).batches(20))
    want = list(ArraySource(sample.tokens, sample.lengths).batches(20))
    assert [b.num_valid for b in got] == [b.num_valid for b in want]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.tokens, w.tokens)
        np.testing.assert_array_equal(g.lengths, w.lengths)


def test_as_source_coercions(sample):
    assert as_source(sample) is sample
    toks, lens = sample.tokens, sample.lengths
    assert isinstance(as_source((toks, lens)), ArraySource)
    it = as_source(iter([(toks[:8], lens[:8])]))
    assert isinstance(it, IterableSource)
    (batch,) = list(it.batches(999))
    assert batch.num_valid == 8          # pre-batched: size passes through
    with pytest.raises(TypeError):
        as_source(42)


def test_as_source_accepts_jax_and_list_pairs(sample):
    import jax.numpy as jnp
    toks, lens = sample.tokens[:8], sample.lengths[:8]
    src = as_source((jnp.asarray(toks), jnp.asarray(lens)))
    assert isinstance(src, ArraySource)
    np.testing.assert_array_equal(src.tokens, toks)
    src2 = as_source((toks.tolist(), lens.tolist()))
    assert isinstance(src2, ArraySource)
    with pytest.raises(TypeError, match="pre-batched"):
        as_source((toks, toks))          # (R, L) lengths: not a valid pair


def test_prefetch_preserves_order_and_errors():
    assert list(prefetch(iter(range(50)), depth=4)) == list(range(50))
    assert list(prefetch(iter(range(5)), depth=0)) == list(range(5))

    def boom():
        yield 1
        raise RuntimeError("producer failed")

    out = prefetch(boom(), depth=2)
    assert next(out) == 1
    with pytest.raises(RuntimeError, match="producer failed"):
        list(out)


def test_prefetch_releases_producer_when_abandoned():
    """Abandoning the stream mid-profile must not leave the producer
    thread blocked on the full queue (or its file handle open)."""
    import threading
    import time

    closed = []

    def endless():
        try:
            i = 0
            while True:
                yield i
                i += 1
        finally:
            closed.append(True)

    before = threading.active_count()
    out = prefetch(endless(), depth=1)
    assert next(out) == 0
    out.close()                          # consumer walks away
    deadline = time.monotonic() + 5.0
    while (threading.active_count() > before or not closed) \
            and time.monotonic() < deadline:
        time.sleep(0.01)
    assert threading.active_count() <= before
    assert closed == [True]              # source iterator was closed too


# -- ReadSource edge cases (the serving layer hits these) ------------------

def test_empty_fastq_yields_zero_read_report(tmp_path):
    """An empty sample file is a valid (empty) profiling request."""
    path = tmp_path / "empty.fastq"
    path.write_text("")
    genomes = synth.make_reference_genomes(SPEC)
    s = ProfilingSession(_config())
    s.build_refdb(genomes)
    rep = s.profile(FastqSource(path, SPEC.read_len))
    assert rep.total_reads == rep.unmapped_reads == rep.multi_reads == 0
    assert float(np.sum(rep.abundance)) == 0.0
    assert len(rep.top(3)) == 3                  # still well-formed


def test_fastq_trailing_blank_lines_add_no_phantom_reads(tmp_path, sample):
    """A trailing newline must not parse as a zero-length read."""
    path = tmp_path / "trail.fastq"
    fasta.write_fastq(path, sample.tokens[:5], sample.lengths[:5])
    with open(path, "a") as f:
        f.write("\n\n")
    toks, lens = fasta.read_fastq(path, SPEC.read_len)
    assert len(toks) == 5
    batches = list(FastqSource(path, SPEC.read_len).batches(4))
    assert sum(b.num_valid for b in batches) == 5


def test_final_partial_batch_profiles_cleanly(sample):
    """A read count not divisible by batch_size pads, never crashes, and
    padding rows never leak into the report."""
    s = ProfilingSession(_config())             # batch_size=16
    s.build_refdb(sample.genomes)
    n = 21                                      # 16 + 5-row partial tail
    rep = s.profile(ArraySource(sample.tokens[:n], sample.lengths[:n]))
    assert rep.total_reads == n
    full = s.profile(sample)
    assert full.total_reads == 96


# -- ProfileReport serialization -------------------------------------------

def test_profile_report_json_roundtrip(sample):
    s = ProfilingSession(_config())
    s.build_refdb(sample.genomes)
    rep = s.profile(sample)
    back = type(rep).from_json(rep.to_json(indent=2))
    for f in dataclasses.fields(rep):
        np.testing.assert_array_equal(np.asarray(getattr(back, f.name)),
                                      np.asarray(getattr(rep, f.name)),
                                      err_msg=f.name)
    assert back.species_names == rep.species_names
    assert back.to_json() == rep.to_json()


# -- ProfilingSession ------------------------------------------------------

def test_classify_batch_matches_profile_on_every_backend(sample):
    """The step-level primitive IS the profile() hot path: driving it by
    hand reproduces profile()'s accumulator inputs bit-exactly, for every
    registered backend."""
    from repro.pipeline import ProfileAccumulator
    for name in available_backends():
        s = ProfilingSession(_config(backend=name))
        db = s.build_refdb(sample.genomes)
        acc = ProfileAccumulator(db.num_species)
        for i, b in enumerate(sample.batches(s.config.batch_size)):
            res = s.classify_batch(b.tokens, b.lengths,
                                   num_valid=b.num_valid, index=i)
            assert res.index == i and res.num_valid == b.num_valid
            n = res.num_valid
            acc.add(np.asarray(res.classification.hits)[:n],
                    np.asarray(res.classification.category)[:n])
        manual = acc.finalize(np.asarray(db.genome_lengths),
                              db.species_names)
        assert manual.to_json() == s.profile(sample).to_json(), name


def test_session_requires_refdb(sample):
    s = ProfilingSession(_config())
    with pytest.raises(RuntimeError, match="no RefDB"):
        s.profile(sample)


def test_on_batch_callback_sees_every_batch(sample):
    s = ProfilingSession(_config())
    s.build_refdb(sample.genomes)
    seen = []
    rep = s.profile(sample, on_batch=lambda b: seen.append(b))
    assert [b.index for b in seen] == list(range(6))
    assert [b.num_valid for b in seen] == [16] * 6
    assert seen[0].queries.shape[0] == 16
    assert sum(b.num_valid for b in seen) == rep.total_reads == 96


def test_refdb_pickle_roundtrip_queries_identically(sample, tmp_path):
    s = ProfilingSession(_config())
    db = s.build_refdb(sample.genomes)
    db2 = pickle.loads(pickle.dumps(db))
    r1 = s.profile(sample, refdb=db)
    r2 = s.profile(sample, refdb=db2)
    np.testing.assert_array_equal(r1.abundance, r2.abundance)


# -- legacy shim (retired) ---------------------------------------------------

def test_retired_demeter_shim_raises_with_migration_pointer():
    from repro.core import Demeter, batch_reads
    with pytest.raises(RuntimeError, match="ProfilingSession"):
        Demeter(SP, window=1024, batch_size=16)
    with pytest.raises(RuntimeError, match="ReadSource"):
        batch_reads(np.zeros((4, 8), np.int32), np.full(4, 8, np.int32), 2)
    # the old import path for reports still resolves to the real class
    from repro.core.profiler import ProfileReport
    from repro.pipeline.report import ProfileReport as Canonical
    assert ProfileReport is Canonical
