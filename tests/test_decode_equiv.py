"""Serving correctness: prefill + incremental decode == full forward (fp32).

Covers: MLA absorbed decode (deepseek-v2-lite), MoE routing at batch-1
groups (phi3.5), SSD recurrence (mamba2), hybrid SWA ring buffers (hymba),
cross-attention caches (whisper), prefix-LM (paligemma), GQA/MHA dense.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config
from repro.models import lm

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("arch", all_archs())
def test_prefill_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              param_dtype="float32")
    params = lm.init_lm(jax.random.key(0), cfg)
    b, s, extra, max_len = 2, 12, 4, 32
    kw = {}
    if cfg.family == "audio":
        kw["enc_embeds"] = jnp.asarray(
            RNG.normal(size=(b, 16, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        kw["prefix_embeds"] = jnp.asarray(
            RNG.normal(size=(b, cfg.vlm_prefix, cfg.d_model)), jnp.float32)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (b, s + extra)),
                         jnp.int32)

    logits_full, _, _ = lm.forward(params, tokens, cfg, q_chunk=8,
                                   kv_chunk=8, remat=False, **kw)
    logits_pre, caches, s0 = lm.prefill(params, tokens[:, :s], cfg, max_len,
                                        q_chunk=8, kv_chunk=8, **kw)
    np.testing.assert_allclose(
        np.asarray(logits_pre),
        np.asarray(logits_full[:, :logits_pre.shape[1]]),
        rtol=1e-4, atol=1e-4)

    for i in range(extra):
        pos = jnp.int32(s0 + i)
        logit_i, caches = lm.decode_step(params, tokens[:, s + i], caches,
                                         pos, cfg)
        want = logits_full[:, s0 + i]
        np.testing.assert_allclose(np.asarray(logit_i), np.asarray(want),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"{arch} step {i}")


def test_swa_ring_buffer_wraps_correctly():
    """Decode far past the window: ring slots must stay coherent."""
    cfg = dataclasses.replace(get_config("hymba_1_5b", smoke=True),
                              param_dtype="float32")
    params = lm.init_lm(jax.random.key(1), cfg)
    b, total = 1, 28          # window is 8 in the smoke config
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (b, total)), jnp.int32)
    logits_full, _, _ = lm.forward(params, tokens, cfg, q_chunk=8,
                                   kv_chunk=8, remat=False)
    s = 4
    _, caches, s0 = lm.prefill(params, tokens[:, :s], cfg, total,
                               q_chunk=8, kv_chunk=8)
    for i in range(total - s - 1):
        logit_i, caches = lm.decode_step(params, tokens[:, s + i], caches,
                                         jnp.int32(s + i), cfg)
        np.testing.assert_allclose(np.asarray(logit_i),
                                   np.asarray(logits_full[:, s + i]),
                                   rtol=5e-3, atol=5e-3,
                                   err_msg=f"pos {s + i}")


def test_generate_runs_greedy():
    from repro.serve import serve_step
    cfg = get_config("stablelm_3b", smoke=True)
    params = lm.init_lm(jax.random.key(0), cfg)
    prompt = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    out = serve_step.generate(params, prompt, cfg, steps=4, max_len=16,
                              q_chunk=8, kv_chunk=8)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab).all()
