"""ProfilingService: multi-tenant serving over one shared RefDB.

The load-bearing contract (ISSUE 3 acceptance): per-request reports from
>= 8 concurrent requests are bit-identical to sequential
``ProfilingSession.profile()`` runs of the same reads, for the
``reference`` and ``pallas_matmul`` backends.  Plus lifecycle coverage:
streaming snapshots, cancellation, backpressure, per-request failure
isolation, mixed read lengths (cohort bucketing), zero-read requests.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession,
                            SyntheticSource)
from repro.serve import (ProfileRequest, ProfilingService, RequestState,
                         ServiceOverloaded)

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=4, genome_len=6_000, seed=11)


def _config(**kw):
    kw.setdefault("space", SP)
    kw.setdefault("window", 1024)
    kw.setdefault("batch_size", 16)
    return ProfilerConfig(**kw)


@pytest.fixture(scope="module")
def sample():
    return SyntheticSource(SPEC, num_reads=192, present=[0, 2])


@pytest.fixture(scope="module")
def refdb(sample):
    return ProfilingSession(_config()).build_refdb(sample.genomes)


def _session(refdb, **kw):
    s = ProfilingSession(_config(**kw))
    s.refdb = refdb          # every backend shares the one database
    return s


def _slices(sample, n):
    """n disjoint read slices, each its own request source."""
    return [ArraySource(sample.tokens[i::n], sample.lengths[i::n])
            for i in range(n)]


# -- acceptance: concurrent == sequential, bit for bit ---------------------

@pytest.mark.parametrize("backend", ["reference", "pallas_matmul"])
def test_concurrent_requests_match_sequential(sample, refdb, backend):
    session = _session(refdb, backend=backend)
    sources = _slices(sample, 8)
    sequential = [session.profile(src) for src in sources]

    service = ProfilingService(session, max_active=8)
    handles = [service.submit(src) for src in sources]
    service.run_until_idle()
    for h, want in zip(handles, sequential):
        assert h.state is RequestState.DONE
        got = h.result(timeout=0)
        assert got.to_json() == want.to_json()      # full-field bit equality
        np.testing.assert_array_equal(got.abundance, want.abundance)


def test_mixed_read_lengths_bucket_into_shared_cohorts(sample, refdb):
    """Requests with different read widths interleave via length buckets."""
    session = _session(refdb)
    short = ArraySource(sample.tokens[:40, :64],
                        np.minimum(sample.lengths[:40], 64))
    long = ArraySource(sample.tokens[40:80], sample.lengths[40:80])
    want = [session.profile(short), session.profile(long)]

    service = ProfilingService(session, max_active=2, buckets=(64, 256))
    hs = [service.submit(short), service.submit(long)]
    service.run_until_idle()
    for h, w in zip(hs, want):
        assert h.result(timeout=0).to_json() == w.to_json()


# -- lifecycle -------------------------------------------------------------

def test_streaming_snapshots_grow_to_final(sample, refdb):
    session = _session(refdb)
    src = ArraySource(sample.tokens, sample.lengths)
    service = ProfilingService(session, max_active=1)
    h = service.submit(ProfileRequest(source=src, request_id="stream-me"))
    assert h.request_id == "stream-me"
    assert h.snapshot().total_reads == 0            # queued: empty report

    counts = []
    while service.step():
        counts.append(h.snapshot().total_reads)
    assert counts == sorted(counts)                 # monotone growth
    assert h.state is RequestState.DONE
    final = h.result(timeout=0)
    assert final.total_reads == len(sample.tokens)
    assert final.to_json() == h.snapshot().to_json()


def test_cancellation_mid_stream(sample, refdb):
    session = _session(refdb)
    sources = _slices(sample, 2)
    want = session.profile(sources[0])
    service = ProfilingService(session, max_active=2)
    keep, kill = (service.submit(s) for s in sources)
    service.step()                                  # first cohort only
    assert kill.cancel()
    assert not kill.cancel()                        # idempotent: already dead
    service.run_until_idle()
    assert kill.state is RequestState.CANCELLED
    with pytest.raises(RuntimeError, match="cancelled"):
        kill.result(timeout=0)
    # the surviving request is unaffected, still bit-exact
    assert keep.result(timeout=0).to_json() == want.to_json()


def test_backpressure_bounds_admission(sample, refdb):
    service = ProfilingService(_session(refdb), max_active=2, max_queue=1)
    srcs = _slices(sample, 4)
    for s in srcs[:3]:                              # 2 active + 1 queued
        service.submit(s)
    with pytest.raises(ServiceOverloaded, match="admission queue full"):
        service.submit(srcs[3])
    with pytest.raises(TimeoutError):
        service.submit(srcs[3], block=True, timeout=0.05)


def test_blocking_submit_admits_once_capacity_frees(sample, refdb):
    service = ProfilingService(_session(refdb), max_active=1, max_queue=0)
    srcs = _slices(sample, 2)
    first = service.submit(srcs[0])
    got = {}

    def late_submit():
        got["h"] = service.submit(srcs[1], block=True, timeout=10)

    t = threading.Thread(target=late_submit)
    t.start()
    service.run_until_idle()                        # finishes first -> slot
    t.join(timeout=10)
    assert not t.is_alive() and "h" in got
    service.run_until_idle()
    assert first.state is got["h"].state is RequestState.DONE


def test_zero_read_request_completes_with_empty_report(sample, refdb):
    service = ProfilingService(_session(refdb), max_active=2)
    empty = ArraySource(np.empty((0, 150), np.int32), np.empty(0, np.int32))
    h = service.submit(empty)
    service.run_until_idle()
    rep = h.result(timeout=0)
    assert h.state is RequestState.DONE
    assert rep.total_reads == 0
    assert float(np.sum(rep.abundance)) == 0.0


def test_source_failure_is_isolated(sample, refdb):
    class Boom(ArraySource):
        def batches(self, batch_size):
            yield from super().batches(batch_size)
            raise OSError("disk vanished")

    session = _session(refdb)
    good_src = ArraySource(sample.tokens[:48], sample.lengths[:48])
    want = session.profile(good_src)
    service = ProfilingService(session, max_active=2)
    bad = service.submit(Boom(sample.tokens[48:96], sample.lengths[48:96]))
    good = service.submit(good_src)
    service.run_until_idle()
    assert bad.state is RequestState.FAILED
    with pytest.raises(OSError, match="disk vanished"):
        bad.result(timeout=0)
    assert good.result(timeout=0).to_json() == want.to_json()


def test_background_worker_serves_submissions(sample, refdb):
    session = _session(refdb)
    sources = _slices(sample, 4)
    sequential = [session.profile(s) for s in sources]
    with ProfilingService(session, max_active=2) as service:
        handles = [service.submit(s, block=True, timeout=30)
                   for s in sources]
        reports = [h.result(timeout=60) for h in handles]
    for got, want in zip(reports, sequential):
        assert got.to_json() == want.to_json()


def test_oversize_read_fails_only_its_request(sample, refdb):
    """A read longer than the largest bucket is that tenant's problem."""
    session = _session(refdb)
    good_src = ArraySource(sample.tokens[:48, :60],
                           np.minimum(sample.lengths[:48], 60))
    want = session.profile(good_src)
    service = ProfilingService(session, max_active=2, buckets=(64,))
    giant = service.submit(ArraySource(
        np.zeros((3, 500), np.int32), np.full(3, 500, np.int32)))
    good = service.submit(good_src)
    service.run_until_idle()
    assert giant.state is RequestState.FAILED
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        giant.result(timeout=0)
    assert good.result(timeout=0).to_json() == want.to_json()


def test_worker_death_fails_live_requests(sample, refdb):
    session = _session(refdb)

    def boom(*a, **kw):
        raise RuntimeError("backend exploded")

    session.classify_batch = boom
    service = ProfilingService(session, max_active=2).start()
    try:
        h = service.submit(ArraySource(sample.tokens[:32],
                                       sample.lengths[:32]))
        with pytest.raises(RuntimeError, match="backend exploded"):
            h.result(timeout=30)
        assert h.state is RequestState.FAILED
        # the dead service refuses new work instead of black-holing it
        deadline = time.monotonic() + 10
        while service.error is None and time.monotonic() < deadline:
            time.sleep(0.01)
        with pytest.raises(RuntimeError, match="worker died"):
            service.submit(ArraySource(sample.tokens[:8],
                                       sample.lengths[:8]))
    finally:
        service.stop(timeout=5)


def test_submit_request_id_precedence(sample, refdb):
    service = ProfilingService(_session(refdb))
    src = ArraySource(sample.tokens[:8], sample.lengths[:8])
    a = service.submit(ProfileRequest(source=src, request_id="inner"),
                       request_id="outer")
    b = service.submit(ProfileRequest(source=src), request_id="outer")
    c = service.submit(ProfileRequest(source=src))
    assert (a.request_id, b.request_id) == ("inner", "outer")
    assert c.request_id.startswith("req-")
    service.run_until_idle()


def test_service_requires_refdb():
    with pytest.raises(ValueError, match="no RefDB"):
        ProfilingService(ProfilingSession(_config()))
