"""End-to-end training: loss decreases on structured synthetic data."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import lm_data
from repro.train import train_step as ts
from repro.train.optimizer import OptConfig, lr_at


def test_lr_schedule_shape():
    oc = OptConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                   min_lr_frac=0.1)
    assert float(lr_at(jnp.int32(0), oc)) == 0.0
    assert abs(float(lr_at(jnp.int32(10), oc)) - 1e-3) < 1e-9
    assert float(lr_at(jnp.int32(55), oc)) < 1e-3
    assert float(lr_at(jnp.int32(100), oc)) >= 0.1e-3 - 1e-9


def test_loss_decreases_on_structured_data():
    cfg = dataclasses.replace(get_config("stablelm_3b", smoke=True),
                              vocab=64, n_layers=2, param_dtype="float32")
    dc = lm_data.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8,
                            seed=1)
    tc = ts.TrainConfig(opt=OptConfig(peak_lr=1e-2, warmup_steps=5,
                                      total_steps=100, weight_decay=0.0),
                        loss_chunk=32, q_chunk=32, kv_chunk=32, z_loss=0.0)
    state = ts.init_train_state(jax.random.key(0), cfg, tc)
    step = jax.jit(ts.make_train_step(cfg, tc))
    losses = []
    for i in range(100):
        batch = jax.tree.map(jnp.asarray, lm_data.batch_at(dc, i))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first * 0.85, f"no learning: {first:.3f} -> {last:.3f}"
    assert np.isfinite(losses).all()


def test_data_pipeline_deterministic():
    dc = lm_data.DataConfig(vocab=64, seq_len=16, global_batch=4, seed=9)
    b1, b2 = lm_data.batch_at(dc, 123), lm_data.batch_at(dc, 123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = lm_data.batch_at(dc, 124)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
