"""Packed-bit substrate: pack/unpack, popcount, the word-roll permutation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops


@pytest.mark.parametrize("seed,dim", [(0, 32), (1, 64), (7, 96), (42, 128),
                                      (123, 192), (2**31, 256)])
def test_pack_unpack_roundtrip(seed, dim):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (3, dim)).astype(np.uint8)
    packed = bitops.pack_bits(jnp.asarray(bits))
    assert packed.dtype == jnp.uint32
    back = bitops.unpack_bits(packed)
    np.testing.assert_array_equal(np.asarray(back), bits)


def test_popcount_matches_numpy():
    rng = np.random.default_rng(0)
    w = rng.integers(0, 2**32, (5, 16), dtype=np.uint32)
    got = np.asarray(bitops.popcount_words(jnp.asarray(w)))
    want = np.array([bin(int(x)).count("1") for x in w.reshape(-1)]
                    ).reshape(5, 16).sum(-1)
    np.testing.assert_array_equal(got, want)


def test_rho_is_32bit_roll_in_bitspace():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.integers(0, 2**32, (8,), dtype=np.uint32))
    rolled = bitops.rho(w, 1)
    bits = np.asarray(bitops.unpack_bits(w))
    want = np.roll(bits, 32)
    np.testing.assert_array_equal(np.asarray(bitops.unpack_bits(rolled)), want)


def test_rho_preserves_hamming_distance():
    key = jax.random.key(0)
    a = bitops.random_packed(key, (4,), 512)
    b = bitops.random_packed(jax.random.key(1), (4,), 512)
    d0 = bitops.hamming_packed(a, b)
    d1 = bitops.hamming_packed(bitops.rho(a, 3), bitops.rho(b, 3))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_random_packed_density():
    v = bitops.random_packed(jax.random.key(0), (16,), 4096, density=0.25)
    frac = float(bitops.popcount_words(v).sum()) / (16 * 4096)
    assert 0.22 < frac < 0.28


def test_dim_must_be_multiple_of_32():
    with pytest.raises(ValueError):
        bitops.num_words(100)
