"""Multi-device mesh tests, each in a subprocess with its own device count.

The main pytest process stays at 1 CPU device (per assignment: smoke tests
see 1 device); these scenarios need 8 host devices, so they run via
``python -c`` with XLA_FLAGS set only in the child environment.
"""

import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run(snippet: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_pjit_train_step_matches_single_device():
    _run("""
import dataclasses, jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed import param_specs, sharding
from repro.train import train_step as ts
cfg = dataclasses.replace(get_config('stablelm_3b', smoke=True), param_dtype='float32')
tc = ts.TrainConfig(loss_chunk=8, q_chunk=8, kv_chunk=8)
rng = np.random.default_rng(0)
batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab, (8,16)), jnp.int32),
         'labels': jnp.asarray(rng.integers(0, cfg.vocab, (8,16)), jnp.int32)}
state = ts.init_train_state(jax.random.key(0), cfg, tc)
step = ts.make_train_step(cfg, tc)
_, m1 = jax.jit(step)(jax.tree.map(lambda x: x, state), batch)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
rules = sharding.TRAIN_RULES
with sharding.use_rules(mesh, rules):
    st_sh = param_specs.state_shardings(state, mesh, rules)
    b_sh = param_specs.batch_shardings(batch, mesh, rules)
    st = jax.device_put(state, st_sh); bt = jax.device_put(batch, b_sh)
    _, m2 = jax.jit(step, in_shardings=(st_sh, b_sh))(st, bt)
d = abs(float(m1['loss']) - float(m2['loss'])) / abs(float(m1['loss']))
assert d < 1e-3, (float(m1['loss']), float(m2['loss']))
print('pjit parity OK', d)
""")


def test_decode_step_under_decode_rules():
    """Seq-sharded KV cache decode lowers, runs, and matches 1-device."""
    _run("""
import dataclasses, jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed import param_specs, sharding
from repro.models import lm
cfg = dataclasses.replace(get_config('deepseek_67b', smoke=True), param_dtype='float32')
params = lm.init_lm(jax.random.key(0), cfg)
rng = np.random.default_rng(0)
tok = jnp.asarray(rng.integers(0, cfg.vocab, (4,)), jnp.int32)
caches = lm.init_cache(cfg, 4, 32, dtype=jnp.float32)
logits1, _ = lm.decode_step(params, tok, caches, jnp.int32(0), cfg)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
rules = sharding.DECODE_RULES
with sharding.use_rules(mesh, rules):
    p_sh = param_specs.param_shardings(params, mesh, rules)
    c_sh = param_specs.cache_shardings(caches, mesh, rules)
    f = jax.jit(lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg),
                in_shardings=(p_sh, None, c_sh, None))
    logits2, _ = f(jax.device_put(params, p_sh), tok,
                   jax.device_put(caches, c_sh), jnp.int32(0))
np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2),
                           rtol=2e-3, atol=2e-3)
print('decode parity OK')
""")


def test_pipeline_and_compressed_psum():
    _run("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed import pipeline as pp
from repro.train import compression as comp
mesh = jax.make_mesh((4, 2), ('pod', 'data'))
rng = np.random.default_rng(0)
params = jnp.asarray(rng.normal(size=(4, 16, 16)) * 0.1, jnp.float32)
x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
got = pp.pipelined_apply(params, x, lambda w, xb: jnp.tanh(xb @ w),
                         mesh=mesh, axis='pod', num_microbatches=4)
want = x
for s in range(4):
    want = jnp.tanh(want @ params[s])
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

mesh2 = jax.make_mesh((8,), ('data',))
g = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
est = comp.init_state({'w': jnp.zeros((16,))})
def f(gl):
    out, _ = comp.compressed_psum({'w': gl[0]}, est, 'data')
    return out['w']
from repro.distributed.sharding import shard_map_compat
got = jax.jit(shard_map_compat(f, mesh=mesh2, in_specs=P('data'),
                               out_specs=P()))(g)
np.testing.assert_allclose(np.asarray(got), np.asarray(g.mean(0)), atol=0.02)
print('pipeline + compressed psum OK')
""")


def test_hdc_profiler_sharded():
    """Demeter classification under pjit: reads over data, D over model."""
    _run("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import HDSpace, bitops
from repro.pipeline import ProfilerConfig, ProfilingSession
sp = HDSpace(dim=2048, ngram=8, z_threshold=3.0)
dm = ProfilingSession(ProfilerConfig(space=sp, window=1024, batch_size=32))
rng = np.random.default_rng(0)
genomes = {f's{i}': rng.integers(0, 4, 8000).astype(np.int32) for i in range(4)}
db = dm.build_refdb(genomes)
toks = jnp.asarray(rng.integers(0, 4, (32, 64)), jnp.int32)
lens = jnp.full((32,), 64, jnp.int32)
q = dm.encode_reads(toks, lens)
res1 = dm.classify_queries(q, db)
mesh = jax.make_mesh((4, 2), ('data', 'model'))
qs = jax.device_put(q, NamedSharding(mesh, P('data', 'model')))
res2 = dm.classify_queries(qs, db)
np.testing.assert_array_equal(np.asarray(res1.scores), np.asarray(res2.scores))
print('sharded HDC classify OK')
""")
