"""`pallas_fused` megakernel: bit-exactness against `reference` on every
entry point (kernel, backend, session, sharded wrapping, ProfilingService
interleaving), odd-shape coverage, and the friendly tile-size validation."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import assoc_memory, encoder, item_memory
from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.kernels import ops
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession,
                            SyntheticSource, available_backends,
                            resolve_backend)

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=4, genome_len=6_000, seed=11)


def _config(**kw):
    kw.setdefault("space", SP)
    kw.setdefault("window", 1024)
    kw.setdefault("batch_size", 16)
    kw.setdefault("backend", "pallas_fused")
    return ProfilerConfig(**kw)


@pytest.fixture(scope="module")
def sample():
    return SyntheticSource(SPEC, num_reads=64, present=[0, 2])


def _reference_agreement(space, toks, lens, protos):
    import jax.numpy as jnp
    im = item_memory.make_item_memory(space)
    tie = item_memory.make_tie_break(space)
    q = encoder.encode(jnp.asarray(toks), jnp.asarray(lens), im, tie, space)
    return np.asarray(assoc_memory.agreement_matmul(
        q, jnp.asarray(protos), space.dim))


def _fused_agreement(space, toks, lens, protos, **tiles):
    import jax.numpy as jnp
    im = item_memory.make_item_memory(space)
    tie = item_memory.make_tie_break(space)
    return np.asarray(ops.fused_agreement(
        jnp.asarray(toks), jnp.asarray(lens), im, tie,
        jnp.asarray(protos), space, **tiles))


# -- kernel-level parity on odd shapes --------------------------------------

@pytest.mark.parametrize("dim,ngram,b,length,s,tiles", [
    (512, 5, 16, 60, 7, {}),                      # plain
    (1056, 8, 4, 50, 5, {"bw": 8}),               # W=33: dim not a multiple
                                                  # of the word tile
    (512, 8, 1, 40, 3, {}),                       # batch of 1
    (512, 8, 5, 6, 9, {}),                        # reads shorter than ngram
    (2048, 16, 12, 150, 300, {"bs": 128}),        # prototype-axis chunking
    (512, 5, 16, 60, 7, {"bb": 4, "bw": 4}),      # tiny tiles
    (512, 5, 8, 40, 387, {"bs": 128}),            # odd S, multi-chunk grid:
                                                  # S % bs != 0, pad-once
    (512, 5, 8, 40, 129, {"bs": 256}),            # bs re-balanced below ask
    (1056, 8, 4, 50, 260, {"bw": 8, "bs": 128}),  # odd S x odd word tile
])
def test_fused_kernel_matches_reference(dim, ngram, b, length, s, tiles):
    space = HDSpace(dim=dim, ngram=ngram, z_threshold=3.0)
    rng = np.random.default_rng(7)
    toks = rng.integers(0, 4, (b, length)).astype(np.int32)
    lens = rng.integers(0, length + 1, b).astype(np.int32)
    protos = np.asarray(item_memory.make_item_memory(space))  # any packed
    protos = np.tile(protos, (s // len(protos) + 1, 1))[:s]
    np.testing.assert_array_equal(
        _fused_agreement(space, toks, lens, protos, **tiles),
        _reference_agreement(space, toks, lens, protos))


def test_fused_double_buffer_path_matches_reference():
    """The manual-DMA double-buffered prototype stream is bit-exact too
    (interpret mode executes the async copies synchronously)."""
    space = HDSpace(dim=512, ngram=5, z_threshold=3.0)
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 4, (8, 50)).astype(np.int32)
    lens = rng.integers(0, 51, 8).astype(np.int32)
    protos = np.asarray(item_memory.make_item_memory(space))
    protos = np.tile(protos, (80, 1))[:300]
    np.testing.assert_array_equal(
        _fused_agreement(space, toks, lens, protos, bs=128,
                         double_buffer=True),
        _reference_agreement(space, toks, lens, protos))


def test_fused_tile_plan_pads_once():
    """Regression for the old per-chunk 128-row pad: an odd S is padded
    once to the chunk grid, wasting less than one chunk in total."""
    plan = ops.fused_tile_plan(16, 387, 16, bs=129)
    assert plan["bs"] % 128 == 0
    assert plan["s_pad"] == plan["n_chunks"] * plan["bs"]
    assert plan["s_pad"] - 387 < plan["bs"]
    # tiny bs requests are clamped, not allowed to explode the pad
    plan = ops.fused_tile_plan(16, 300, 16, bs=8)
    assert plan["bs"] >= 128 and plan["s_pad"] - 300 < plan["bs"]


# -- backend + session ------------------------------------------------------

def test_fused_backend_registered():
    assert "pallas_fused" in available_backends()


def test_fused_profile_matches_reference(sample):
    ref = ProfilingSession(_config(backend="reference"))
    ref.build_refdb(sample.genomes)
    fused = ProfilingSession(_config())
    fused.build_refdb(sample.genomes)
    assert fused.profile(sample).to_json() == ref.profile(sample).to_json()


def test_fused_batchresult_has_no_queries(sample):
    """The fusion's whole point: the encoded matrix is never materialized,
    so the per-batch callback sees ``queries=None``."""
    s = ProfilingSession(_config())
    s.build_refdb(sample.genomes)
    seen = []
    s.profile(sample, on_batch=seen.append)
    assert seen and all(b.queries is None for b in seen)
    assert sum(b.num_valid for b in seen) == 64


def test_fused_partial_tail_batch(sample):
    """A read count not divisible by batch_size (nor the batch tile)."""
    ref = ProfilingSession(_config(backend="reference"))
    ref.build_refdb(sample.genomes)
    s = ProfilingSession(_config())
    s.build_refdb(sample.genomes)
    src = ArraySource(sample.tokens[:21], sample.lengths[:21])
    assert s.profile(src).to_json() == ref.profile(src).to_json()


def test_fused_tile_options_through_config(sample):
    """Non-default tiles change nothing but the schedule."""
    ref = ProfilingSession(_config(backend="reference"))
    ref.build_refdb(sample.genomes)
    s = ProfilingSession(_config(backend_options={"bb": 4, "bw": 4,
                                                  "bs": 128}))
    s.build_refdb(sample.genomes)
    assert s.profile(sample).to_json() == ref.profile(sample).to_json()


# -- sharded wrapping -------------------------------------------------------

def test_fused_under_sharded_wrapping(sample):
    ref = ProfilingSession(_config(backend="reference"))
    ref.build_refdb(sample.genomes)
    s = ProfilingSession(_config(backend="sharded",
                                 backend_options={"base": "pallas_fused"}))
    be = s.backend
    assert getattr(be, "tokens_agreement", None) is not None
    assert getattr(be, "tokens_species_scores", None) is not None
    s.build_refdb(sample.genomes)
    assert s.profile(sample).to_json() == ref.profile(sample).to_json()


def test_sharded_over_unfused_base_exposes_no_tokens_capability():
    s = ProfilingSession(_config(backend="sharded",
                                 backend_options={"base": "reference"}))
    assert getattr(s.backend, "tokens_agreement", None) is None
    assert getattr(s.backend, "tokens_species_scores", None) is None


# -- ProfilingService interleaving ------------------------------------------

def test_fused_through_profiling_service(sample):
    """Two interleaved requests over the fused backend produce reports
    bit-identical to sequential ``session.profile`` runs."""
    from repro.serve.profiler_service import ProfilingService

    s = ProfilingSession(_config(batch_size=8))
    s.build_refdb(sample.genomes)
    a = ArraySource(sample.tokens[:40], sample.lengths[:40])
    b = ArraySource(sample.tokens[40:], sample.lengths[40:])
    service = ProfilingService(s, max_active=2)
    ha, hb = service.submit(a), service.submit(b)
    service.run_until_idle()
    assert ha.result(timeout=60).to_json() == s.profile(a).to_json()
    assert hb.result(timeout=60).to_json() == s.profile(b).to_json()


# -- option validation (bugfix satellite) -----------------------------------

@pytest.mark.parametrize("options,match", [
    ({"bb": 3}, "power of two"),
    ({"bb": 0}, "positive int"),
    ({"bw": -1}, "positive int"),
    ({"bs": 0}, "positive int"),
    ({"bb": True}, "must be an integer"),
    ({"bw": "wide"}, "must be an integer"),
    ({"block": 64}, "unknown option"),
    ({"bs": 100}, "multiple of 128"),
    ({"bb": 64}, "padded batch"),          # config batch_size=16 pads to 16
    ({"autotune": 1}, "must be a bool"),
    ({"autotune_cache": ""}, "non-empty path"),
])
def test_fused_tile_validation_is_friendly(options, match):
    """Bad tile sizes fail at session construction with a ValueError —
    never a Pallas shape crash mid-profile."""
    with pytest.raises(ValueError, match=match):
        ProfilingSession(_config(backend_options=options))


def test_fused_explicit_tiles_override_autotune(sample):
    """autotune=true plus explicit tiles: explicit wins, warned once."""
    from repro.pipeline import fused as fused_mod

    fused_mod._warned_autotune_override = False
    with pytest.warns(UserWarning, match="override autotune"):
        s = ProfilingSession(_config(
            backend_options={"autotune": True, "bb": 4}))
    assert s.backend._autotune is False
    assert s.backend.tiles["bb"] == 4
    # second construction: same override, no second warning
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        ProfilingSession(_config(backend_options={"autotune": True,
                                                  "bb": 4}))


# -- registry completeness (bugfix satellite) --------------------------------

def test_backends_visible_without_package_import():
    """`--list-backends` and the unknown-backend error must include every
    backend even when only `repro.pipeline.backend` was imported (the
    lazily-registered entry points)."""
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.pipeline.backend import available_backends\n"
         "print(','.join(available_backends()))"],
        env={**os.environ,
             "PYTHONPATH": os.pathsep.join(
                 filter(None, ["src", os.environ.get("PYTHONPATH")]))},
        capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    names = set(out.stdout.strip().split(","))
    assert {"pallas_fused", "pcm_sim", "sharded"} <= names


def test_unknown_backend_error_lists_lazy_backends():
    with pytest.raises(ValueError, match="pallas_fused"):
        resolve_backend("no_such_backend", _config(backend="reference"))
