"""End-to-end system tests: the five-step Demeter pipeline on a synthetic
food sample, FASTA/FASTQ IO, and dry-run harness internals."""

import numpy as np
import pytest

from repro.core import HDSpace
from repro.eval import score_profile
from repro.genomics import alphabet, fasta, synth
from repro.pipeline import ArraySource, ProfilerConfig, ProfilingSession


def test_end_to_end_food_profile(tmp_path):
    """Build HD-RefDB -> stream reads -> classify -> abundance (all 5 steps),
    including FASTA/FASTQ round-trips of the inputs."""
    spec = synth.CommunitySpec(num_species=8, genome_len=30_000,
                               homology_fraction=0.05, seed=5)
    genomes, toks, lens, truth, true_ab = synth.make_sample(
        spec, num_reads=600, present=[1, 3, 5])

    # IO round-trip (the real pipeline reads files)
    fa = tmp_path / "ref.fasta"
    fq = tmp_path / "sample.fastq"
    fasta.write_fasta(fa, genomes)
    fasta.write_fastq(fq, toks, lens)
    genomes2 = fasta.read_fasta(fa)
    toks2, lens2 = fasta.read_fastq(fq, spec.read_len)
    assert set(genomes2) == set(genomes)
    np.testing.assert_array_equal(toks2, toks)

    dm = ProfilingSession(ProfilerConfig(
        space=HDSpace(dim=8192, ngram=16, z_threshold=5.0), window=4096,
        batch_size=128))
    db = dm.build_refdb(genomes2)
    rep = dm.profile(ArraySource(toks2, lens2), refdb=db)
    m = score_profile(rep.abundance, true_ab)
    assert m.recall == 1.0, m.row()
    assert m.precision >= 0.75, m.row()
    assert m.l1_error < 0.3, m.row()
    # absent species get (almost) nothing
    absent = [i for i in range(8) if true_ab[i] == 0]
    assert rep.abundance[absent].sum() < 0.1


def test_alphabet_roundtrip():
    seq = "ACGTACGTNNGT"
    toks = alphabet.seq_to_tokens(seq)
    assert alphabet.tokens_to_seq(toks) == seq.replace("N", "A")
    rc = alphabet.reverse_complement(alphabet.seq_to_tokens("AACG"))
    assert alphabet.tokens_to_seq(rc) == "CGTT"


def test_refdb_is_write_once():
    """RefDB is frozen (PCM write-once discipline)."""
    import dataclasses
    dm = ProfilingSession(ProfilerConfig(
        space=HDSpace(dim=512, ngram=4), window=512))
    rng = np.random.default_rng(0)
    db = dm.build_refdb({"a": rng.integers(0, 4, 2000).astype(np.int32)})
    with pytest.raises(dataclasses.FrozenInstanceError):
        db.prototypes = None


def test_collective_parser():
    from repro.launch import dryrun
    hlo = """
  %all-reduce = f32[1024]{0} all-reduce(%x), channel_id=1, replica_groups=[4,8]<=[32], use_global_device_ids=true
  %ag = bf16[64,128]{1,0} all-gather(%y), channel_id=2, replica_groups=[2,16]<=[32]
  %cp = bf16[32]{0} collective-permute(%z), channel_id=3
  %other = f32[8]{0} add(%a, %b)
"""
    out = dryrun.parse_collectives(hlo)
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["result_bytes"] == 4096
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["result_bytes"] == 64 * 128 * 2
    assert out["collective-permute"]["result_bytes"] == 64
    # all-reduce link bytes = 2 * size * (g-1)/g with g=8
    assert abs(out["all-reduce"]["link_bytes"] - 2 * 4096 * 7 / 8) < 1e-6
    assert out["total_link_bytes"] > 0


def test_dryrun_artifacts_if_present():
    """Integration evidence: if the sweep ran, every cell must be ok."""
    import json
    import pathlib
    art = pathlib.Path(__file__).parent.parent / "artifacts" / "dryrun"
    files = sorted(art.glob("*.json")) if art.exists() else []
    if not files:
        pytest.skip("dry-run artifacts not generated in this environment")
    bad = []
    for f in files:
        d = json.loads(f.read_text())
        if not d["ok"]:
            bad.append((f.name, d["error"][:100]))
    assert not bad, bad
