"""The ``sharded`` backend and the shard-composable species reduction.

Acceptance contract: sharded reports are bit-identical to ``reference``
for the same config on a 1-device mesh (in-process here) AND on an 8-way
``--xla_force_host_platform_device_count`` mesh (subprocess tests below,
own process so the device count doesn't leak into other tests).
"""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import classifier
from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.pipeline import (ProfilerConfig, ProfilingSession, SyntheticSource,
                            available_backends, pad_refdb, per_device_bytes,
                            place_refdb, resolve_backend)
from repro.distributed import sharding

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=4, genome_len=6_000, seed=11)
REPO = pathlib.Path(__file__).resolve().parent.parent


def _config(**kw):
    kw.setdefault("space", SP)
    kw.setdefault("window", 1024)
    kw.setdefault("batch_size", 16)
    return ProfilerConfig(**kw)


@pytest.fixture(scope="module")
def sample():
    return SyntheticSource(SPEC, num_reads=64, present=[0, 2])


@pytest.fixture(scope="module")
def reference(sample):
    s = ProfilingSession(_config())
    s.build_refdb(sample.genomes)
    return s, s.profile(sample)


# -- 1-device-mesh parity (half of the acceptance contract) ----------------

def test_registered():
    assert "sharded" in available_backends()


@pytest.mark.parametrize("base", ["reference", "reference_packed", "pcm_sim"])
def test_report_bit_identical_on_one_device_mesh(sample, reference, base):
    ref_session, ref_report = reference
    s = ProfilingSession(_config(backend="sharded",
                                 backend_options={"base": base}))
    s.build_refdb(sample.genomes)
    assert s.profile(sample).to_json() == ref_report.to_json()


def test_agreement_protocol_surface_matches(sample, reference):
    """The Backend-protocol primitive (per-prototype counts) is exact,
    including when S doesn't divide the mesh (padding sliced off)."""
    ref_session, _ = reference
    db = ref_session.refdb
    q = ref_session.encode_reads(sample.tokens[:8], sample.lengths[:8])
    sharded = resolve_backend("sharded", _config(backend="sharded"))
    for s_take in (db.prototypes.shape[0], 7):       # even and ragged
        protos = db.prototypes[:s_take]
        np.testing.assert_array_equal(
            np.asarray(sharded.agreement(q, protos)),
            np.asarray(ref_session.backend.agreement(q, protos)))


def test_fused_species_scores_matches_tail(sample, reference):
    ref_session, _ = reference
    db = ref_session.refdb
    q = ref_session.encode_reads(sample.tokens[:8], sample.lengths[:8])
    sharded = resolve_backend("sharded", _config(backend="sharded"))
    got = np.asarray(sharded.species_scores(
        q, db.prototypes, db.proto_species, db.num_species))
    agree = ref_session.backend.agreement(q, db.prototypes)
    want = np.asarray(classifier.partial_scores(
        agree, db.proto_species, db.num_species))
    np.testing.assert_array_equal(got, want)


def test_sharded_shares_refdb_cache_with_reference(tmp_path, sample):
    """backend/backend_options are excluded from the cache key: the
    sharded backend loads the database reference built, then places it."""
    s1 = ProfilingSession(_config())
    s1.build_or_load_refdb(sample.genomes, cache_dir=tmp_path)
    s2 = ProfilingSession(_config(backend="sharded"))
    s2.build_or_load_refdb(sample.genomes, cache_dir=tmp_path)
    assert s2.refdb_loaded_from_cache
    assert len(list(tmp_path.glob("refdb_*.npz"))) == 1


# -- placement + padding ----------------------------------------------------

def test_pad_refdb_tags_padding_out_of_range(reference):
    db = reference[0].refdb
    padded = pad_refdb(db, 8)
    s = db.prototypes.shape[0]
    assert padded.prototypes.shape[0] % 8 == 0
    tail = np.asarray(padded.proto_species[s:])
    assert (tail == db.num_species).all()            # dropped by segment_max
    np.testing.assert_array_equal(np.asarray(padded.prototypes[s:]), 0)
    # idempotent once divisible
    assert pad_refdb(padded, 8) is padded


def test_place_refdb_preserves_values(reference):
    db = reference[0].refdb
    mesh = sharding.make_profile_mesh(1)
    placed = place_refdb(db, mesh)
    np.testing.assert_array_equal(np.asarray(placed.prototypes),
                                  np.asarray(db.prototypes))
    assert placed.species_names == db.species_names


def test_per_device_bytes():
    import jax.numpy as jnp
    from repro.core.assoc_memory import RefDB
    db = RefDB(prototypes=jnp.zeros((10, 16), jnp.uint32),
               proto_species=jnp.zeros(10, jnp.int32),
               genome_lengths=jnp.zeros(3, jnp.int32),
               num_species=3, species_names=("a", "b", "c"))
    assert per_device_bytes(db, 1) == db.memory_bytes()
    # 10 rows over 4 shards pads to 12 -> 3 rows/device
    assert per_device_bytes(db, 4) == 3 * 16 * 4 + 3 * 4 + 3 * 4


def test_option_validation():
    with pytest.raises(ValueError, match="base"):
        resolve_backend("sharded", _config(
            backend="sharded", backend_options={"base": "sharded"}))
    with pytest.raises(ValueError, match="shards"):
        resolve_backend("sharded", _config(
            backend="sharded", backend_options={"shards": -1}))
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("sharded", _config(
            backend="sharded", backend_options={"base": "no_such"}))
    with pytest.raises(ValueError, match="num_shards"):
        resolve_backend("sharded", _config(
            backend="sharded", backend_options={"shards": 10_000}))


# -- the associative per-shard merge (property-tested) ----------------------

def _check_merge_case(rng, num_species, n_protos, b, n_pad, cuts):
    """One instance of the property: shard-then-merge == reduce-global."""
    import jax.numpy as jnp
    ps = np.sort(rng.integers(0, num_species, n_protos)).astype(np.int32)
    agree = rng.integers(0, 513, (b, n_protos)).astype(np.int32)
    ps_p = np.concatenate([ps, np.full(n_pad, num_species, np.int32)])
    agree_p = np.concatenate(
        [agree, rng.integers(0, 513, (b, n_pad)).astype(np.int32)], axis=1)
    want = np.asarray(classifier.partial_scores(
        jnp.asarray(agree), jnp.asarray(ps), num_species))
    bounds = [0, *sorted(cuts), n_protos + n_pad]
    partials = [classifier.partial_scores(
        jnp.asarray(agree_p[:, lo:hi]), jnp.asarray(ps_p[lo:hi]), num_species)
        for lo, hi in zip(bounds[:-1], bounds[1:]) if lo < hi]
    if not partials:
        return
    got = np.asarray(classifier.merge_scores(*partials))
    np.testing.assert_array_equal(got, want)


def test_merge_property_deterministic():
    """Seeded sweep of the same property (runs even without hypothesis):
    uneven shards, empty shards, absent species, mesh-padding rows."""
    rng = np.random.default_rng(0)
    for _ in range(40):
        num_species = int(rng.integers(1, 7))
        n_protos = int(rng.integers(1, 41))
        b = int(rng.integers(1, 6))
        n_pad = int(rng.integers(0, 8))
        n_cuts = int(rng.integers(0, 5))
        cuts = rng.integers(0, n_protos + n_pad + 1, n_cuts).tolist()
        _check_merge_case(rng, num_species, n_protos, b, n_pad, cuts)


def test_merge_property_hypothesis():
    """Concatenating prototype shards then reducing == merging per-shard
    partial reductions — for uneven shard sizes and padded rows."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=60)
    @given(st.data())
    def check(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
        num_species = data.draw(st.integers(1, 6))
        n_protos = data.draw(st.integers(1, 40))
        n_pad = data.draw(st.integers(0, 7))
        cuts = data.draw(st.lists(
            st.integers(0, n_protos + n_pad), max_size=4))
        _check_merge_case(rng, num_species, n_protos,
                          data.draw(st.integers(1, 5)), n_pad, cuts)

    check()


def test_no_score_is_the_reduction_fill_and_merge_identity():
    """partial_scores fills species absent from a shard with NO_SCORE
    (what segment_max actually emits), and NO_SCORE never wins a merge —
    pinning the constant to the implementation so they cannot drift."""
    import jax.numpy as jnp
    agree = jnp.asarray([[7], [3]], jnp.int32)       # 1 prototype, species 0
    sc = np.asarray(classifier.partial_scores(
        agree, jnp.asarray([0], jnp.int32), 3))
    assert (sc[:, 1:] == classifier.NO_SCORE).all()  # absent species
    np.testing.assert_array_equal(sc[:, 0], [7, 3])
    merged = classifier.merge_scores(
        jnp.asarray(sc), jnp.full_like(jnp.asarray(sc), classifier.NO_SCORE))
    np.testing.assert_array_equal(np.asarray(merged), sc)  # identity


def test_merge_is_order_invariant():
    import jax.numpy as jnp
    a = jnp.asarray([[1, -5], [3, 2]], jnp.int32)
    b = jnp.asarray([[0, 7], [-1, 2]], jnp.int32)
    c = jnp.asarray([[2, 2], [2, 2]], jnp.int32)
    lhs = classifier.merge_scores(classifier.merge_scores(a, b), c)
    rhs = classifier.merge_scores(a, classifier.merge_scores(c, b))
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


# -- serving over one sharded RefDB ----------------------------------------

def test_service_shares_sharded_refdb(sample, reference):
    """Many concurrent requests over one sharded, device-placed database
    come back bit-identical to sequential reference runs."""
    from repro.serve import ProfilingService
    _, ref_report = reference
    s = ProfilingSession(_config(backend="sharded"))
    s.build_refdb(sample.genomes)
    service = ProfilingService(s, max_active=4)
    handles = [service.submit((sample.tokens, sample.lengths))
               for _ in range(3)]
    service.run_until_idle()
    for h in handles:
        assert h.result(timeout=5).to_json() == ref_report.to_json()


# -- 8-way host-platform mesh (the other half of the acceptance) ------------

def _run8(snippet: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_eight_way_mesh_report_parity():
    """Reports bit-identical to reference on an 8-device mesh, for an
    S that does NOT divide the mesh (padding in play), plus cache
    build/load through the store under sharding."""
    _run8("""
import tempfile
import numpy as np
from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.pipeline import ProfilerConfig, ProfilingSession, SyntheticSource

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=5, genome_len=6_000, seed=11)
sample = SyntheticSource(SPEC, num_reads=64, present=[0, 2])

ref = ProfilingSession(ProfilerConfig(space=SP, window=1024, batch_size=16))
ref.build_refdb(sample.genomes)
want = ref.profile(sample).to_json()
# 5 genomes x 6 windows = 30 prototypes: not a multiple of 8 -> padded
assert ref.refdb.prototypes.shape[0] % 8 != 0

for base in ("reference", "reference_packed", "pallas_matmul"):
    for shards in (3, 8):
        cfg = ProfilerConfig(space=SP, window=1024, batch_size=16,
                             backend="sharded",
                             backend_options={"base": base, "shards": shards})
        s = ProfilingSession(cfg)
        s.build_refdb(sample.genomes)
        assert s.backend.num_shards == shards
        got = s.profile(sample).to_json()
        assert got == want, (base, shards)

with tempfile.TemporaryDirectory() as d:
    s1 = ProfilingSession(ProfilerConfig(space=SP, window=1024, batch_size=16))
    s1.build_or_load_refdb(sample.genomes, cache_dir=d)
    s2 = ProfilingSession(ProfilerConfig(space=SP, window=1024, batch_size=16,
                                         backend="sharded"))
    db = s2.build_or_load_refdb(sample.genomes, cache_dir=d)
    assert s2.refdb_loaded_from_cache
    assert db.prototypes.shape[0] % 8 == 0         # placed = padded to mesh
    assert s2.profile(sample).to_json() == want
print('8-way parity OK')
""")


def test_eight_way_mesh_actually_distributes():
    """Placement puts distinct prototype rows on distinct devices (the
    capacity claim, not just numerical parity)."""
    _run8("""
import jax
import numpy as np
from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.pipeline import ProfilerConfig, ProfilingSession, SyntheticSource

assert len(jax.devices()) == 8
SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=4, genome_len=6_000, seed=11)
sample = SyntheticSource(SPEC, num_reads=8, present=[0, 2])
s = ProfilingSession(ProfilerConfig(space=SP, window=1024, batch_size=8,
                                    backend="sharded"))
s.build_refdb(sample.genomes)
db = s.refdb
shards = {sh.device.id for sh in db.prototypes.addressable_shards}
assert len(shards) == 8, shards
rows = db.prototypes.shape[0]
for sh in db.prototypes.addressable_shards:
    assert sh.data.shape[0] == rows // 8
print('8-way placement OK')
""")
