"""Config fidelity: parameter counts match the published model sizes."""

import pytest

from repro.configs import all_archs, get_config
from repro.configs import shapes as shapes_mod

# (arch, expected TOTAL params, tolerance) — active counts for MoE noted.
EXPECTED_ACTIVE = {
    "deepseek_67b": (67e9, 0.10),
    "starcoder2_7b": (7e9, 0.15),
    "nemotron4_15b": (15e9, 0.20),
    "stablelm_3b": (3e9, 0.25),
    "mamba2_1_3b": (1.3e9, 0.15),
    "hymba_1_5b": (1.5e9, 0.35),
    "paligemma_3b": (3e9, 0.25),     # backbone (SigLIP tower is stubbed)
    "phi35_moe": (6.6e9, 0.25),      # active (a6.6b)
    "deepseek_v2_lite": (2.4e9, 0.40),  # active ~2.4B
    "whisper_tiny": (39e6, 0.60),    # tiny enc-dec
}


@pytest.mark.parametrize("arch", all_archs())
def test_config_loads_and_validates(arch):
    cfg = get_config(arch)
    assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab > 0
    smoke = get_config(arch, smoke=True)
    assert smoke.family == cfg.family
    assert smoke.d_model <= 128, "smoke configs must be tiny"


@pytest.mark.parametrize("arch", list(EXPECTED_ACTIVE))
def test_active_param_count_fidelity(arch):
    cfg = get_config(arch)
    want, tol = EXPECTED_ACTIVE[arch]
    got = cfg.active_param_count()
    assert abs(got - want) / want < tol, \
        f"{arch}: active params {got/1e9:.2f}B vs published {want/1e9:.2f}B"


def test_aliases_cover_assignment_ids():
    for assignment_id in ("deepseek-v2-lite-16b", "phi3.5-moe-42b-a6.6b",
                          "starcoder2-7b", "deepseek-67b", "nemotron-4-15b",
                          "stablelm-3b", "whisper-tiny", "hymba-1.5b",
                          "mamba2-1.3b", "paligemma-3b"):
        assert get_config(assignment_id) is not None


def test_shape_applicability_skips():
    long = shapes_mod.SHAPES["long_500k"]
    runs, _ = shapes_mod.applicable(get_config("mamba2_1_3b"), long)
    assert runs
    runs, reason = shapes_mod.applicable(get_config("deepseek_67b"), long)
    assert not runs and "full-attention" in reason
    runs, _ = shapes_mod.applicable(get_config("hymba_1_5b"), long)
    assert runs


def test_input_specs_shapes():
    cfg = get_config("paligemma_3b")
    spec = shapes_mod.input_specs(cfg, shapes_mod.SHAPES["train_4k"])
    # image prefix + text = 4096 total
    assert spec["prefix_embeds"].shape == (256, 256, 2048)
    assert spec["tokens"].shape == (256, 4096 - 256)

    wcfg = get_config("whisper_tiny")
    spec = shapes_mod.input_specs(wcfg, shapes_mod.SHAPES["prefill_32k"])
    assert spec["enc_embeds"].shape == (32, 32768, 384)

    dcfg = get_config("deepseek_67b")
    spec = shapes_mod.input_specs(dcfg, shapes_mod.SHAPES["decode_32k"])
    assert spec["token"].shape == (128,)


def test_cache_specs_no_allocation():
    cfg = get_config("deepseek_67b")
    caches = shapes_mod.cache_specs(cfg, shapes_mod.SHAPES["decode_32k"])
    import jax
    leaves = jax.tree.leaves(caches)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    # KV cache shape: (layers, batch, seq, kv_heads, head_dim)
    assert caches[0]["k"].shape == (95, 128, 32768, 8, 128)
