"""Fleet serving: replication, routing, failover, fleet-wide swaps.

Acceptance contract (ISSUE 8): a 3-host fleet survives a host killed
mid-request — affected requests are rerouted to surviving replicas and
their reports are bit-identical to a sequential run — and a fleet-wide
hot-swap is two-phase: no host serves the new version before every host
has it pinned (prepare), and the old version only becomes gc-eligible
at the source after every host has drained it (retire).  Both asserted
on the ``reference`` and ``pallas_fused`` backends.  Plus: tenant
affinity + least-load routing, resumable replication across downtime
and source gc, non-replayable-source failure semantics, and the merged
fleet metrics snapshot (per-host labels + fleet gauges).
"""

import numpy as np
import pytest

from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.pipeline import (ArraySource, IterableSource, ProfilerConfig,
                            ProfilingSession, SyntheticSource)
from repro.serve import (FleetController, HostDown, HostState,
                         NoHealthyHosts, RefDBRegistry)

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=4, genome_len=6_000, seed=11)


def _config(**kw):
    kw.setdefault("space", SP)
    kw.setdefault("window", 1024)
    kw.setdefault("batch_size", 16)
    return ProfilerConfig(**kw)


@pytest.fixture(scope="module")
def sample():
    return SyntheticSource(SPEC, num_reads=144, present=[0, 2])


@pytest.fixture(scope="module")
def extra():
    rng = np.random.default_rng(99)
    return {"sp_new": rng.integers(0, 4, 6_000, dtype=np.int32)}


def _slices(sample, n):
    return [ArraySource(sample.tokens[i::n], sample.lengths[i::n])
            for i in range(n)]


def _registry(sample, cfg):
    reg = RefDBRegistry(root=None)
    reg.create("food", sample.genomes, cfg)
    return reg


def _sequential(reg, cfg, version):
    s = ProfilingSession(cfg)
    s.adopt_refdb(reg.snapshot("food", version).db)
    return s


# -- routing -----------------------------------------------------------------

def test_routing_spreads_and_reports_bit_exact(sample):
    cfg = _config(backend="reference")
    reg = _registry(sample, cfg)
    fleet = FleetController(reg, hosts=3)
    fleet.add_tenant("a", "food", max_active=1, max_queue=8)
    fleet.add_tenant("b", "food", max_active=1, max_queue=8)
    srcs = _slices(sample, 6)
    with fleet:
        handles = [fleet.submit(s, tenant="ab"[i % 2]) for i, s in
                   enumerate(srcs)]
        reports = [h.result(timeout=300) for h in handles]
    fleet.close()
    seq = _sequential(reg, cfg, 1)
    for h, src, rep in zip(handles, srcs, reports):
        assert h.version == 1
        assert rep.to_json() == seq.profile(src).to_json()
    # least-outstanding routing spreads load past the affinity home
    assert len({h.host for h in handles}) > 1


def test_tenant_affinity_on_idle_fleet(sample):
    """With no load anywhere, a tenant always lands on its ring home."""
    cfg = _config(backend="reference")
    reg = _registry(sample, cfg)
    fleet = FleetController(reg, hosts=3)
    fleet.add_tenant("acme", "food", max_active=4, max_queue=16)
    srcs = _slices(sample, 4)
    homes = set()
    with fleet:
        for src in srcs:
            h = fleet.submit(src, tenant="acme")
            h.result(timeout=300)       # fleet idle again before the next
            homes.add(h.host)
    fleet.close()
    assert len(homes) == 1


def test_unknown_tenant_and_no_healthy_hosts(sample):
    cfg = _config(backend="reference")
    reg = _registry(sample, cfg)
    fleet = FleetController(reg, hosts=2)
    fleet.add_tenant("a", "food")
    src = _slices(sample, 1)[0]
    with pytest.raises(KeyError, match="nope"):
        fleet.submit(src, tenant="nope")
    fleet.kill_host("host0")
    fleet.kill_host("host1")
    with pytest.raises(NoHealthyHosts):
        fleet.submit(src, tenant="a")
    fleet.close()


# -- acceptance: mid-flight host kill, rerouted and bit-exact ----------------

@pytest.mark.parametrize("backend", ["reference", "pallas_fused"])
def test_kill_host_reroutes_bit_exact(sample, backend):
    """Requests on the killed host fail over to survivors; every report
    (rerouted ones included) is bit-identical to a sequential run.

    Submitted before the pumps start, so the victim's requests are
    provably in flight (queued, not done) when the host dies."""
    cfg = _config(backend=backend)
    reg = _registry(sample, cfg)
    fleet = FleetController(reg, hosts=3)
    fleet.add_tenant("acme", "food", max_active=2, max_queue=16)
    srcs = _slices(sample, 6)
    handles = [fleet.submit(s, tenant="acme") for s in srcs]
    by_host: dict[str, int] = {}
    for h in handles:
        by_host[h.host] = by_host.get(h.host, 0) + 1
    victim = max(by_host, key=by_host.get)
    moved = fleet.kill_host(victim)
    assert moved                       # the busiest host had live work
    with fleet:                        # survivors pump; victim stays down
        reports = [h.result(timeout=300) for h in handles]
    seq = _sequential(reg, cfg, 1)
    for h, src, rep in zip(handles, srcs, reports):
        assert rep.to_json() == seq.profile(src).to_json()
        assert h.host != victim        # nothing still claims the dead host
    rerouted = [h for h in handles if h.rerouted]
    assert {h.request_id for h in rerouted} == set(moved)
    assert all(len(h.attempts) == 2 for h in rerouted)
    assert fleet.host(victim).state is HostState.DOWN
    fleet.close()


def test_kill_host_nonreplayable_source_fails_clean(sample):
    """An IterableSource cannot be re-submitted: its handle raises
    HostDown instead of silently returning a partial report."""
    cfg = _config(backend="reference")
    reg = _registry(sample, cfg)
    fleet = FleetController(reg, hosts=2)
    fleet.add_tenant("acme", "food", max_active=2, max_queue=16)
    stream = IterableSource(
        iter([(sample.tokens[:16], sample.lengths[:16])]))
    h = fleet.submit(stream, tenant="acme")
    fleet.kill_host(h.host)
    with pytest.raises(HostDown, match="not replayable"):
        h.result(timeout=300)
    fleet.close()


# -- acceptance: fleet-wide two-phase swap -----------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas_fused"])
def test_fleet_swap_two_phase_invariants(sample, extra, backend):
    """No host serves v2 before every host has it pinned; v1 is only
    gc-eligible at the source after every host drained it."""
    cfg = _config(backend=backend)
    reg = _registry(sample, cfg)
    fleet = FleetController(reg, hosts=3)
    fleet.add_tenant("acme", "food", max_active=4, max_queue=16)
    srcs = _slices(sample, 4)
    phases = []

    def on_phase(phase):
        phases.append(phase)
        if phase != "prepared":
            return
        for replica in fleet.hosts():
            # prepared: v2 resident + pinned on every mirror...
            assert 2 in replica.registry.versions("food")
            assert replica.registry.pins("food").get(2, 0) >= 1
            # ...but every router still admits against v1
            assert replica.router.serving_version("food") == 1

    with fleet:
        pre = [fleet.submit(s, tenant="acme") for s in srcs[:2]]
        snap2 = reg.apply_delta("food", add=extra)
        fleet.fleet_swap("food", version=snap2.version, on_phase=on_phase)
        for replica in fleet.hosts():
            assert replica.router.serving_version("food") == 2
        post = [fleet.submit(s, tenant="acme") for s in srcs[2:]]
        for h in pre + post:
            h.result(timeout=300)
        # v1 still source-pinned until every host reports drained; a gc
        # sweep right now must refuse it no matter the keep policy
        assert reg.gc("food", keep_last=1).collected == ()
        fleet.wait_retired("food", 1, timeout=300)
    assert phases == ["prepared", "flipped"]
    assert 1 not in reg.pins("food")
    seq1, seq2 = _sequential(reg, cfg, 1), _sequential(reg, cfg, 2)
    swept = reg.gc("food", keep_last=1)
    assert swept.collected == (("food", 1),)
    for h, src in zip(pre, srcs[:2]):
        assert h.version == 1
        assert h.result(timeout=0).to_json() == seq1.profile(src).to_json()
    for h, src in zip(post, srcs[2:]):
        assert h.version == 2
        assert h.result(timeout=0).to_json() == seq2.profile(src).to_json()
    fleet.close()


# -- replication: resumable across downtime and source gc --------------------

def test_down_host_resyncs_on_revive_past_gcd_versions(sample, extra):
    """A host that missed a publish (and whose missed version the source
    then gc'd) revives straight onto the fleet's serving version."""
    cfg = _config(backend="reference")
    reg = _registry(sample, cfg)
    fleet = FleetController(reg, hosts=3)
    fleet.kill_host("host2")        # down before it ever mirrors anything
    fleet.add_tenant("acme", "food", max_active=4, max_queue=16)
    with fleet:
        snap2 = reg.apply_delta("food", add=extra)
        fleet.fleet_swap("food", version=snap2.version)  # 2 live hosts
        fleet.wait_retired("food", 1, timeout=300)
        assert reg.gc("food", keep_last=1).collected == (("food", 1),)
        fleet.revive_host("host2")
        replica = fleet.host("host2")
        assert replica.state is HostState.HEALTHY
        # the mirror chain skips gc'd v1: only v2 was left to pull
        assert replica.registry.versions("food") == (2,)
        assert replica.router.serving_version("food") == 2
        assert replica.lag("food") == 0
        src = _slices(sample, 1)[0]
        h = replica.submit(src, tenant="acme")
        fleet.run_until_idle()
        assert h.result(timeout=300).to_json() == \
            _sequential(reg, cfg, 2).profile(src).to_json()
    fleet.close()


def test_install_is_idempotent_and_checks_fingerprint(sample):
    cfg = _config(backend="reference")
    reg = _registry(sample, cfg)
    mirror = RefDBRegistry(root=None)
    snap = reg.current("food")
    a = mirror.install("food", snap, config=cfg)
    b = mirror.install("food", snap, config=cfg)
    assert a is b                       # idempotent per version
    other = _config(space=HDSpace(dim=256, ngram=5, z_threshold=3.0))
    with pytest.raises(ValueError, match="fingerprint"):
        mirror.install("food", snap, config=other)


# -- fleet observability ------------------------------------------------------

def test_fleet_metrics_snapshot_has_host_labels(sample):
    cfg = _config(backend="reference")
    reg = _registry(sample, cfg)
    fleet = FleetController(reg, hosts=3)
    fleet.add_tenant("acme", "food", max_active=4, max_queue=16)
    with fleet:
        for src in _slices(sample, 3):
            fleet.submit(src, tenant="acme")
        fleet.run_until_idle()
        merged = fleet.metrics_snapshot()
    fleet.close()
    snap = merged.snapshot()
    installs = snap["counters"]["refdb_installs_total"]["series"]
    hosts = {s["labels"]["host"] for s in installs}
    assert hosts == {"host0", "host1", "host2"}   # every mirror synced
    gauges = snap["gauges"]
    assert gauges["fleet_healthy_hosts"]["series"][0]["value"] == 3.0
    lag = {s["labels"]["host"]: s["value"]
           for s in gauges["fleet_replication_lag_versions"]["series"]}
    assert lag == {"host0": 0.0, "host1": 0.0, "host2": 0.0}
    assert "fleet_outstanding_reads" in gauges
    assert snap["counters"]["fleet_requests_total"]["series"]
