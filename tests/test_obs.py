"""Observability layer: metrics math, trace assembly, and the two
contracts the serving stack stakes on it.

Acceptance contract (ISSUE 7): enabling metrics must not move a single
bit of profiler output on any backend (``reference``, ``pallas_fused``,
``sharded`` — and ``pcm_sim`` with device noise, whose stats read is a
separate compiled graph); and an assembled request trace's child spans
must tile the root span exactly, cancelled and failed requests
included.  Plus: histogram bucket/percentile/merge math, registry GC
(pinned refusal, ``keep_last``, ``max_age_s``, reclaimed bytes), and
the router/registry metric touchpoints.
"""

import math

import numpy as np
import pytest

from repro import obs
from repro.core.assoc_memory import build_refdb
from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession,
                            SyntheticSource)
from repro.serve import (ProfilingService, RefDBRegistry, ServiceOverloaded,
                         TenantRouter)

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)
SPEC = synth.CommunitySpec(num_species=4, genome_len=6_000, seed=11)


def _config(**kw):
    kw.setdefault("space", SP)
    kw.setdefault("window", 1024)
    kw.setdefault("batch_size", 16)
    return ProfilerConfig(**kw)


@pytest.fixture(scope="module")
def sample():
    return SyntheticSource(SPEC, num_reads=96, present=[0, 2])


@pytest.fixture(scope="module")
def refdb(sample):
    return build_refdb(sample.genomes, SP, window=1024)


@pytest.fixture(scope="module")
def extra():
    rng = np.random.default_rng(99)
    return {"sp_new": rng.integers(0, 4, 6_000, dtype=np.int32)}


def _slices(sample, n):
    return [ArraySource(sample.tokens[i::n], sample.lengths[i::n])
            for i in range(n)]


# -- histogram bucket + percentile math --------------------------------------

def test_histogram_boundaries_and_overflow():
    state = obs.HistogramState((1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 2.0, 4.0, 5.0):     # bounds inclusive (le)
        state.observe(v)
    assert state.counts == [2, 1, 1, 1]     # last slot = overflow
    assert state.count == 5
    assert state.sum == pytest.approx(12.5)
    # ranks landing in the overflow bucket clamp to the last bound
    assert state.percentile(100) == 4.0


def test_histogram_percentile_interpolates_within_bucket():
    state = obs.HistogramState((10.0,))
    state.observe(3.0)                      # one sample, bucket [0, 10]
    assert state.percentile(50) == pytest.approx(5.0)
    state = obs.HistogramState((1.0, 2.0))
    for _ in range(2):
        state.observe(1.5)
    for _ in range(2):
        state.observe(0.5)
    assert state.percentile(50) == pytest.approx(1.0)
    assert state.percentile(100) == pytest.approx(2.0)


def test_histogram_empty_and_bad_args():
    state = obs.HistogramState((1.0,))
    assert math.isnan(state.percentile(50))
    assert math.isnan(state.mean)
    with pytest.raises(ValueError):
        state.percentile(101)
    with pytest.raises(ValueError):
        obs.HistogramState(())
    with pytest.raises(ValueError):
        obs.HistogramState((2.0, 1.0))      # not ascending


def test_histogram_merge():
    a = obs.HistogramState((1.0, 2.0))
    b = obs.HistogramState((1.0, 2.0))
    a.observe(0.5)
    b.observe(1.5)
    b.observe(9.0)
    a.merge(b)
    assert a.counts == [1, 1, 1]
    assert a.count == 3
    assert a.sum == pytest.approx(11.0)
    with pytest.raises(ValueError):
        a.merge(obs.HistogramState((1.0,)))


def test_registry_merge_from_and_merged():
    """The cross-host aggregation seam: merged() folds per-host
    registries into one snapshot with a ``host`` label on every series;
    an unlabelled merge_from accumulates same-label series."""
    a = obs.MetricsRegistry()
    b = obs.MetricsRegistry()
    a.counter("reads_total").inc(3, tenant="acme")
    b.counter("reads_total").inc(2, tenant="acme")
    a.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.05)
    b.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(5.0)
    b.gauge("queue_depth").set(7)

    fleet = obs.MetricsRegistry.merged({"h0": a, "h1": b})
    snap = fleet.snapshot()
    reads = {s["labels"]["host"]: s["value"]
             for s in snap["counters"]["reads_total"]["series"]}
    assert reads == {"h0": 3.0, "h1": 2.0}
    assert all(s["labels"]["tenant"] == "acme"
               for s in snap["counters"]["reads_total"]["series"])
    hosts = {s["labels"]["host"]
             for s in snap["histograms"]["lat_seconds"]["series"]}
    assert hosts == {"h0", "h1"}
    [g] = snap["gauges"]["queue_depth"]["series"]
    assert g["labels"] == {"host": "h1"} and g["value"] == 7.0

    total = obs.MetricsRegistry()       # no label: same series accumulate
    total.merge_from(a)
    total.merge_from(b)
    snap2 = total.snapshot()
    assert snap2["counters"]["reads_total"]["series"][0]["value"] == 5.0
    [h] = snap2["histograms"]["lat_seconds"]["series"]
    assert h["counts"] == [1, 0, 1]     # bucket-wise HistogramState.merge


def test_registry_get_or_create_and_kind_conflicts():
    reg = obs.MetricsRegistry()
    h = reg.histogram("x_seconds", buckets=(1.0, 2.0))
    assert reg.histogram("x_seconds", buckets=(1.0, 2.0)) is h
    with pytest.raises(ValueError, match="different buckets"):
        reg.histogram("x_seconds", buckets=(1.0,))
    reg.counter("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError):
        reg.counter("x_total").inc(-1)      # counters only go up


def test_snapshot_and_prometheus_exposition():
    reg = obs.MetricsRegistry()
    reg.counter("reads_total").inc(3, tenant="acme")
    lat = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    lat.observe(0.05, backend="reference")
    lat.observe(5.0, backend="reference")
    snap = reg.snapshot()
    assert snap["counters"]["reads_total"]["series"][0] == {
        "labels": {"tenant": "acme"}, "value": 3.0}
    [series] = snap["histograms"]["lat_seconds"]["series"]
    assert series["labels"] == {"backend": "reference"}
    assert series["counts"] == [1, 0, 1]
    assert series["p50"] is not None
    text = reg.to_prometheus()
    assert 'reads_total{tenant="acme"} 3' in text
    assert 'lat_seconds_bucket{backend="reference",le="+Inf"} 2' in text
    assert 'lat_seconds_count{backend="reference"} 2' in text


def test_null_registry_is_inert():
    null = obs.NULL_METRICS
    assert not null.enabled
    c = null.counter("whatever_total")
    c.inc(5)
    assert c.value() == 0.0 and not c.enabled
    null.histogram("h").observe(1.0)
    assert math.isnan(null.histogram("h").percentile(50))
    assert null.instruments() == ()


# -- trace assembly -----------------------------------------------------------

def _timeline(*marks):
    tl = obs.RequestTimeline()
    for name, t in marks:
        tl.mark(name, at=t)
    return tl


def test_trace_children_tile_root_exactly():
    tl = _timeline(("submitted", 1.0), ("started", 1.5),
                   ("first_execute", 2.0), ("accumulate", 3.0),
                   ("finalize", 3.25), ("finished", 4.0))
    trace = obs.assemble_trace("r-0", tl, state="done")
    assert [s.name for s in trace.spans] == [
        "request", "admission", "schedule", "execute", "accumulate",
        "finalize"]
    children = trace.spans[1:]
    assert sum(s.duration_s for s in children) == trace.duration_s == 3.0
    assert all(s.parent_id == 0 for s in children)
    assert trace.span("schedule").duration_s == pytest.approx(0.5)


def test_trace_of_request_cancelled_while_queued():
    tl = _timeline(("submitted", 1.0), ("finished", 2.0))
    trace = obs.assemble_trace("r-1", tl, state="cancelled")
    assert trace.state == "cancelled"
    assert [s.name for s in trace.spans] == ["request", "admission"]
    assert trace.duration_s == pytest.approx(1.0)


def test_trace_stops_at_last_phase_reached():
    tl = _timeline(("submitted", 1.0), ("started", 2.0),
                   ("first_execute", 2.5), ("finished", 3.0))
    trace = obs.assemble_trace("r-2", tl, state="failed")
    assert [s.name for s in trace.spans] == [
        "request", "admission", "schedule", "execute"]
    assert sum(s.duration_s for s in trace.spans[1:]) == trace.duration_s


def test_timeline_first_wins_except_accumulate():
    tl = _timeline(("submitted", 1.0), ("submitted", 9.0),
                   ("accumulate", 2.0), ("accumulate", 3.0))
    assert tl.at("submitted") == 1.0
    assert tl.at("accumulate") == 3.0       # latest cohort demux
    with pytest.raises(ValueError, match="unknown timeline mark"):
        tl.mark("warp")
    with pytest.raises(ValueError, match="no marks"):
        obs.assemble_trace("r-3", obs.RequestTimeline())


def test_trace_recorder_keeps_first_n():
    rec = obs.TraceRecorder(sample=2)
    for i in range(4):
        tl = _timeline(("submitted", float(i)), ("finished", i + 1.0))
        rec.record(f"r-{i}", tl)
    assert rec.full
    assert [t.trace_id for t in rec.traces()] == ["r-0", "r-1"]
    null = obs.NULL_TRACER
    assert null.record("r", _timeline(("submitted", 0.0))) is None
    assert null.traces() == () and not null.enabled


# -- bit-exactness: metrics on == metrics off --------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas_fused", "sharded"])
def test_metrics_do_not_perturb_results(sample, refdb, backend):
    cfg = _config(backend=backend)
    off = ProfilingSession(cfg)
    off.adopt_refdb(refdb)
    reg = obs.MetricsRegistry()
    on = ProfilingSession(cfg, metrics=reg)
    on.adopt_refdb(refdb)
    src = _slices(sample, 1)[0]
    assert on.profile(src).to_json() == off.profile(src).to_json()
    # the enabled twin really recorded (the comparison wasn't vacuous)
    assert reg.counter("session_classify_batches_total").total() > 0
    assert reg.histogram("session_classify_batch_seconds").merged().count > 0


def test_pcm_sim_metrics_bit_exact_with_device_noise(sample, refdb):
    """The stats read is a separate graph; its result math must match."""
    cfg = _config(backend="pcm_sim",
                  backend_options={"preset": "pcm", "seed": 3})
    src = _slices(sample, 1)[0]
    off = ProfilingSession(cfg)
    off.adopt_refdb(refdb)
    rep_off = off.profile(src).to_json()
    reg = obs.enable_metrics()              # backends resolve the global
    try:
        on = ProfilingSession(cfg)
        on.adopt_refdb(refdb)
        rep_on = on.profile(src).to_json()
    finally:
        obs.disable()
    assert rep_on == rep_off
    assert reg.counter("pcm_program_events_total").total() >= 1
    assert reg.counter("pcm_reads_total").total() > 0
    stuck = reg.gauge("pcm_stuck_cells")
    assert len(stuck.labelsets()) == 4      # {pos,neg} x {on,off}


# -- service + router end to end ---------------------------------------------

def test_service_metrics_and_traces_end_to_end(sample, refdb):
    cfg = _config(backend="reference")
    session = ProfilingSession(cfg)
    session.adopt_refdb(refdb)
    reg = obs.MetricsRegistry()
    rec = obs.TraceRecorder(sample=8)
    service = ProfilingService(session, max_active=2, max_queue=8,
                               metrics=reg, tracer=rec)
    srcs = _slices(sample, 4)
    handles = [service.submit(s) for s in srcs]
    service.run_until_idle()
    reads = sum(h.result(timeout=0).total_reads for h in handles)

    assert reg.counter("serve_requests_total").value(state="done") == 4
    assert reg.counter("serve_reads_classified_total").total() == reads
    assert reg.histogram("serve_admission_wait_seconds").merged().count == 4
    assert reg.histogram("serve_batch_seconds").merged().count > 0
    fill = reg.histogram("serve_cohort_fill_ratio",
                         buckets=obs.RATIO_BUCKETS).merged()
    assert fill.count > 0 and fill.sum <= fill.count    # ratios in (0, 1]
    assert reg.gauge("serve_queue_depth").value() == 0
    assert reg.gauge("serve_active_requests").value() == 0

    traces = rec.traces()
    assert len(traces) == 4
    for trace in traces:
        assert trace.state == "done"
        assert sum(s.duration_s for s in trace.spans[1:]) \
            == pytest.approx(trace.duration_s)
    # the trace clock IS the handle latency clock (one accounting)
    by_id = {t.trace_id: t for t in traces}
    for h in handles:
        assert by_id[h.request_id].duration_s \
            == pytest.approx(h.latency_s)
        assert h.queue_wait_s + h.service_s == pytest.approx(h.latency_s)


def test_cancelled_and_failed_requests_still_trace(sample, refdb):
    cfg = _config(backend="reference")
    session = ProfilingSession(cfg)
    session.adopt_refdb(refdb)
    reg = obs.MetricsRegistry()
    rec = obs.TraceRecorder(sample=8)
    service = ProfilingService(session, max_active=1, max_queue=8,
                               metrics=reg, tracer=rec)
    srcs = _slices(sample, 3)
    h_done = service.submit(srcs[0])
    service.run_until_idle()
    h_done.result(timeout=0)
    h_cancel = service.submit(srcs[1])
    assert h_cancel.cancel()                # still queued: cancellable
    h_fail = service.submit(srcs[2])
    service.fail_all(RuntimeError("injected"))
    service.run_until_idle()
    states = {t.trace_id: t.state for t in rec.traces()}
    assert states[h_cancel.request_id] == "cancelled"
    assert states[h_fail.request_id] == "failed"
    # cancelled/failed while queued: the trace stops at admission
    for h in (h_cancel, h_fail):
        trace = [t for t in rec.traces()
                 if t.trace_id == h.request_id][0]
        assert [s.name for s in trace.spans] == ["request", "admission"]
    assert reg.counter("serve_requests_total").value(state="cancelled") == 1
    assert reg.counter("serve_requests_total").value(state="failed") == 1


def test_router_and_registry_metrics_touchpoints(tmp_path, sample, extra):
    reg = obs.MetricsRegistry()
    registry = RefDBRegistry(root=tmp_path / "r", metrics=reg)
    registry.create("food", sample.genomes, _config(backend="reference"))
    router = TenantRouter(registry, metrics=reg)
    router.add_tenant("acme", database="food", max_active=2, max_queue=0)
    router.add_tenant("tiny", database="food", max_active=1, max_queue=0)

    srcs = _slices(sample, 4)
    handles = [router.submit(s, tenant="acme") for s in srcs[:2]]
    router.submit(srcs[2], tenant="tiny")
    with pytest.raises(ServiceOverloaded):
        router.submit(srcs[3], tenant="tiny")
    registry.apply_delta("food", add=extra)         # auto hot-swap
    router.run_until_idle()
    reads = sum(h.result(timeout=300).total_reads for h in handles)
    router.step()                                   # final prune pass
    router.close()

    assert reg.counter("router_requests_total").value(tenant="acme") == 2
    assert reg.counter("router_quota_rejections_total") \
              .value(tenant="tiny") == 1
    assert reg.counter("router_reads_completed_total") \
              .value(tenant="acme") == reads
    assert reg.gauge("router_serving_version").value(database="food") == 2
    assert reg.histogram("router_hot_swap_seconds").merged().count == 1
    assert reg.histogram("router_drain_seconds").merged().count == 1
    assert reg.counter("refdb_publishes_total").value(database="food") == 2
    assert reg.gauge("refdb_current_version").value(database="food") == 2
    builds = reg.histogram("refdb_build_seconds")
    assert builds.count(database="food", kind="create") == 1
    assert builds.count(database="food", kind="delta") == 1


# -- registry garbage collection ---------------------------------------------

def _three_versions(tmp_path, sample, extra, metrics=None):
    registry = RefDBRegistry(root=tmp_path / "r", metrics=metrics)
    registry.create("food", sample.genomes, _config())
    registry.apply_delta("food", add=extra)
    registry.apply_delta("food", remove=["sp_new"])
    assert registry.versions("food") == (1, 2, 3)
    return registry


def test_gc_keep_last_and_reclaimed_bytes(tmp_path, sample, extra):
    reg = obs.MetricsRegistry()
    registry = _three_versions(tmp_path, sample, extra, metrics=reg)
    result = registry.gc("food", keep_last=1)
    assert result.collected == (("food", 1), ("food", 2))
    assert result.reclaimed_bytes > 0
    assert registry.versions("food") == (3,)
    assert not list((tmp_path / "r" / "food").glob("v1.npz"))
    assert reg.counter("refdb_gc_versions_total").total() == 2
    assert reg.counter("refdb_gc_reclaimed_bytes_total").total() \
        == result.reclaimed_bytes
    # idempotent: a second sweep finds nothing
    assert registry.gc("food", keep_last=1).collected == ()
    with pytest.raises(ValueError):
        registry.gc("food", keep_last=0)


def test_gc_refuses_pinned_versions(tmp_path, sample, extra):
    registry = _three_versions(tmp_path, sample, extra)
    registry.pin("food", 1)
    result = registry.gc("food", keep_last=1)
    assert result.collected == (("food", 2),)       # v1 pinned, v3 current
    assert registry.versions("food") == (1, 3)
    registry.release("food", 1)
    assert registry.gc("food", keep_last=1).collected == (("food", 1),)
    with pytest.raises(KeyError):
        registry.pin("food", 99)


def test_gc_max_age_is_a_further_filter(tmp_path, sample, extra):
    registry = _three_versions(tmp_path, sample, extra)
    # nothing is an hour old yet -> nothing collected despite keep_last
    assert registry.gc("food", keep_last=1,
                       max_age_s=3600).collected == ()
    assert registry.versions("food") == (1, 2, 3)
    assert registry.gc("food", keep_last=1,
                       max_age_s=0).collected == (("food", 1), ("food", 2))


def test_gc_never_collects_what_a_live_router_serves(tmp_path, sample,
                                                     extra):
    registry = RefDBRegistry(root=tmp_path / "r")
    registry.create("food", sample.genomes, _config(backend="reference"))
    router = TenantRouter(registry)
    router.add_tenant("acme", database="food")
    assert registry.pins("food") == {1: 1}          # served -> pinned
    srcs = _slices(sample, 2)
    h = router.submit(srcs[0], tenant="acme")
    registry.apply_delta("food", add=extra)         # swap; v1 drains
    # both versions are held: v1 draining h, v2 serving new admissions
    assert registry.gc("food", keep_last=1).collected == ()
    router.run_until_idle()
    h.result(timeout=300)
    router.step()                                   # retire drained v1
    assert registry.pins("food") == {2: 1}
    assert registry.gc("food", keep_last=1).collected == (("food", 1),)
    router.close()
