"""Tile autotuner: cache round-trip, determinism (cache hits never
re-measure), VMEM feasibility filtering, and tuned-config bit-exactness
through session / sharded / ProfilingService."""

import json
import os

import pytest

from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.kernels import autotune
from repro.pipeline import ProfilerConfig, ProfilingSession, SyntheticSource

SP = HDSpace(dim=256, ngram=4, z_threshold=3.0)


def _tune(path, **kw):
    kw.setdefault("batch", 8)
    kw.setdefault("num_prototypes", 20)
    kw.setdefault("read_len", 64)
    kw.setdefault("trials", 1)
    return autotune.tune(SP, path=path, **kw)


# -- cache behaviour --------------------------------------------------------

def test_cache_round_trip(tmp_path):
    p = tmp_path / "cache.json"
    tiles, cached = _tune(p)
    assert not cached and set(tiles) == {"bb", "bw", "bs"}
    data = json.loads(p.read_text())
    key = autotune.cache_key(8, SP.num_words, 20, SP.dim)
    assert data[key]["tiles"] == tiles
    assert data[key]["swept"] >= 1


def test_same_key_reuses_without_remeasuring(tmp_path, monkeypatch):
    p = tmp_path / "cache.json"
    tiles, _ = _tune(p)

    def boom(*a, **k):
        raise AssertionError("cache hit must not re-measure")

    monkeypatch.setattr(autotune, "_time_plan", boom)
    tiles2, cached = _tune(p)
    assert cached and tiles2 == tiles


def test_force_remeasures_and_updates_cache(tmp_path):
    """Determinism lives in the cache: without --force a key never
    re-measures; with it, the sweep reruns and the cache is replaced."""
    p = tmp_path / "cache.json"
    _tune(p)
    tiles2, cached = _tune(p, force=True)
    assert not cached and set(tiles2) == {"bb", "bw", "bs"}
    key = autotune.cache_key(8, SP.num_words, 20, SP.dim)
    assert json.loads(p.read_text())[key]["tiles"] == tiles2


def test_corrupt_cache_is_an_empty_cache(tmp_path):
    p = tmp_path / "cache.json"
    p.write_text("{not json")
    assert autotune.load_cache(p) == {}
    tiles, cached = _tune(p)                  # tunes + rewrites atomically
    assert not cached and json.loads(p.read_text())


def test_env_var_overrides_cache_location(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "env.json"))
    assert autotune.cache_path() == tmp_path / "env.json"
    assert autotune.cache_path(tmp_path / "x.json") == tmp_path / "x.json"


def test_distinct_shapes_get_distinct_keys():
    keys = {autotune.cache_key(*a) for a in
            [(8, 8, 20, 256), (16, 8, 20, 256), (8, 16, 20, 512),
             (8, 8, 40, 256)]}
    assert len(keys) == 4


# -- feasibility filter -----------------------------------------------------

def test_vmem_filter_drops_oversized_plans(tmp_path):
    plans = autotune.candidate_plans(64, 5000, 512)
    cost = dict(read_len=1024, n=8)
    budget = 2 ** 20
    feasible = [p for p in plans if autotune.vmem_bytes(p, **cost) <= budget]
    dropped = [p for p in plans if autotune.vmem_bytes(p, **cost) > budget]
    assert dropped, "sweep must contain plans a 1 MiB budget rejects"
    assert all(autotune.vmem_bytes(p, **cost) <= budget for p in feasible)


def test_degenerate_budget_still_tunes(tmp_path):
    # budget=1 rejects everything; tune falls back to the leanest plan
    tiles, cached = _tune(tmp_path / "c.json", budget=1)
    assert not cached and tiles["bs"] >= 128


# -- tuned-config parity through the pipeline -------------------------------

@pytest.fixture(scope="module")
def pipeline_setup(tmp_path_factory):
    space = HDSpace(dim=512, ngram=5, z_threshold=3.0)
    spec = synth.CommunitySpec(num_species=3, genome_len=4_000, seed=5)
    sample = SyntheticSource(spec, num_reads=24, present=[0, 1])
    cache = str(tmp_path_factory.mktemp("tuner") / "tuner.json")

    def cfg(backend, **kw):
        return ProfilerConfig(space=space, window=256, batch_size=8,
                              backend=backend, **kw)

    ref = ProfilingSession(cfg("reference"))
    ref.build_refdb(sample.genomes)
    expected = ref.profile(sample).to_json()
    return cfg, sample, cache, expected


def test_tuned_session_parity_and_cache_reuse(pipeline_setup):
    cfg, sample, cache, expected = pipeline_setup
    opts = {"autotune": True, "autotune_cache": cache}
    s = ProfilingSession(cfg("pallas_fused", backend_options=opts))
    s.build_refdb(sample.genomes)
    assert s.profile(sample).to_json() == expected
    assert os.path.exists(cache), "first profiled batch persists the sweep"
    tuned = s.backend.tiles
    # a second session reuses the cached choice (deterministic, no sweep)
    s2 = ProfilingSession(cfg("pallas_fused", backend_options=opts))
    s2.build_refdb(sample.genomes)
    assert s2.profile(sample).to_json() == expected
    assert {k: s2.backend.tiles[k] for k in ("bb", "bw", "bs")} == \
        {k: tuned[k] for k in ("bb", "bw", "bs")}


def test_tuned_sharded_parity(pipeline_setup):
    """`sharded` forwards non-own options to its base, so autotune flows
    through to the fused shards untouched."""
    cfg, sample, cache, expected = pipeline_setup
    s = ProfilingSession(cfg("sharded", backend_options={
        "base": "pallas_fused", "autotune": True, "autotune_cache": cache}))
    s.build_refdb(sample.genomes)
    assert s.profile(sample).to_json() == expected


def test_tuned_service_parity(pipeline_setup):
    from repro.serve.profiler_service import ProfilingService
    cfg, sample, cache, expected = pipeline_setup
    s = ProfilingSession(cfg("pallas_fused", backend_options={
        "autotune": True, "autotune_cache": cache}))
    s.build_refdb(sample.genomes)
    service = ProfilingService(s, max_active=2)
    h = service.submit(sample)
    service.run_until_idle()
    assert h.result(timeout=60).to_json() == expected
