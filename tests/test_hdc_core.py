"""HDC core: encoder equivalences, classifier semantics, abundance math."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (HDSpace, abundance, assoc_memory, bitops, classifier,
                        encoder, item_memory)
from repro.core import UNMAPPED, UNIQUE, MULTI


SP = HDSpace(dim=1024, ngram=6, z_threshold=3.0)


def _im():
    return item_memory.make_item_memory(SP), item_memory.make_tie_break(SP)


def test_rolling_encoder_matches_gather_encoder():
    """The O(1)-per-position recurrence == direct Eq.1 evaluation."""
    im, tie = _im()
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 4, (5, 40)), jnp.int32)
    lens = jnp.asarray([40, 40, 17, 6, 40], jnp.int32)

    im_rolled = item_memory.rolled(im, SP.ngram)
    grams = encoder.encode_grams(toks, im_rolled)
    m = np.maximum(np.asarray(lens) - SP.ngram + 1, 0)
    bits = np.asarray(bitops.unpack_bits(grams)).astype(np.int64)
    counts_want = np.zeros((5, SP.dim), np.int64)
    for b in range(5):
        counts_want[b] = bits[b, :m[b]].sum(axis=0)

    im_last = bitops.rho(im, SP.ngram - 1)
    counts, mm = encoder.bundle_counts(toks, lens, im, im_last,
                                       n=SP.ngram, dim=SP.dim)
    np.testing.assert_array_equal(np.asarray(mm), m)
    np.testing.assert_array_equal(np.asarray(counts), counts_want)


def test_gram_equals_eq1_binding():
    """gram_0 == B[c0] ^ rho(B[c1]) ^ ... ^ rho^{n-1}(B[c_{n-1}])."""
    im, _ = _im()
    toks = jnp.asarray([[0, 1, 2, 3, 2, 1]], jnp.int32)
    im_rolled = item_memory.rolled(im, 6)
    gram = encoder.encode_grams(toks, im_rolled)[0, 0]
    want = im[0]
    for j in range(1, 6):
        want = jnp.bitwise_xor(want, bitops.rho(im[toks[0, j]], j))
    np.testing.assert_array_equal(np.asarray(gram), np.asarray(want))


def test_majority_tie_break():
    counts = jnp.asarray([[0, 1, 2, 1]], jnp.int32)  # m=2: 0<1, 1==tie, 2>1
    tie = bitops.pack_bits(jnp.asarray([[1, 0, 1, 1] + [0] * 28], jnp.uint8))[0]
    m = jnp.asarray([2], jnp.int32)
    packed = encoder.binarize_majority(
        jnp.pad(counts, ((0, 0), (0, 28))), m, tie)
    bits = np.asarray(bitops.unpack_bits(packed))[0, :4]
    np.testing.assert_array_equal(bits, [0, 0, 1, 1])


def test_agreement_formulations_match():
    key = jax.random.key(2)
    q = bitops.random_packed(key, (6,), SP.dim)
    p = bitops.random_packed(jax.random.key(3), (9,), SP.dim)
    a1 = assoc_memory.agreement_matmul(q, p, SP.dim)
    a2 = assoc_memory.agreement_packed_chunked(q, p, SP.dim, chunk=4)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_classifier_categories():
    """Unique / multi / unmapped reads are assigned the right category."""
    from repro.core.assoc_memory import RefDB
    key = jax.random.key(4)
    protos = bitops.random_packed(key, (3,), SP.dim)
    db = RefDB(prototypes=protos,
               proto_species=jnp.asarray([0, 1, 2]),
               genome_lengths=jnp.asarray([1000, 1000, 1000]),
               num_species=3, species_names=("a", "b", "c"))
    # query 0 == prototype 0 (unique); query 1 == p1 with p2 duplicated
    # below; query 2 random (unmapped)
    db_multi = RefDB(prototypes=jnp.concatenate([protos, protos[1:2]]),
                     proto_species=jnp.asarray([0, 1, 2, 2]),
                     genome_lengths=db.genome_lengths, num_species=3,
                     species_names=db.species_names)
    q = jnp.stack([protos[0], protos[1],
                   bitops.random_packed(jax.random.key(99), (), SP.dim)])
    res = classifier.classify(q, db_multi, SP)
    cat = np.asarray(res.category)
    assert cat[0] == UNIQUE
    assert cat[1] == MULTI        # species 1 and 2 share the prototype
    assert cat[2] == UNMAPPED


def test_abundance_proportional_split():
    # 3 species; 4 unique reads on s0, 2 on s1; 2 multi reads {s0, s1}.
    hits = np.zeros((8, 3), bool)
    hits[0:4, 0] = True
    hits[4:6, 1] = True
    hits[6:8, [0]] = True
    hits[6:8, [1]] = True
    cat = np.array([UNIQUE] * 6 + [MULTI] * 2, np.int32)
    lens = np.array([100, 100, 100])
    res = abundance.estimate(jnp.asarray(hits), jnp.asarray(cat),
                             jnp.asarray(lens))
    # rates: s0 = 4/100, s1 = 2/100 -> multi splits 2/3 vs 1/3
    want0 = (4 + 2 * (4 / 6)) / 8
    want1 = (2 + 2 * (2 / 6)) / 8
    np.testing.assert_allclose(np.asarray(res.abundance),
                               [want0, want1, 0.0], atol=1e-6)
    assert float(res.unmapped_fraction) == 0.0


def test_abundance_uniform_fallback():
    # multi read over species with zero unique support -> uniform split
    hits = np.zeros((1, 2), bool)
    hits[0] = [True, True]
    cat = np.array([MULTI], np.int32)
    res = abundance.estimate(jnp.asarray(hits), jnp.asarray(cat),
                             jnp.asarray([50, 50]))
    np.testing.assert_allclose(np.asarray(res.abundance), [0.5, 0.5])
