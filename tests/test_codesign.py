"""Noise-aware RefDB co-design: write-verify programming + retraining.

Covers the two stages of :func:`repro.accel.codesign.noise_aware_refdb`:
the fault-aware programming pass (:func:`repro.accel.crossbar
.write_verify_bits`) that probes the simulated device and re-chooses the
stored bits, and the validation-gated margin retraining on top.  The
headline property — a shift-faulted racetrack AM recovering reads that
the naive build loses — is pinned both at the crossbar level (exact
pre-compensation) and end to end through ``ProfilerConfig``.
"""

import dataclasses

import numpy as np
import pytest

from repro.accel.backend_pcm import split_options
from repro.accel.codesign import noise_aware_refdb
from repro.accel.crossbar import crossbar_agreement, write_verify_bits
from repro.core.hd_space import HDSpace
from repro.pipeline.backend import resolve_backend
from repro.pipeline.config import ProfilerConfig

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)


def _config(backend="racetrack_sim", **options):
    return ProfilerConfig(space=SP, window=512, batch_size=32,
                          backend=backend, backend_options=options)


@pytest.fixture(scope="module")
def community():
    rng = np.random.default_rng(11)
    genomes = {f"s{i}": rng.integers(0, 4, 6000).astype(np.int32)
               for i in range(4)}
    toks = np.stack([np.asarray(g)[200 + 37 * i:200 + 37 * i + 96]
                     for i, g in enumerate(genomes.values())] * 8)
    lens = np.full(len(toks), 96, np.int32)
    labels = np.tile(np.arange(4), 8)
    return genomes, toks, lens, labels


def test_write_verify_is_identity_on_ideal_substrate():
    xcfg, sub = split_options({}, backend="racetrack_sim",
                              default_substrate="racetrack")
    rng = np.random.default_rng(0)
    ref = resolve_backend("reference", _config(backend="reference"))
    protos = ref.encode(rng.integers(0, 4, (6, 128), np.int32),
                        np.full(6, 128, np.int32))
    assert write_verify_bits(protos, xcfg, sub) is protos


def test_write_verify_precompensates_misaligned_tracks(community):
    """With *every* track misaligned and no other fault, pre-rolling the
    stored content recovers most of the readout error.  (Not all of it:
    the positive and complement banks draw independent fault maps, and a
    dim whose two tracks are misaligned in *different* directions can
    only be pre-compensated for one bank — the tie keeps the content
    bit, halving that track's error instead of zeroing it.)"""
    genomes, toks, lens, _ = community
    ref = resolve_backend("reference", _config(backend="reference"))
    q = ref.encode(toks, lens)
    protos = ref.encode(
        np.stack([np.asarray(g)[:512] for g in genomes.values()]),
        np.full(4, 512, np.int32))
    expect = np.asarray(ref.agreement(q, protos))

    xcfg, sub = split_options({"shift_fault_rate": 1.0, "seed": 2},
                              backend="racetrack_sim",
                              default_substrate="racetrack")
    naive = np.asarray(crossbar_agreement(q, protos, SP.dim, xcfg, sub))
    assert (naive != expect).any()          # the faults actually bite
    fixed = write_verify_bits(protos, xcfg, sub)
    assert (np.asarray(fixed) != np.asarray(protos)).any()
    naive_err = np.abs(naive - expect).mean()
    fixed_err = np.abs(
        np.asarray(crossbar_agreement(q, fixed, SP.dim, xcfg, sub))
        - expect).mean()
    assert fixed_err < 0.6 * naive_err


def test_noise_aware_refdb_improves_shift_faulted_readout(community):
    """End to end at the benchmark's sweep point: the noise-aware build
    raises the own-species agreement the faulty device reads out."""
    genomes, toks, lens, labels = community
    config = _config(shift_fault_rate=0.5, seed=3)
    from repro.pipeline import ProfilingSession
    session = ProfilingSession(config)
    db = session.build_refdb(genomes)
    be = resolve_backend(config.backend, config)
    q = be.encode(toks, lens)

    def own_score(refdb):
        agree = np.asarray(be.agreement(q, refdb.prototypes))
        own = np.where(np.asarray(refdb.proto_species)[None, :]
                       == labels[:, None], agree, -1)
        return own.max(axis=1).mean()

    refined = noise_aware_refdb(db, genomes, config, iterations=1,
                                reads_per_species=16, read_len=64)
    assert refined.species_names == db.species_names
    assert refined.num_species == db.num_species
    assert (np.asarray(refined.prototypes)
            != np.asarray(db.prototypes)).any()
    assert own_score(refined) > own_score(db)


def test_noise_aware_refdb_keeps_metadata_on_digital_backend(community):
    genomes, _, _, _ = community
    config = _config(backend="reference")
    from repro.pipeline import ProfilingSession
    db = ProfilingSession(config).build_refdb(genomes)
    out = noise_aware_refdb(db, genomes, config, iterations=1,
                            reads_per_species=8, read_len=64)
    assert out.prototypes.shape == db.prototypes.shape
    assert out.species_names == db.species_names
    np.testing.assert_array_equal(np.asarray(out.genome_lengths),
                                  np.asarray(db.genome_lengths))


def test_noise_aware_fingerprint_is_distinct():
    base = _config(shift_fault_rate=0.5, seed=3)
    aware = dataclasses.replace(base, noise_aware_refdb=True)
    aware2 = dataclasses.replace(aware, noise_aware_iters=5)
    prints = {c.refdb_fingerprint() for c in (base, aware, aware2)}
    assert len(prints) == 3


def test_noise_aware_refdb_rejects_bad_inputs(community):
    genomes, _, _, _ = community
    config = _config(shift_fault_rate=0.5)
    from repro.pipeline import ProfilingSession
    db = ProfilingSession(config).build_refdb(genomes)
    with pytest.raises(ValueError, match="iterations"):
        noise_aware_refdb(db, genomes, config, iterations=0)
    with pytest.raises(KeyError, match="missing"):
        noise_aware_refdb(db, {"s0": genomes["s0"]}, config)
