"""Acc-Demeter device-model subsystem: zero-noise bit-exactness vs the
digital reference, seeded determinism of the noisy path, crossbar tiling
edge cases, backend_options plumbing, the cost model, and the sweep
harness."""

import numpy as np
import pytest

from repro.accel import (CrossbarConfig, DeviceConfig, accel_cost,
                         adc_quantize, noise_sweep)
from repro.core.hd_space import HDSpace
from repro.genomics import synth
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession,
                            available_backends, resolve_backend)

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)


def _config(**kw):
    kw.setdefault("space", SP)
    kw.setdefault("window", 1024)
    kw.setdefault("batch_size", 16)
    kw.setdefault("backend", "pcm_sim")
    return ProfilerConfig(**kw)


@pytest.fixture(scope="module")
def packed():
    """(queries, prototypes, reference agreement) on the shared space."""
    ref = resolve_backend("reference", _config(backend="reference"))
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 4, (16, 64)).astype(np.int32)
    lens = np.full(16, 64, np.int32)
    q = np.asarray(ref.encode(toks, lens))
    protos = q[:7]                       # S=7: not a multiple of anything
    return q, protos, np.asarray(ref.agreement(q, protos))


# -- zero-noise bit-exactness ----------------------------------------------

def test_pcm_sim_registered():
    assert "pcm_sim" in available_backends()


def test_zero_noise_matches_reference_exactly(packed):
    q, protos, a_ref = packed
    be = resolve_backend("pcm_sim", _config())
    np.testing.assert_array_equal(np.asarray(be.agreement(q, protos)), a_ref)


@pytest.mark.parametrize("rows,cols", [(64, 4), (100, 3), (512, 256),
                                       (1024, 7)])
def test_tiling_edge_cases_stay_exact(packed, rows, cols):
    """Partial tiles (S % cols != 0, dim % rows != 0, oversize arrays)
    must not leak padding into the agreement."""
    q, protos, a_ref = packed
    be = resolve_backend(
        "pcm_sim", _config().with_options(rows=rows, cols=cols,
                                          adc_bits=11))
    np.testing.assert_array_equal(np.asarray(be.agreement(q, protos)), a_ref)


def test_single_prototype_exact(packed):
    q, protos, a_ref = packed
    be = resolve_backend("pcm_sim", _config())
    got = np.asarray(be.agreement(q, protos[:1]))
    np.testing.assert_array_equal(got, a_ref[:, :1])


def test_lossy_adc_quantizes_but_stays_in_range(packed):
    q, protos, a_ref = packed
    be = resolve_backend("pcm_sim", _config().with_options(adc_bits=4))
    got = np.asarray(be.agreement(q, protos))
    assert not np.array_equal(got, a_ref)           # 15 levels < 256 counts
    assert got.min() >= 0 and got.max() <= SP.dim
    # self-agreement stays within half an ADC step per partial count
    # (2 row tiles x 2 banks, step = rows / 15 at 4 bits)
    step = 256 / 15
    assert np.diag(got[:7]).min() >= SP.dim - 4 * (step / 2) - 1


# -- seeded determinism of the noisy path ----------------------------------

def test_noisy_path_is_deterministic_per_seed(packed):
    q, protos, a_ref = packed
    cfg = _config().with_options(preset="pcm", seed=11)
    a1 = np.asarray(resolve_backend("pcm_sim", cfg).agreement(q, protos))
    a2 = np.asarray(resolve_backend("pcm_sim", cfg).agreement(q, protos))
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(a1, a_ref)            # noise really applied
    a3 = np.asarray(resolve_backend(
        "pcm_sim", _config().with_options(preset="pcm", seed=12)
    ).agreement(q, protos))
    assert not np.array_equal(a1, a3)               # seed is load-bearing


def test_read_noise_keyed_by_batch_content(packed):
    """The read-event key folds in a batch digest: replaying a batch
    reproduces its noise exactly, while the same query read in a different
    batch context draws a fresh noise sample."""
    q, protos, _ = packed
    be = resolve_backend("pcm_sim", _config().with_options(read_sigma=0.5))
    a_first = np.asarray(be.agreement(q, protos))
    a_again = np.asarray(be.agreement(q, protos))
    np.testing.assert_array_equal(a_first, a_again)     # replay == replay
    a_sub = np.asarray(be.agreement(q[:8], protos))     # different digest
    assert not np.array_equal(a_sub, a_first[:8])


def test_stuck_on_saturates_agreement(packed):
    """All cells pinned ON: both banks read back their full active-row
    count, so every agreement clips to exactly dim."""
    q, protos, _ = packed
    be = resolve_backend("pcm_sim", _config().with_options(stuck_on_rate=1.0))
    np.testing.assert_array_equal(np.asarray(be.agreement(q, protos)),
                                  np.full((16, 7), SP.dim, np.int32))


def test_uncalibrated_drift_reads_low(packed):
    q, protos, a_ref = packed
    be = resolve_backend("pcm_sim", _config().with_options(
        drift_nu=0.05, drift_t_s=86_400.0, drift_calibration=0.0))
    got = np.asarray(be.agreement(q, protos))
    assert got.mean() < a_ref.mean() * 0.75
    # perfect calibration restores bit-exactness
    be2 = resolve_backend("pcm_sim", _config().with_options(
        drift_nu=0.05, drift_t_s=86_400.0, drift_calibration=1.0))
    np.testing.assert_array_equal(np.asarray(be2.agreement(q, protos)),
                                  a_ref)


# -- backend_options plumbing ----------------------------------------------

def test_options_canonicalized_and_hashable():
    cfg = _config(backend_options={"read_sigma": 0.1, "adc_bits": 8})
    assert cfg.backend_options == (("adc_bits", 8), ("read_sigma", 0.1))
    assert hash(cfg) == hash(_config(
        backend_options=[("read_sigma", 0.1), ("adc_bits", 8)]))
    assert cfg.options == {"adc_bits": 8, "read_sigma": 0.1}


def test_options_json_roundtrip_and_fingerprint():
    cfg = _config(backend_options={"preset": "pcm", "seed": 3})
    back = ProfilerConfig.from_json(cfg.to_json())
    assert back == cfg
    assert cfg.fingerprint() != _config().fingerprint()
    # options are a host/substrate knob: the RefDB cache key ignores them
    assert cfg.refdb_fingerprint() == _config().refdb_fingerprint()


def test_with_options_merges():
    cfg = _config(backend_options={"read_sigma": 0.1})
    out = cfg.with_options(prog_sigma=0.2, read_sigma=0.3)
    assert out.options == {"read_sigma": 0.3, "prog_sigma": 0.2}


def test_invalid_options_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        _config(backend_options=[("a", 1), ("a", 2)])
    with pytest.raises(ValueError, match="JSON primitive"):
        _config(backend_options={"a": [1, 2]})
    with pytest.raises(ValueError, match="non-empty string"):
        _config(backend_options={"": 1})


def test_unknown_pcm_option_and_preset_rejected():
    with pytest.raises(ValueError,
                       match="pcm_sim got unknown option 'nonsense'"):
        resolve_backend("pcm_sim", _config().with_options(nonsense=1))
    with pytest.raises(ValueError, match="'preset' must be one of"):
        resolve_backend("pcm_sim", _config().with_options(preset="tpu"))
    # Cross-substrate knobs fail at the narrowed (per-substrate) schema.
    with pytest.raises(ValueError, match=r"substrate=pcm.*shift_fault_rate"
                                         r"|shift_fault_rate"):
        resolve_backend("pcm_sim",
                        _config().with_options(shift_fault_rate=0.1))


def test_mistyped_option_values_rejected():
    """CLI typos (e.g. --backend-option rows=abc) must surface as
    ValueErrors naming the option, not tracebacks from inside jax."""
    with pytest.raises(ValueError, match="'rows' must be an integer"):
        resolve_backend("pcm_sim", _config().with_options(rows="abc"))
    with pytest.raises(ValueError, match="'seed' must be an integer"):
        resolve_backend("pcm_sim", _config().with_options(seed=1.5))
    with pytest.raises(ValueError, match="'read_sigma' must be a number"):
        resolve_backend("pcm_sim", _config().with_options(read_sigma="x"))


def test_prototypes_programmed_once_per_array(packed):
    """Write-once discipline: repeated agreement calls against the same
    prototype array must not reprogram the conductance banks."""
    q, protos, a_ref = packed
    be = resolve_backend("pcm_sim", _config())
    calls = []
    real = be._program
    be._program = lambda p: (calls.append(1), real(p))[1]
    for _ in range(3):
        np.testing.assert_array_equal(np.asarray(be.agreement(q, protos)),
                                      a_ref)
    assert len(calls) == 1
    be.agreement(q, protos[:3].copy())      # new array object: reprograms
    assert len(calls) == 2


def test_device_config_validation():
    with pytest.raises(ValueError):
        DeviceConfig(g_on_us=1.0, g_off_us=2.0)
    with pytest.raises(ValueError):
        DeviceConfig(prog_sigma=-0.1)
    with pytest.raises(ValueError):
        DeviceConfig(stuck_on_rate=0.7, stuck_off_rate=0.7)
    with pytest.raises(ValueError):
        CrossbarConfig(adc_bits=0)
    assert DeviceConfig().is_ideal
    assert not DeviceConfig.pcm().is_ideal


# -- ADC model --------------------------------------------------------------

def test_adc_lossless_is_identity_on_counts():
    import jax.numpy as jnp
    cfg = CrossbarConfig(rows=256, adc_bits=9)
    assert cfg.lossless
    counts = jnp.arange(257.0)
    np.testing.assert_array_equal(np.asarray(adc_quantize(counts, cfg)),
                                  np.asarray(counts))


def test_adc_lossy_snaps_to_grid():
    import jax.numpy as jnp
    cfg = CrossbarConfig(rows=256, adc_bits=4)
    assert not cfg.lossless
    out = np.asarray(adc_quantize(jnp.arange(257.0), cfg))
    assert len(np.unique(out)) <= 16


# -- cost model -------------------------------------------------------------

def test_cost_model_breakdown_consistent():
    c = accel_cost(num_protos=100, dim=2048, read_len=150, ngram=16,
                   xcfg=CrossbarConfig(rows=256, cols=256))
    assert c.num_arrays == 2 * 8 * 1                # ceil ratios, two banks
    assert c.total_pj == pytest.approx(
        sum(pj for _, pj, _ in c.energy_rows()))
    assert sum(pct for _, _, pct in c.energy_rows()) == pytest.approx(100.0)
    assert c.total_area_mm2 > 0 and c.latency_ns > 0
    assert c.mbp_per_joule(150) > 0
    # more prototypes -> more arrays, more energy
    c2 = accel_cost(num_protos=1000, dim=2048, read_len=150, ngram=16)
    assert c2.num_arrays > c.num_arrays
    assert c2.total_pj > c.total_pj


# -- sweep harness ----------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_community():
    spec = synth.CommunitySpec(num_species=3, genome_len=4_000, seed=5)
    genomes = synth.make_reference_genomes(spec)
    ab = np.array([0.5, 0.5, 0.0])
    toks, lens, _ = synth.sample_reads(genomes, ab, 64, spec)
    return genomes, toks, lens, ab


def test_noise_sweep_zero_level_matches_reference(tiny_community):
    genomes, toks, lens, ab = tiny_community
    points = noise_sweep(genomes, toks, lens, ab, config=_config(),
                         knob="read_sigma", levels=(0.0, 0.3))
    assert [p.value for p in points] == [0.0, 0.3]

    ref = ProfilingSession(_config(backend="reference"))
    ref.build_refdb(genomes)
    rep = ref.profile(ArraySource(toks, lens))
    np.testing.assert_array_equal(points[0].report.abundance, rep.abundance)
    assert 0.0 <= points[0].metrics.precision <= 1.0
    assert 0.0 <= points[0].unmapped_frac <= 1.0


def test_noise_sweep_rejects_unknown_knob(tiny_community):
    genomes, toks, lens, ab = tiny_community
    with pytest.raises(ValueError, match="unknown sweep knob"):
        noise_sweep(genomes, toks, lens, ab, config=_config(),
                    knob="voltage", levels=(1.0,))
