"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bit-exact."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import item_memory
from repro.core.hd_space import HDSpace
from repro.kernels import ops, ref
from repro.kernels.am_matmul import am_matmul
from repro.kernels.hamming_am import hamming_am

RNG = np.random.default_rng(42)


def _rand_packed(b, w):
    return jnp.asarray(RNG.integers(0, 2**32, (b, w), dtype=np.uint32))


@pytest.mark.parametrize("b,s,w", [(8, 16, 64), (16, 128, 128),
                                   (8, 128, 40), (4, 300, 64), (128, 8, 8)])
def test_am_agreement_sweep(b, s, w):
    q, p = _rand_packed(b, w), _rand_packed(s, w)
    want = np.asarray(ref.hamming_am_ref(q, p))
    got_m = np.asarray(ops.am_agreement(q, p, 32 * w, "matmul"))
    got_p = np.asarray(ops.am_agreement(q, p, 32 * w, "packed"))
    np.testing.assert_array_equal(got_m, want)
    np.testing.assert_array_equal(got_p, want)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 128), (4, 16, 256)])
def test_am_matmul_blockings(bm, bn, bk):
    q, p = _rand_packed(8, 16), _rand_packed(16, 16)
    qpm, ppm = ops.to_pm1(q), ops.to_pm1(p)
    got = np.asarray(am_matmul(qpm, ppm, bm=bm, bn=bn, bk=bk))
    want = np.asarray(ref.am_matmul_ref(qpm, ppm))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("bm,bn,bw", [(4, 8, 8), (8, 16, 16)])
def test_hamming_am_blockings(bm, bn, bw):
    q, p = _rand_packed(8, 32), _rand_packed(16, 32)
    got = np.asarray(hamming_am(q, p, bm=bm, bn=bn, bw=bw))
    want = np.asarray(ref.hamming_am_ref(q, p))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dim,n,length", [(1024, 4, 24), (2048, 8, 40),
                                          (512, 2, 9), (512, 6, 5)])
def test_encoder_kernel_sweep(dim, n, length):
    sp = HDSpace(dim=dim, ngram=n)
    im = item_memory.make_item_memory(sp)
    tie = item_memory.make_tie_break(sp)
    imr = item_memory.rolled(im, n)
    toks = jnp.asarray(RNG.integers(0, 4, (8, length), dtype=np.int32))
    lens = jnp.asarray(RNG.integers(0, length + 1, 8, dtype=np.int32))
    want = np.asarray(ref.hdc_encode_ref(toks, lens, imr, tie))
    got = np.asarray(ops.hdc_encode(toks, lens, im, tie, sp))
    np.testing.assert_array_equal(got, want)


def test_encoder_kernel_batch_padding():
    """Non-multiple-of-8 batch is padded and sliced back."""
    sp = HDSpace(dim=512, ngram=3)
    im = item_memory.make_item_memory(sp)
    tie = item_memory.make_tie_break(sp)
    toks = jnp.asarray(RNG.integers(0, 4, (5, 12), dtype=np.int32))
    lens = jnp.full((5,), 12, jnp.int32)
    got = ops.hdc_encode(toks, lens, im, tie, sp)
    want = ref.hdc_encode_ref(toks, lens, item_memory.rolled(im, 3), tie)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_profiler_integration():
    """The pallas_matmul backend == the reference backend end-to-end."""
    from repro.pipeline import ProfilerConfig, ProfilingSession
    sp = HDSpace(dim=512, ngram=5, z_threshold=3.0)
    rng = np.random.default_rng(0)
    genomes = {f"s{i}": rng.integers(0, 4, 3000).astype(np.int32)
               for i in range(3)}
    s0 = ProfilingSession(ProfilerConfig(
        space=sp, window=1024, batch_size=16, backend="reference"))
    s1 = ProfilingSession(ProfilerConfig(
        space=sp, window=1024, batch_size=16, backend="pallas_matmul"))
    db0, db1 = s0.build_refdb(genomes), s1.build_refdb(genomes)
    np.testing.assert_array_equal(np.asarray(db0.prototypes),
                                  np.asarray(db1.prototypes))
    toks = rng.integers(0, 4, (16, 60)).astype(np.int32)
    lens = np.full(16, 60, np.int32)
    q0 = s0.encode_reads(toks, lens)
    q1 = s1.encode_reads(toks, lens)
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
    r0 = s0.classify_queries(q0, db0)
    r1 = s1.classify_queries(q1, db1)
    np.testing.assert_array_equal(np.asarray(r0.scores), np.asarray(r1.scores))
