"""Baseline profilers: correctness on an easy community + memory ordering."""

import numpy as np
import pytest

from repro.baselines import ClarkLike, Kraken2Like, MetaCacheLike, bracken_like
from repro.core import HDSpace
from repro.eval import read_level_accuracy, score_profile
from repro.genomics import synth
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession)

SPEC = synth.CommunitySpec(num_species=6, genome_len=20_000,
                           homology_fraction=0.0, strain_snp_rate=0.0,
                           read_error_rate=0.0, seed=11)


@pytest.fixture(scope="module")
def community():
    return synth.make_sample(SPEC, num_reads=300, present=[0, 2, 4])


@pytest.mark.parametrize("baseline", [Kraken2Like(k=21), MetaCacheLike(),
                                      ClarkLike(k=21)])
def test_baseline_profile_accuracy(community, baseline):
    genomes, toks, lens, truth, true_ab = community
    glens = np.array([len(g) for g in genomes.values()])
    baseline.build(genomes)
    hits, cat = baseline.classify_reads(toks, lens)
    assert read_level_accuracy(hits, cat, truth) > 0.9
    res = bracken_like.estimate_abundance(hits, cat, glens)
    m = score_profile(np.asarray(res.abundance), true_ab)
    assert m.precision == 1.0 and m.recall == 1.0, m.row()


def test_clark_discards_shared_kmers():
    rng = np.random.default_rng(0)
    shared = rng.integers(0, 4, 2000).astype(np.int32)
    g = {"a": shared, "b": shared.copy()}   # fully homologous
    c = ClarkLike(k=21).build(g)
    assert len(c.table.hashes) == 0         # nothing is discriminative


def test_memory_ordering_demeter_smallest(community):
    genomes, *_ = community
    k = Kraken2Like(k=21).build(genomes)
    m = MetaCacheLike().build(genomes)
    dm = ProfilingSession(ProfilerConfig(
        space=HDSpace(dim=4096, ngram=16), window=4096))
    db = dm.build_refdb(genomes)
    assert db.memory_bytes() < m.memory_bytes() < k.memory_bytes()
    # paper's headline: order-of-magnitude+ vs kraken-like tables
    assert k.memory_bytes() / db.memory_bytes() > 10


def test_demeter_beats_threshold_on_easy_community(community):
    genomes, toks, lens, truth, true_ab = community
    dm = ProfilingSession(ProfilerConfig(
        space=HDSpace(dim=8192, ngram=16, z_threshold=5.0), window=4096,
        batch_size=64))
    db = dm.build_refdb(genomes)
    rep = dm.profile(ArraySource(toks, lens), refdb=db)
    m = score_profile(rep.abundance, true_ab)
    assert m.precision == 1.0 and m.recall == 1.0, m.row()
    assert m.l1_error < 0.15
