"""Shared substrate contract: every registered substrate, one rulebook.

Parametrized over ``repro.accel.substrate.available_substrates()`` so a
newly registered substrate is automatically held to the same contract:

* zero-noise bit-exactness — the ideal device is indistinguishable from
  the ``reference`` backend, bit for bit;
* seeded determinism — same seed, same answers; different seed,
  different noise;
* fault census — programmed fault populations (stuck cells, misaligned
  tracks) are counted and reproducible;
* options-schema round-trip — every declared option validates at its
  default and survives CLI string coercion.

Plus the cross-backend half of the options satellite: a misspelled
option fails with the *same* friendly error on every registered backend.
"""

import dataclasses

import numpy as np
import pytest

from repro.accel.substrate import (available_substrates, narrowed_schema,
                                   resolve_substrate, substrate_options)
from repro.core.hd_space import HDSpace
from repro.pipeline.backend import (available_backends, options_schema,
                                    resolve_backend)
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.options import OptionError

SP = HDSpace(dim=512, ngram=5, z_threshold=3.0)

#: options that force a visible, countable fault population per substrate
FAULT_OPTIONS = {
    "pcm": {"stuck_on_rate": 0.5, "stuck_off_rate": 0.25},
    "racetrack": {"stuck_on_rate": 0.5, "stuck_off_rate": 0.25,
                  "shift_fault_rate": 0.5},
}
#: fault-census keys each substrate must report
CENSUS_KEYS = {
    "pcm": {"on", "off"},
    "racetrack": {"on", "off", "misaligned"},
}


def _config(backend="pcm_sim", **options):
    return ProfilerConfig(space=SP, window=1024, batch_size=16,
                          backend=backend, backend_options=options)


@pytest.fixture(scope="module")
def workload():
    ref = resolve_backend("reference", _config(backend="reference"))
    rng = np.random.default_rng(7)
    toks = rng.integers(0, 4, (12, 96), np.int32)
    lens = np.full(12, 96, np.int32)
    q = ref.encode(toks, lens)
    protos = ref.encode(rng.integers(0, 4, (6, 96), np.int32),
                        np.full(6, 96, np.int32))
    return q, protos, np.asarray(ref.agreement(q, protos))


def test_substrate_registry_is_populated():
    assert {"pcm", "racetrack"} <= set(available_substrates())


@pytest.mark.parametrize("substrate", available_substrates())
@pytest.mark.parametrize("carrier", ["pcm_sim", "racetrack_sim"])
def test_zero_noise_bit_exact_with_reference(workload, carrier, substrate):
    """An ideal device of any substrate, through either substrate backend,
    reproduces the reference agreement bit for bit."""
    q, protos, expect = workload
    be = resolve_backend(carrier, _config(backend=carrier,
                                          substrate=substrate))
    np.testing.assert_array_equal(np.asarray(be.agreement(q, protos)),
                                  expect)


@pytest.mark.parametrize("substrate", available_substrates())
def test_seeded_determinism(workload, substrate):
    q, protos, expect = workload
    noisy = dict(FAULT_OPTIONS[substrate], read_sigma=0.3, seed=5,
                 substrate=substrate)
    a1 = np.asarray(resolve_backend(
        "pcm_sim", _config(**noisy)).agreement(q, protos))
    a2 = np.asarray(resolve_backend(
        "pcm_sim", _config(**noisy)).agreement(q, protos))
    np.testing.assert_array_equal(a1, a2)
    a3 = np.asarray(resolve_backend(
        "pcm_sim", _config(**dict(noisy, seed=6))).agreement(q, protos))
    assert (a1 != a3).any()
    assert (a1 != expect).any()     # the noise actually bites


@pytest.mark.parametrize("substrate", available_substrates())
def test_fault_census_counts_and_reproducibility(substrate):
    sub = resolve_substrate(substrate, FAULT_OPTIONS[substrate])
    shape = (4, 64, 128)            # (tiles, prototypes, rows)
    census = sub.fault_census(shape, stream=0)
    assert set(census) == CENSUS_KEYS[substrate]
    assert all(isinstance(v, int) and v >= 0 for v in census.values())
    total = int(np.prod(shape))
    # rates are large enough that every fault class must be populated,
    # and bounded by the population it is drawn from
    assert 0 < census["on"] < total
    assert 0 < census["off"] < total
    # same seed -> same census; the faults are device state, not re-drawn
    assert sub.fault_census(shape, stream=0) == census
    other = resolve_substrate(substrate,
                              dict(FAULT_OPTIONS[substrate], seed=99))
    assert other.fault_census(shape, stream=0) != census


@pytest.mark.parametrize("substrate", available_substrates())
def test_ideal_substrate_census_is_empty(substrate):
    sub = resolve_substrate(substrate, {})
    assert sub.is_ideal
    census = sub.fault_census((2, 16, 32), stream=0)
    assert set(census) == CENSUS_KEYS[substrate]
    assert all(v == 0 for v in census.values())


@pytest.mark.parametrize("substrate", available_substrates())
def test_options_schema_round_trip(substrate):
    """Every declared option validates at its default and survives the
    CLI string coercion path (``--backend-option name=str(default)``)."""
    schema = narrowed_schema("pcm_sim", substrate)
    declared = {o.name for o in substrate_options(substrate)}
    assert declared <= set(schema.names)
    for opt in schema.options:
        if opt.default is None or opt.name == "substrate":
            continue
        own, rest = schema.validate({opt.name: opt.default})
        assert own == {opt.name: opt.default} and rest == {}
        assert schema.parse_cli(opt.name, str(opt.default)) == opt.default


@pytest.mark.parametrize("substrate", available_substrates())
def test_cross_substrate_knob_rejected(substrate):
    """A knob declared by a *different* substrate fails the narrowed
    schema even though the union schema admits it for the CLI."""
    foreign = {"pcm": "shift_fault_rate", "racetrack": "prog_sigma"}
    with pytest.raises(OptionError, match="got unknown option"):
        resolve_backend("pcm_sim", _config(
            substrate=substrate, **{foreign[substrate]: 0.1}))


@pytest.mark.parametrize("backend", available_backends())
def test_misspelled_option_fails_identically_everywhere(backend):
    """Acceptance criterion: one uniform unknown-option error, every
    backend, whether it declares options, none, or passes through (the
    ``sharded`` wrapper forwards the typo to its base, which then names
    itself in the same message shape)."""
    with pytest.raises(OptionError,
                       match=r"got unknown option 'zzz_bogus'"):
        resolve_backend(backend, _config(backend=backend, zzz_bogus=1))


@pytest.mark.parametrize("backend", available_backends())
def test_every_backend_declares_a_schema(backend):
    schema = options_schema(backend)
    assert schema.backend == backend
    for row in schema.describe():
        assert isinstance(row, str) and row


def test_substrate_cost_models_disagree():
    """Each substrate owns its cost entry: same workload, different
    energy/latency decomposition (racetrack pays shifts, not the ADC)."""
    pcm = resolve_substrate("pcm", {})
    rt = resolve_substrate("racetrack", {})
    from repro.accel.crossbar import CrossbarConfig
    xcfg = CrossbarConfig()
    a = pcm.cost(64, SP.dim, 100, SP.ngram, xcfg)
    b = rt.cost(64, SP.dim, 100, SP.ngram, xcfg)
    assert a.substrate == "pcm" and b.substrate == "racetrack"
    assert a.shift_pj == 0.0 and b.shift_pj > 0.0
    assert {n: e for n, e, _ in b.energy_rows()}.get("shift", 0.0) > 0.0
