"""Distributed substrate (process-local parts): sharding-rule resolution
(AbstractMesh), checkpointing, elastic policy, compression, fault
tolerance.  Tests needing real multi-device meshes live in
test_mesh_subprocess.py (separate process so device count doesn't leak)."""

import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ck
from repro.configs import get_config
from repro.distributed import elastic, fault_tolerance as ft
from repro.distributed import param_specs, pipeline as pp, sharding
from repro.models import lm
from repro.train import compression as comp
from repro.train import train_step as ts


def _amesh(shape, names):
    # AbstractMesh's constructor drifted across jax releases; the compat
    # helper handles both spellings (device-free, so no mesh leaks).
    return sharding.abstract_mesh(shape, names)


# -- sharding rules (AbstractMesh: no devices needed) ----------------------------

def test_param_specs_divisibility():
    cfg = get_config("phi35_moe", smoke=True)
    params = jax.eval_shape(lambda: lm.init_lm(jax.random.key(0), cfg))
    mesh = _amesh((2, 4), ("data", "model"))
    shardings = param_specs.param_shardings(params, mesh,
                                            sharding.TRAIN_RULES)
    p_flat = jax.tree_util.tree_flatten_with_path(params)[0]
    s_flat = jax.tree_util.tree_flatten_with_path(shardings)[0]
    n_sharded = 0
    for (path, leaf), (_, s) in zip(p_flat, s_flat):
        spec = tuple(s.spec) + (None,) * (len(leaf.shape) - len(s.spec))
        for dim, part in zip(leaf.shape, spec):
            if part is None:
                continue
            size = int(np.prod([mesh.shape[a] for a in
                                (part if isinstance(part, tuple)
                                 else (part,))]))
            assert dim % size == 0, (path, leaf.shape, s.spec)
            n_sharded += 1
    assert n_sharded > 10, "rules resolved to nothing"


def test_decode_rules_shard_cache_seq():
    cfg = get_config("deepseek_67b", smoke=True)
    caches = jax.eval_shape(lambda: lm.init_cache(cfg, 4, 32))
    mesh = _amesh((2, 4), ("data", "model"))
    sh = param_specs.cache_shardings(caches, mesh, sharding.DECODE_RULES)
    k_shard = sh[0]["k"]
    # (count, B, S, KV, dh): seq dim (idx 2) on 'model'
    assert k_shard.spec[2] == "model", k_shard.spec


def test_constrain_safe_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = sharding.constrain_safe(x, ("batch", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_elastic_reshard_plan_reports_changes():
    cfg = get_config("stablelm_3b", smoke=True)
    state = jax.eval_shape(lambda: ts.init_train_state(
        jax.random.key(0), cfg, ts.TrainConfig()))
    a = _amesh((4, 2), ("data", "model"))
    b = _amesh((2, 4), ("data", "model"))
    _, report = elastic.reshard_plan(state, a, b, sharding.TRAIN_RULES)
    assert report.n_leaves > 0
    assert isinstance(report.changed, tuple)


def test_elastic_batch_policy():
    assert elastic.rescale_batch(256, 16, 8) == 256
    with pytest.raises(ValueError):
        elastic.rescale_batch(100, 16, 64)


# -- checkpointing ----------------------------------------------------------------

def test_checkpoint_roundtrip_async_and_gc():
    state = {"w": jnp.arange(6.0), "step": jnp.int32(3)}
    with tempfile.TemporaryDirectory() as d:
        acp = ck.AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3):
            acp.save(state, s)
        acp.wait()
        assert ck.latest_step(d) == 3
        assert len(list(pathlib.Path(d).glob("step_*"))) == 2
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        got, step = ck.restore(d, target)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(state["w"]))


def test_checkpoint_atomic_publish():
    """A .tmp dir (crashed save) is never picked up as latest."""
    state = {"w": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, state, 1)
        (pathlib.Path(d) / "step_00000002.tmp").mkdir()
        assert ck.latest_step(d) == 1


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, {"w": jnp.ones((3,))}, 1)
        with pytest.raises(ValueError):
            ck.restore(d, {"w": jax.ShapeDtypeStruct((4,), jnp.float32)})


# -- pipeline (host-level helpers) --------------------------------------------------

def test_pipeline_stage_ranges():
    assert pp.pipeline_stages(10, 4) == [(0, 3), (3, 6), (6, 8), (8, 10)]
    assert pp.pipeline_stages(8, 2) == [(0, 4), (4, 8)]


# -- gradient compression -----------------------------------------------------------

def test_error_feedback_converges():
    w_star = jnp.asarray(np.random.default_rng(0).normal(size=(32,)),
                         jnp.float32)

    def grad(w):
        return {"w": w["w"] - w_star}

    runs = {}
    for compressed in (False, True):
        w = {"w": jnp.zeros(32)}
        est = comp.init_state(w)
        for _ in range(60):
            g = grad(w)
            if compressed:
                q, est = comp.compress(g, est)
                g = comp.decompress(q)
            w = jax.tree.map(lambda p, gg: p - 0.2 * gg, w, g)
        runs[compressed] = float(jnp.linalg.norm(w["w"] - w_star))
    assert runs[True] < 1e-2, runs


def test_compression_is_4x():
    g = {"a": jnp.zeros((1024,), jnp.float32)}
    q, _ = comp.compress(g, comp.init_state(g))
    assert q["a"]["q"].dtype == jnp.int8
    assert q["a"]["q"].nbytes * 4 == g["a"].nbytes


# -- fault tolerance -----------------------------------------------------------------

def test_heartbeat_and_straggler():
    t = [0.0]
    reg = ft.HeartbeatRegistry(["w0", "w1"], timeout=10, clock=lambda: t[0])
    assert reg.healthy()
    t[0] = 11.0
    reg.ping("w0")
    assert reg.dead_workers() == ["w1"]

    mon = ft.StragglerMonitor(k=5.0, min_samples=4)
    for i in range(8):
        assert mon.observe("w0", i, 1.0 + 0.01 * i) is None
    rep = mon.observe("w1", 9, 100.0)
    assert rep is not None and rep.worker == "w1"
    mon.observe("w1", 10, 100.0)
    mon.observe("w1", 11, 100.0)
    assert mon.should_replace("w1")


def test_restart_driver_replays_deterministically():
    saved = {}
    crashed = {"done": False}

    def step_fn(s, i):
        if i == 6 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("boom")
        return s + i

    final, stats = ft.run_with_restarts(
        init_fn=lambda: 0, step_fn=step_fn,
        save_fn=lambda s, i: saved.update(ck=(s, i)),
        restore_fn=lambda: saved.get("ck"),
        total_steps=10, checkpoint_every=3)
    assert stats.restarts == 1
    assert final == sum(range(10))
