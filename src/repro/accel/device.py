"""PCM cell models for the simulated Acc-Demeter crossbar (paper §5).

A binary HD bit is stored as the conductance of one phase-change-memory
cell: logical 1 = crystalline (SET, high conductance ``g_on_us``),
logical 0 = amorphous (RESET, low conductance ``g_off_us``).  Everything
that makes a real PCM array diverge from that ideal is a knob on the
frozen :class:`DeviceConfig`:

* **multi-bit levels** — the cell is programmed by an iterative
  program-and-verify loop that can target ``levels`` (2/4/8) evenly
  spaced conductances in the window.  The AM still stores binary HD bits
  at the two *extreme* levels, so a higher-level device changes nothing
  at zero noise — what it buys is precision: noise is physically set by
  the level spacing ``window / (levels - 1)`` the programming loop must
  resolve, so an MLC-capable cell holding a binary bit sees its noise
  shrink by ``levels - 1`` (the MIMHD observation; see PAPERS.md) at the
  cost of a longer program-verify sequence (:mod:`repro.accel.cost`);
* **programming noise** — the iterative SET/RESET loop lands on a
  conductance distributed around the target (Gaussian, std expressed as a
  fraction of the level spacing), frozen at program time;
* **conductance drift** — amorphous structural relaxation decays the
  programmed conductance as ``(t / t0)**-nu`` (Ielmini's empirical law;
  we apply one lumped exponent to the whole array);
* **stuck-at faults** — fabrication defects pin a cell at ON or OFF
  regardless of what was programmed;
* **read noise** — per-read-event current fluctuation (1/f + thermal),
  modeled at the bit-line as Gaussian current noise whose std scales with
  the square root of the number of active rows (sum of independent
  per-cell fluctuations), so the simulator never materializes a
  per-(query, cell) noise tensor.

All sampling functions are pure JAX (``key`` in, array out): the same key
always produces the same device instance, which is what makes the noisy
backend deterministic and the zero-noise configuration bit-exact with the
digital reference.

:class:`PCMSubstrate` adapts this cell model to the
:class:`repro.accel.substrate.Substrate` protocol (registered as
``"pcm"``): it is the device half the substrate-generic crossbar in
:mod:`repro.accel.crossbar` actually talks to.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.accel.substrate import register_substrate
from repro.pipeline.options import (Option, non_negative, positive,
                                    unit_interval)


@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    """Frozen PCM cell parameters (defaults = ideal, zero-noise device).

    Attributes:
      g_on_us: SET (crystalline) conductance, microsiemens.
      g_off_us: RESET (amorphous) conductance, microsiemens.
      levels: conductance levels the program-and-verify loop can target
        (2 = binary SET/RESET, 4/8 = MLC-precision programming).  HD bits
        always sit at the extreme levels; ``levels`` sets the *absolute*
        noise scale through the level spacing, and the per-cell
        programming cost through the longer verify sequence.
      prog_sigma: programming-noise std as a fraction of the level
        spacing ``(g_on_us - g_off_us) / (levels - 1)`` — at the binary
        default the spacing is the full window, so existing
        parameterizations are unchanged; 0 disables.
      read_sigma: per-cell read-noise std as a fraction of the level
        spacing; applied at the bit line scaled by sqrt(active rows);
        0 disables.
      drift_nu: conductance-drift exponent (``g *= (t/t0)**-nu``,
        t0 = 1 s); 0 disables.
      drift_t_s: seconds elapsed since programming (drift horizon).
      drift_calibration: fraction of the drift decay the read periphery
        compensates via reference-cell calibration (standard PCM
        practice); 1 = perfect compensation, 0 = raw drifted currents.
        The residual ``drift_factor**(1 - drift_calibration)`` scale
        error is the non-ideality the profiler actually sees.
      stuck_on_rate: fraction of cells pinned at ``g_on_us``.
      stuck_off_rate: fraction of cells pinned at ``g_off_us``.
      seed: base PRNG seed for every device sample (programming noise,
        fault map, read noise); the backend threads it from
        ``ProfilerConfig.backend_options``.
    """

    g_on_us: float = 20.0
    g_off_us: float = 0.1
    levels: int = 2
    prog_sigma: float = 0.0
    read_sigma: float = 0.0
    drift_nu: float = 0.0
    drift_t_s: float = 0.0
    drift_calibration: float = 1.0
    stuck_on_rate: float = 0.0
    stuck_off_rate: float = 0.0
    seed: int = 0xACC_DE

    def __post_init__(self) -> None:
        if self.g_on_us <= self.g_off_us:
            raise ValueError("g_on_us must exceed g_off_us")
        if self.g_off_us < 0:
            raise ValueError("g_off_us must be >= 0")
        if self.levels < 2:
            raise ValueError("levels must be >= 2")
        for f in ("prog_sigma", "read_sigma", "drift_nu", "drift_t_s"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0")
        for f in ("stuck_on_rate", "stuck_off_rate", "drift_calibration"):
            if not 0.0 <= getattr(self, f) <= 1.0:
                raise ValueError(f"{f} must be in [0, 1]")
        if self.stuck_on_rate + self.stuck_off_rate > 1.0:
            raise ValueError("stuck_on_rate + stuck_off_rate must be <= 1")

    @property
    def g_window_us(self) -> float:
        """The ON/OFF conductance window (the unit of one agreement count)."""
        return self.g_on_us - self.g_off_us

    @property
    def level_spacing_us(self) -> float:
        """Conductance gap between adjacent programmable levels — the
        precision the program-and-verify loop resolves, and therefore the
        physical scale of both noise sigmas.  Binary cells: the window."""
        return self.g_window_us / (self.levels - 1)

    @property
    def is_ideal(self) -> bool:
        """True when every non-ideality is switched off (bit-exact path)."""
        return (self.prog_sigma == 0.0 and self.read_sigma == 0.0
                and self.residual_drift == 1.0
                and self.stuck_on_rate == 0.0 and self.stuck_off_rate == 0.0)

    @property
    def drift_factor(self) -> float:
        """Multiplicative conductance decay after ``drift_t_s`` seconds."""
        if self.drift_nu == 0.0 or self.drift_t_s <= 1.0:
            return 1.0
        return float(self.drift_t_s ** -self.drift_nu)

    @property
    def residual_drift(self) -> float:
        """Drift scale error left after periphery calibration."""
        return float(self.drift_factor ** (1.0 - self.drift_calibration))

    @classmethod
    def pcm(cls, **overrides) -> "DeviceConfig":
        """Literature-parameterized mushroom-cell PCM (Karunaratne-style
        silicon prototype numbers): ~8% programming spread, ~3% read
        fluctuation, nu = 0.05 drift read back after ~1 day with 90%
        reference-cell calibration, 1e-3 stuck cells per polarity."""
        base = dict(prog_sigma=0.08, read_sigma=0.03,
                    drift_nu=0.05, drift_t_s=86_400.0, drift_calibration=0.9,
                    stuck_on_rate=1e-3, stuck_off_rate=1e-3)
        base.update(overrides)
        return cls(**base)


def _key(cfg: DeviceConfig, stream: int, source: int) -> jax.Array:
    """Deterministic sub-key: one per (crossbar bank, noise source)."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.key(cfg.seed), stream), source)


# Noise-source tags — one per physically distinct mechanism.
_PROG, _FAULT, READ_SOURCE = 0, 1, 2


def program_conductances(bits: jax.Array, cfg: DeviceConfig, *,
                         stream: int = 0) -> jax.Array:
    """Program a {0,1} bit array into per-cell conductances (µS).

    Models the one-time write: target level, programming spread, drift to
    the read-back horizon, then the stuck-at fault map (faults win over
    whatever was programmed — the defect is in the cell, not the pulse).

    Args:
      bits: any-shape {0,1} array (uint8/int/bool/float all accepted).
      cfg: device parameters; with ``cfg.is_ideal`` the result is exactly
        ``g_off + bits * (g_on - g_off)``.
      stream: noise-stream tag so physically distinct arrays (e.g. the
        positive and complement banks of a differential crossbar) draw
        independent noise from the same seed.

    Returns:
      float32 conductances, same shape as ``bits``, clipped to >= 0.
    """
    b = bits.astype(jnp.float32)
    g = cfg.g_off_us + b * cfg.g_window_us
    if cfg.prog_sigma > 0.0:
        noise = jax.random.normal(_key(cfg, stream, _PROG), b.shape,
                                  jnp.float32)
        g = g + cfg.prog_sigma * cfg.level_spacing_us * noise
    g = g * cfg.drift_factor
    if cfg.stuck_on_rate > 0.0 or cfg.stuck_off_rate > 0.0:
        u = jax.random.uniform(_key(cfg, stream, _FAULT), b.shape)
        g = jnp.where(u < cfg.stuck_on_rate, cfg.g_on_us, g)
        g = jnp.where(u > 1.0 - cfg.stuck_off_rate, cfg.g_off_us, g)
    return jnp.maximum(g, 0.0)


def stuck_cell_counts(shape: tuple[int, ...], cfg: DeviceConfig, *,
                      stream: int = 0) -> tuple[int, int]:
    """Census of one bank's stuck-at fault map: ``(stuck_on, stuck_off)``.

    Replays the exact uniform draw :func:`program_conductances` masks
    with (same key, same shape), so the counts describe the device that
    was actually programmed — without adding anything to, or pulling
    anything out of, the programming graph.  Host-side observability
    only; runs outside any jit.
    """
    if cfg.stuck_on_rate == 0.0 and cfg.stuck_off_rate == 0.0:
        return 0, 0
    u = jax.random.uniform(_key(cfg, stream, _FAULT), shape)
    return (int(jnp.sum(u < cfg.stuck_on_rate)),
            int(jnp.sum(u > 1.0 - cfg.stuck_off_rate)))


def read_event_key(cfg: DeviceConfig, stream: int,
                   digest: jax.Array | int) -> jax.Array:
    """Key for one read event on one bank.

    ``digest`` may be a traced int (e.g. a cheap hash of the query batch),
    so distinct batches draw fresh — but reproducible — read noise.
    """
    return jax.random.fold_in(_key(cfg, stream, READ_SOURCE),
                              jnp.asarray(digest, jnp.uint32))


def bitline_read_noise(key: jax.Array, shape: tuple[int, ...],
                       active_rows: jax.Array,
                       cfg: DeviceConfig) -> jax.Array:
    """Per-read current noise at the bit line (µS-equivalent).

    The sum of ``active_rows`` independent per-cell fluctuations of std
    ``read_sigma * level_spacing`` has std ``read_sigma * level_spacing *
    sqrt(active_rows)`` — sampling at the bit line is statistically
    equivalent to per-cell sampling and O(B*S) instead of O(B*S*D).
    With binary cells the spacing is the full window (the historical
    behavior); an MLC-precision cell fluctuates around its tighter level.

    Args:
      key: read-event key (the backend folds a batch digest into the
        device seed so each distinct batch sees fresh, reproducible noise).
      shape: bit-line current shape, e.g. ``(B, S)``.
      active_rows: broadcastable count of rows driven high per current.
      cfg: device parameters; returns zeros when ``read_sigma == 0``.
    """
    if cfg.read_sigma == 0.0:
        return jnp.zeros(shape, jnp.float32)
    std = cfg.read_sigma * cfg.level_spacing_us * jnp.sqrt(
        jnp.maximum(active_rows.astype(jnp.float32), 0.0))
    return std * jax.random.normal(key, shape, jnp.float32)


# -- the Substrate-protocol adapter -----------------------------------------

#: Declared PCM-specific backend options (geometry/selection options are
#: contributed by :data:`repro.accel.substrate.COMMON_OPTIONS`).
PCM_OPTIONS: tuple[Option, ...] = (
    Option("preset", "str", "ideal", "named device parameterization "
           "(ideal = zero noise, pcm = literature-calibrated silicon)",
           choices=("ideal", "pcm")),
    Option("levels", "int", 2, "programmable conductance levels per cell "
           "(2 = binary; 4/8 = MLC precision, tighter noise, costlier "
           "programming)", choices=(2, 4, 8)),
    Option("g_on_us", "number", 20.0, "SET conductance, uS", check=positive),
    Option("g_off_us", "number", 0.1, "RESET conductance, uS",
           check=non_negative),
    Option("prog_sigma", "number", 0.0,
           "programming-noise std / level spacing", check=non_negative),
    Option("read_sigma", "number", 0.0,
           "per-cell read-noise std / level spacing", check=non_negative),
    Option("drift_nu", "number", 0.0, "conductance-drift exponent",
           check=non_negative),
    Option("drift_t_s", "number", 0.0, "seconds since programming",
           check=non_negative),
    Option("drift_calibration", "number", 1.0,
           "fraction of drift the periphery compensates",
           check=unit_interval),
    Option("stuck_on_rate", "number", 0.0, "cells pinned at g_on",
           check=unit_interval),
    Option("stuck_off_rate", "number", 0.0, "cells pinned at g_off",
           check=unit_interval),
)

_PRESETS = {"ideal": DeviceConfig, "pcm": DeviceConfig.pcm}


@dataclasses.dataclass(frozen=True)
class PCMSubstrate:
    """:class:`~repro.accel.substrate.Substrate` over the PCM cell model.

    Stored state is the per-cell conductance map (µS); the effective read
    weight of a cell is its *calibrated, pedestal-free* conductance in
    window units — exactly the programmed bit on an ideal device, so the
    substrate-generic crossbar stays bit-exact with ``reference`` at zero
    noise for any ``levels``.
    """

    config: DeviceConfig = DeviceConfig()

    name = "pcm"

    @classmethod
    def from_options(cls, options: dict) -> "PCMSubstrate":
        opts = dict(options)
        preset = opts.pop("preset", "ideal")
        return cls(_PRESETS[preset](**opts))

    @property
    def is_ideal(self) -> bool:
        return self.config.is_ideal

    @property
    def _calibration_divisor(self) -> float:
        cfg = self.config
        return cfg.drift_factor ** cfg.drift_calibration

    def program(self, bits: jax.Array, *, stream: int = 0) -> jax.Array:
        return program_conductances(bits, self.config, stream=stream)

    def read_weights(self, state: jax.Array, *, stream: int = 0
                     ) -> jax.Array:
        # The read periphery divides out its reference-cell drift estimate
        # (drift_factor**drift_calibration), then inverts with the
        # *nominal* window and g_off pedestal.  Residual drift scale error
        # and programming noise pass through as weight error — those ARE
        # the non-idealities the profiler sees.
        cfg = self.config
        return ((state / self._calibration_divisor) - cfg.g_off_us) \
            / cfg.g_window_us

    def read_event_key(self, stream: int, digest) -> jax.Array:
        return read_event_key(self.config, stream, digest)

    def read_noise(self, key: jax.Array, shape: tuple[int, ...],
                   active_rows: jax.Array) -> jax.Array:
        # Bit-line current noise, propagated through the same calibration
        # divide + window normalization the signal sees -> count units.
        current = bitline_read_noise(key, shape, active_rows, self.config)
        if self.config.read_sigma == 0.0:
            return current
        return current / (self._calibration_divisor * self.config.g_window_us)

    def fault_census(self, shape: tuple[int, ...], *, stream: int = 0
                     ) -> dict[str, int]:
        n_on, n_off = stuck_cell_counts(shape, self.config, stream=stream)
        return {"on": n_on, "off": n_off}

    def cost(self, num_protos: int, dim: int, read_len: int, ngram: int,
             xcfg):
        from repro.accel import cost as cost_mod
        return cost_mod.accel_cost(num_protos, dim, read_len, ngram, xcfg,
                                   levels=self.config.levels)


@register_substrate("pcm", PCM_OPTIONS)
def _make_pcm(options: dict) -> PCMSubstrate:
    return PCMSubstrate.from_options(options)
