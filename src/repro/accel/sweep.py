"""Noise-sweep evaluation harness: accuracy vs device non-ideality.

Reproduces the shape of the paper's robustness argument (and of
Karunaratne et al.'s accuracy-vs-noise curves for in-memory HDC): run the
*same* profiling workload through ``pcm_sim`` while stepping one device
knob — read noise, programming noise, drift horizon, stuck-at rate, ADC
resolution — and record profiling accuracy at every point.

The RefDB is built once on the digital path (every backend's ``encode``
is bit-exact, so the database is shared; only the programmed-array +
search non-idealities vary) and each sweep point gets a fresh
:class:`~repro.pipeline.session.ProfilingSession` whose config differs
only in ``backend_options`` — which is exactly what makes the sweep a
family of honestly fingerprinted, cache-friendly runs rather than ad-hoc
parameter pokes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.eval import ProfileMetrics, score_profile
# Submodule imports (not the package) so registering pcm_sim from
# repro.pipeline.__init__ cannot hit a partially initialized package.
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.report import ProfileReport
from repro.pipeline.session import ProfilingSession
from repro.pipeline.source import ArraySource

#: Device/geometry knobs a sweep may step (declared option names of the
#: substrate backends; ``levels`` and ``shift_fault_rate`` are
#: substrate-specific — the backend's schema rejects them elsewhere).
SWEEPABLE = ("read_sigma", "prog_sigma", "drift_t_s", "stuck_on_rate",
             "stuck_off_rate", "adc_bits", "seed", "levels",
             "shift_fault_rate")

#: Backends the sweep can drive; anything else is forced to ``pcm_sim``.
_SUBSTRATE_BACKENDS = ("pcm_sim", "racetrack_sim")


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """Accuracy of one profiling run at one device setting."""

    knob: str
    value: float
    metrics: ProfileMetrics
    unmapped_frac: float
    report: ProfileReport

    def row(self) -> str:
        return (f"{self.knob}={self.value:g} {self.metrics.row()} "
                f"unmapped={self.unmapped_frac:.3f}")


def noise_sweep(genomes: dict[str, np.ndarray], tokens: np.ndarray,
                lengths: np.ndarray, true_abundance: np.ndarray, *,
                config: ProfilerConfig, knob: str = "read_sigma",
                levels: Sequence[float] = (0.0, 0.02, 0.05, 0.1, 0.2),
                refdb=None) -> list[SweepPoint]:
    """Profile one sample at every ``knob`` level; return accuracy points.

    Args:
      genomes: reference genomes (step 2 input; encoded once, digitally).
      tokens / lengths: the query read sample.
      true_abundance: ground-truth abundance for scoring.
      config: base config; its backend is kept if it is a substrate
        backend (``pcm_sim`` / ``racetrack_sim``), else forced to
        ``pcm_sim``; existing ``backend_options`` (e.g. a preset) are
        kept, with ``knob`` overridden per level.
      knob: one of :data:`SWEEPABLE`.
      levels: values to step ``knob`` through.
      refdb: prebuilt reference database; pass one to share a single
        build across several sweeps (the prototypes are identical at
        every level and for every knob).
    """
    if knob not in SWEEPABLE:
        raise ValueError(f"unknown sweep knob {knob!r}; one of {SWEEPABLE}")
    backend = (config.backend if config.backend in _SUBSTRATE_BACKENDS
               else "pcm_sim")
    base = dataclasses.replace(config, backend=backend)

    if refdb is None:
        # Step 2 once: the digital prototypes are identical at every level
        # (the builder strips the device options and any noise-aware flag —
        # the reference backend takes no options, and a sweep compares
        # device settings against one shared database).
        builder = ProfilingSession(dataclasses.replace(
            base, backend="reference", backend_options=(),
            noise_aware_refdb=False))
        refdb = builder.build_refdb(genomes)

    points: list[SweepPoint] = []
    for raw in levels:
        level = int(raw) if knob in ("adc_bits", "seed") else float(raw)
        cfg = base.with_options(**{knob: level})
        session = ProfilingSession(cfg)
        report = session.profile(ArraySource(tokens, lengths), refdb=refdb)
        points.append(SweepPoint(
            knob=knob, value=float(level),
            metrics=score_profile(report.abundance, true_abundance),
            unmapped_frac=report.unmapped_reads / max(report.total_reads, 1),
            report=report))
    return points
