"""Noise-aware RefDB co-design: retrain prototypes on simulated readout.

The memristive-SoC co-design argument (PAPERS.md): a reference database
built purely digitally is optimal for a noiseless AM, but the device the
search actually runs on adds programming error, drift residue, shift
faults and read noise — so the *margin* between a read's true species and
its best rival, not just the sign, decides accuracy.  This module closes
the loop: it takes a naively built RefDB and a noisy substrate backend
config, simulates readout of reference-derived training reads through
that backend, and nudges the prototypes to maximize the species margin
under the device's own noise.

The pass has two stages, both validated on held-out reads:

1. **fault-aware programming** (:func:`repro.accel.crossbar
   .write_verify_bits`): when the backend runs on a simulated substrate,
   probe the device's deterministic transfer function and re-choose the
   stored bits to minimize readout bias — pre-rolling content into
   misaligned racetrack tracks, aligning stored bits with stuck cells.
   This is the write-verify discipline of real PCM parts, and it is the
   stage that recovers the statically-faulted sweep points (a shift-
   faulted racetrack AM goes from most reads UNMAPPED back to near the
   ideal-device abundance error);
2. **margin retraining** (perceptron-style, as in MIMHD and the HDC
   retraining literature, lifted to bundling counters): recover per-bit
   counters from the binarized prototypes (``±init_scale``), sample
   seeded training reads from the reference genomes, read their
   agreement through the *noisy simulated substrate* — the same
   backend, options and seed the profiling run will use — and for every
   read whose true-species score fails its best rival or the absolute
   hit threshold by ``margin`` counts, bundle the read into its species'
   best prototype (un-bundling it from the rival when the rival was the
   binding constraint), then re-binarize (ties keep the prior bit).

Every candidate — the naive build, the write-verified build, and each
retraining iterate — is scored on noisy readout of a held-out validation
split of the sampled reads, and the best validated candidate is
returned.  A sweep point where neither stage can help (pure zero-mean
read noise, a global drift-calibration bias) therefore degenerates to
the naive build instead of regressing.

Because the readout in step 3 happens through the registered backend, the
refined database is specific to (backend, backend_options) — which is why
``ProfilerConfig.refdb_fingerprint`` folds both in when the pass is
enabled (``noise_aware_refdb=True``), keeping cached naive and refined
databases from ever colliding.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import assoc_memory, bitops, classifier
from repro.core.assoc_memory import RefDB
from repro.pipeline.config import ProfilerConfig


def _training_reads(db: RefDB, genomes: dict[str, np.ndarray], *,
                    read_len: int, reads_per_species: int,
                    rng: np.random.Generator
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Seeded read-like windows from every reference genome + labels."""
    toks_out, labels = [], []
    for label, name in enumerate(db.species_names):
        toks = np.asarray(genomes[name])
        n = min(read_len, len(toks))
        row = np.zeros((reads_per_species, read_len), np.int32)
        starts = rng.integers(0, len(toks) - n + 1, reads_per_species)
        for i, s in enumerate(starts):
            row[i, :n] = toks[s:s + n]
        toks_out.append(row)
        labels.append(np.full(reads_per_species, label, np.int32))
    # per-read true length (genomes may be shorter than read_len)
    lengths = np.concatenate(
        [np.full(reads_per_species,
                 min(read_len, len(np.asarray(genomes[name]))), np.int32)
         for name in db.species_names])
    return (np.concatenate(toks_out), lengths, np.concatenate(labels))


def noise_aware_refdb(db: RefDB, genomes: dict[str, np.ndarray],
                      config: ProfilerConfig, *, iterations: int = 2,
                      reads_per_species: int = 48, read_len: int = 256,
                      margin: int | None = None, init_scale: int = 8,
                      seed: int = 0) -> RefDB:
    """Margin-maximizing retraining of ``db`` on simulated noisy readout.

    Args:
      db: the naively built RefDB (binarized one-shot bundling).
      genomes: the reference genomes the database was built from.
      config: the *profiling* config — its backend + backend_options are
        the simulated substrate the retraining reads through (a digital
        backend works too; the pass then just sharpens margins against
        quantization, which is rarely worth the build time).
      iterations: full passes over the training reads.
      reads_per_species: seeded training reads sampled per species.
      read_len: training read length in tokens (clipped per genome).
      margin: required winning margin in agreement counts before a read
        stops generating updates; default ``dim // 32``.
      init_scale: magnitude assigned to each recovered bundling counter;
        bounds how many disagreeing training reads it takes to flip a
        naive bit.
      seed: sampling seed (independent of the device seed on purpose —
        the device noise is the backend's, the training data is ours).

    Returns:
      A new RefDB with retrained prototypes; species metadata unchanged.
    """
    # Resolved here (not at module import) to keep codesign importable
    # without triggering backend registration order issues.
    from repro.pipeline.backend import resolve_backend

    if iterations < 1:
        raise ValueError("iterations must be >= 1")
    if margin is None:
        margin = max(1, config.space.dim // 32)
    missing = set(db.species_names) - set(genomes)
    if missing:
        raise KeyError(f"genomes missing for species {sorted(missing)}")

    be = resolve_backend(config.backend, config)
    rng = np.random.default_rng(seed)
    tokens, lengths, labels = _training_reads(
        db, genomes, read_len=read_len,
        reads_per_species=reads_per_species, rng=rng)

    # Stage 1: fault-aware programming.  Only meaningful when the backend
    # exposes a probe-able simulated substrate; digital backends skip it.
    base_protos = db.prototypes
    if getattr(be, "substrate", None) is not None:
        from repro.accel.crossbar import write_verify_bits
        base_protos = write_verify_bits(
            db.prototypes, be.crossbar_config, be.substrate)

    # Encode once, digitally (bit-exact on every backend), in batches.
    qblocks = []
    bs = config.batch_size
    for i in range(0, len(tokens), bs):
        qblocks.append(np.asarray(
            be.encode(tokens[i:i + bs], lengths[i:i + bs])))
    queries = np.concatenate(qblocks)
    qbits = np.asarray(bitops.unpack_bits(queries))[:, :config.space.dim]
    qpm = (2 * qbits.astype(np.int32) - 1)                 # (B, dim) ±1

    base_bits = np.asarray(
        bitops.unpack_bits(base_protos))[:, :config.space.dim]
    counters = (2 * base_bits.astype(np.int32) - 1) * init_scale
    proto_species = np.asarray(db.proto_species)
    same = proto_species[None, :] == labels[:, None]        # (B, S_protos)
    neg = np.iinfo(np.int64).min

    def noisy_agreement(idx, prototypes):
        out = np.empty((len(idx), len(proto_species)), np.int64)
        for i in range(0, len(idx), bs):
            sel = idx[i:i + bs]
            out[i:i + len(sel)] = np.asarray(
                be.agreement(queries[sel], prototypes))
        return out

    # Held-out validation split: candidates (the naive build included)
    # are scored on noisy readout of reads the updates never saw, and the
    # best validated prototype set wins — retraining can refuse to "help".
    split = rng.permutation(len(queries))
    n_val = max(len(proto_species) // 4, len(queries) // 5)
    val_idx, train_idx = split[:n_val], split[n_val:]

    def validate(prototypes):
        """Score a candidate by classifying the held-out reads exactly
        as step 4 will (species scores, z threshold): first keep the
        true-species hit rate, then minimize false hits on other species
        — the failure mode noise actually causes (reads drifting from
        UNIQUE to MULTI/UNMAPPED and polluting the abundance split)."""
        agree = noisy_agreement(val_idx, prototypes)
        res = classifier.from_agreement(
            jnp.asarray(agree, jnp.int32), db.proto_species,
            db.num_species, config.space.threshold_bits)
        hits = np.asarray(res.hits)
        rows = np.arange(len(val_idx))
        correct = hits[rows, labels[val_idx]].mean()
        false = (hits.sum(axis=1) - hits[rows, labels[val_idx]]).mean()
        return float(correct), -float(false)

    best_score, best_protos = validate(db.prototypes), db.prototypes
    if base_protos is not db.prototypes:
        score = validate(base_protos)
        if score > best_score:
            best_score, best_protos = score, base_protos
    prototypes = base_protos
    for _ in range(iterations):
        # Noisy simulated readout through the actual profiling backend.
        # Training reads are re-shuffled every pass: the device keys its
        # read noise off the query-batch digest, so a fresh batch
        # composition draws a fresh noise realization — each iteration
        # sees a new sample of the readout distribution instead of
        # re-fitting the one realization a fixed order would replay.
        order = rng.permutation(train_idx)
        agree = noisy_agreement(order, prototypes)
        sq, spm = same[order], qpm[order]
        own = np.where(sq, agree, neg)
        rival = np.where(sq, neg, agree)
        own_best = own.argmax(axis=1)                      # proto indices
        rival_best = rival.argmax(axis=1)
        rows = np.arange(len(order))
        # A read fails when its true species doesn't beat the best rival
        # by ``margin`` — or doesn't clear the classifier's *absolute*
        # hit threshold (paper Eq. 2) by the same margin: device noise
        # that shrinks scores pushes reads to UNMAPPED, and bundling the
        # read back into its prototype is exactly what recovers them.
        own_score = own[rows, own_best]
        rival_flag = own_score < rival[rows, rival_best] + margin
        thr_flag = own_score < config.space.threshold_bits + margin
        flagged = rival_flag | thr_flag
        if not flagged.any():
            break
        # Bundle the read into its species' best prototype; un-bundle it
        # from the rival only when the rival was the binding constraint —
        # the counter-space perceptron step.
        np.add.at(counters, own_best[flagged], spm[flagged])
        np.add.at(counters, rival_best[rival_flag], -spm[rival_flag])
        prototypes = assoc_memory.rebinarize_counters(counters, base_bits)
        score = validate(prototypes)
        if score > best_score:
            best_score, best_protos = score, prototypes

    return RefDB(prototypes=best_protos,
                 proto_species=db.proto_species,
                 genome_lengths=db.genome_lengths,
                 num_species=db.num_species,
                 species_names=db.species_names)
