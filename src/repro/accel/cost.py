"""Analytical latency / energy / area models of the AM substrates (§6).

Mirrors the :class:`benchmarks.hw.Chip` pattern: one frozen dataclass of
per-operation constants per substrate (the paper's 65nm UMC + PCM
technology point, and a racetrack/domain-wall point following the HDCR
design space — filled with literature values where the papers report
only aggregates; clearly *models*, not measurements) plus pure functions
that turn a workload shape into a Table-3-style breakdown.

The workload shape is exactly what the simulator in
:mod:`repro.accel.crossbar` executes: a differential AM of
``2 * ceil(D/rows) * ceil(S/cols)`` arrays, one converter event per
(column, row tile, bank) per query, digital accumulation of partial
counts, and a CMOS n-gram encoder feeding the word lines.  The two cost
entries tell opposite stories through the same report shape:

* **PCM** (:func:`accel_cost`) — dense analog reads, but every bit-line
  current needs a 25 pJ SAR conversion, and multi-bit programming pays
  ``levels - 1`` program-and-verify pulses per cell;
* **racetrack** (:func:`racetrack_cost`) — transverse-read popcounts
  replace the ADC (sub-pJ sense amps, ~2 F^2 cells), but every access
  *shifts* whole tracks under their ports, so shift energy and serial
  shift latency dominate.
"""

from __future__ import annotations

import dataclasses
import math

from repro.accel.crossbar import CrossbarConfig


@dataclasses.dataclass(frozen=True)
class PCMChip:
    """65nm UMC + mushroom-cell PCM technology constants.

    Energy entries are per-event; area entries are per-instance.  The
    defaults follow the paper's synthesis point (65nm, ~1 GHz digital
    periphery) with Horowitz/Murmann-style literature numbers for the
    analog blocks.
    """

    freq_hz: float = 1.0e9          # digital periphery clock
    t_read_ns: float = 10.0         # crossbar row-activate + settle
    t_adc_ns: float = 5.0           # one SAR conversion
    t_set_ns: float = 100.0         # PCM SET/RESET programming pulse
    # energy
    fj_per_cell_read: float = 8.0   # V_read^2 * g_on * t_read (0.2 V)
    pj_per_adc: float = 25.0        # 8-9 bit SAR @ 65nm (Murmann FoM)
    pj_per_cell_set: float = 25.0   # one PCM program-and-verify pulse
    pj_per_dig_op: float = 0.5      # 32-bit add/popcount step @ 65nm
    pj_per_enc_bitop: float = 0.05  # 1-bit XOR/majority cell in the encoder
    # area
    f_nm: float = 65.0
    cell_area_f2: float = 25.0      # 1T1R PCM cell footprint
    adc_area_mm2: float = 0.003     # one SAR ADC instance
    dig_area_mm2_per_kgate: float = 0.0014
    encoder_kgates: float = 120.0   # n-gram bind/bundle/majority logic
    adcs_per_array: int = 8         # bit lines share ADCs (column-serial)
    row_activity: float = 0.5       # expected fraction of word lines high


UMC65_PCM = PCMChip()


@dataclasses.dataclass(frozen=True)
class RacetrackChip:
    """Domain-wall nanowire technology constants (HDCR-style point).

    A "cell" is one magnetic domain; a *track* holds ``rows`` of them and
    is accessed by shifting domains under ``ports`` access ports, where a
    transverse read (TR) senses the popcount of a ``tr_span``-domain
    segment directly — no per-bit-line ADC exists, which is the
    substrate's whole energy argument.
    """

    freq_hz: float = 1.0e9
    t_shift_ns: float = 0.5         # one domain step along a track
    t_tr_ns: float = 1.0            # one transverse-read sense
    t_write_ns: float = 2.0         # shift-register write, per domain
    # energy
    fj_per_cell_shift: float = 0.02  # moving one domain one position
    pj_per_tr: float = 0.5          # one TR sense event (vs a 25 pJ SAR)
    pj_per_cell_write: float = 0.1  # writing one domain
    pj_per_dig_op: float = 0.5
    pj_per_enc_bitop: float = 0.05
    # area
    f_nm: float = 65.0
    cell_area_f2: float = 2.0       # domain pitch; no access transistor
    sense_area_mm2: float = 0.0004  # one TR sense amplifier
    dig_area_mm2_per_kgate: float = 0.0014
    encoder_kgates: float = 120.0
    senses_per_array: int = 8       # tracks share TR sense amps


DW_RACETRACK = RacetrackChip()


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Per-query cost of one profiled read, plus one-time array costs.

    One report shape serves every substrate; ``substrate`` names the
    model that produced it and ``shift_pj`` is nonzero only where the
    access physics involves moving data under ports (racetrack).  For
    racetrack reports ``adc_pj`` carries the transverse-read sense
    energy — the TR sense amp *is* that substrate's converter.
    """

    # per-query energy, picojoules
    encoder_pj: float
    array_read_pj: float
    adc_pj: float
    digital_pj: float
    # per-query latency (pipelined steady state), nanoseconds
    latency_ns: float
    # one-time / static
    program_pj: float               # programming the whole AM once
    array_area_mm2: float
    adc_area_mm2: float
    encoder_area_mm2: float
    num_arrays: int
    substrate: str = "pcm"
    shift_pj: float = 0.0           # per-query track-shift energy

    @property
    def total_pj(self) -> float:
        return (self.encoder_pj + self.array_read_pj + self.adc_pj
                + self.digital_pj + self.shift_pj)

    @property
    def total_area_mm2(self) -> float:
        return self.array_area_mm2 + self.adc_area_mm2 + self.encoder_area_mm2

    @property
    def reads_per_s(self) -> float:
        return 1e9 / self.latency_ns

    def mbp_per_joule(self, read_len: int) -> float:
        """The paper's headline efficiency metric (megabasepairs/J)."""
        return read_len / (self.total_pj * 1e-12) / 1e6

    def energy_rows(self) -> list[tuple[str, float, float]]:
        """Table-3-style ``(component, pJ/read, percent)`` rows."""
        t = self.total_pj
        rows = [("encoder", self.encoder_pj),
                ("array_read", self.array_read_pj),
                ("adc", self.adc_pj),
                ("digital", self.digital_pj)]
        if self.shift_pj:
            rows.append(("shift", self.shift_pj))
        return [(n, e, 100.0 * e / t) for n, e in rows]


def accel_cost(num_protos: int, dim: int, read_len: int, ngram: int,
               xcfg: CrossbarConfig = CrossbarConfig(),
               chip: PCMChip = UMC65_PCM, levels: int = 2) -> CostReport:
    """PCM cost of one query against an ``S = num_protos`` prototype AM.

    Latency model: row tiles/arrays fire in parallel; each array's
    ``cols`` bit lines share ``adcs_per_array`` converters, so one AM
    read occupies ``t_read + ceil(cols / adcs) * t_adc``; the digital
    accumulation tree is pipelined behind the converters and the encoder
    is pipelined ahead of the search (the paper overlaps steps 3 and 4),
    so steady-state per-query latency is the AM read.

    ``levels`` is the cell's programmable-level count: the iterative
    program-and-verify loop needs one more verify step per extra level,
    so one-time programming energy scales with ``levels - 1`` (read
    energy does not — HD bits sit at the window extremes either way).
    """
    rt, ct = xcfg.num_tiles(dim, num_protos)
    num_arrays = xcfg.num_arrays(dim, num_protos)
    s_pad, d_pad = ct * xcfg.cols, rt * xcfg.rows
    cells = 2 * s_pad * d_pad                     # both differential banks

    # -- per-query energy ---------------------------------------------------
    grams = max(read_len - ngram + 1, 1)
    encoder_pj = grams * dim * chip.pj_per_enc_bitop \
        + dim * chip.pj_per_enc_bitop             # bind+bundle, + majority
    array_read_pj = cells * chip.row_activity * chip.fj_per_cell_read * 1e-3
    conversions = 2 * s_pad * rt                  # per (col, row tile, bank)
    adc_pj = conversions * chip.pj_per_adc
    digital_pj = conversions * chip.pj_per_dig_op  # partial-count adds

    # -- latency ------------------------------------------------------------
    latency_ns = chip.t_read_ns \
        + math.ceil(xcfg.cols / chip.adcs_per_array) * chip.t_adc_ns

    # -- one-time programming + area ---------------------------------------
    program_pj = cells * chip.pj_per_cell_set * (levels - 1)
    f_um = chip.f_nm * 1e-3
    cell_area_mm2 = chip.cell_area_f2 * (f_um * f_um) * 1e-6
    array_area_mm2 = cells * cell_area_mm2
    adc_area_mm2 = num_arrays * chip.adcs_per_array * chip.adc_area_mm2
    encoder_area_mm2 = chip.encoder_kgates * chip.dig_area_mm2_per_kgate

    return CostReport(
        encoder_pj=encoder_pj, array_read_pj=array_read_pj, adc_pj=adc_pj,
        digital_pj=digital_pj, latency_ns=latency_ns, program_pj=program_pj,
        array_area_mm2=array_area_mm2, adc_area_mm2=adc_area_mm2,
        encoder_area_mm2=encoder_area_mm2, num_arrays=num_arrays,
        substrate="pcm")


def racetrack_cost(num_protos: int, dim: int, read_len: int, ngram: int,
                   xcfg: CrossbarConfig = CrossbarConfig(),
                   chip: RacetrackChip = DW_RACETRACK,
                   ports: int = 4, tr_span: int = 5) -> CostReport:
    """Racetrack cost of one query against the same AM workload shape.

    One "array" is ``cols`` tracks of ``rows`` domains each.  Per query,
    every track aligns each ``tr_span``-domain segment under a port and
    senses it with one transverse read: ``ceil(rows / (tr_span * ports))``
    shift sequences of up to ``tr_span`` steps each — every domain passes
    a port once, so a track moves ``~rows / ports`` net positions — and
    ``ceil(rows / tr_span)`` TR senses.  Shifting one track one position
    moves all ``rows`` domains (that is racetrack's tax); sensing costs
    sub-pJ (that is its win over the SAR ADC).  Tracks shift in parallel,
    TR senses on a track serialize over its ports.
    """
    rt, ct = xcfg.num_tiles(dim, num_protos)
    num_arrays = xcfg.num_arrays(dim, num_protos)
    s_pad, d_pad = ct * xcfg.cols, rt * xcfg.rows
    cells = 2 * s_pad * d_pad                     # both differential banks
    tracks = cells // xcfg.rows                   # one track per (proto, tile)

    # -- per-query energy ---------------------------------------------------
    grams = max(read_len - ngram + 1, 1)
    encoder_pj = grams * dim * chip.pj_per_enc_bitop \
        + dim * chip.pj_per_enc_bitop
    shifts_per_track = math.ceil(xcfg.rows / ports)   # net domain steps
    shift_pj = tracks * shifts_per_track * xcfg.rows \
        * chip.fj_per_cell_shift * 1e-3
    tr_events = tracks * math.ceil(xcfg.rows / tr_span)
    adc_pj = tr_events * chip.pj_per_tr           # TR sense = the converter
    digital_pj = tr_events * chip.pj_per_dig_op   # partial-count adds
    array_read_pj = 0.0                           # folded into the TR sense

    # -- latency ------------------------------------------------------------
    latency_ns = shifts_per_track * chip.t_shift_ns \
        + math.ceil(xcfg.rows / (tr_span * ports)) * chip.t_tr_ns

    # -- one-time programming + area ---------------------------------------
    program_pj = cells * chip.pj_per_cell_write \
        + tracks * shifts_per_track * xcfg.rows * chip.fj_per_cell_shift * 1e-3
    f_um = chip.f_nm * 1e-3
    cell_area_mm2 = chip.cell_area_f2 * (f_um * f_um) * 1e-6
    array_area_mm2 = cells * cell_area_mm2
    adc_area_mm2 = num_arrays * chip.senses_per_array * chip.sense_area_mm2
    encoder_area_mm2 = chip.encoder_kgates * chip.dig_area_mm2_per_kgate

    return CostReport(
        encoder_pj=encoder_pj, array_read_pj=array_read_pj, adc_pj=adc_pj,
        digital_pj=digital_pj, latency_ns=latency_ns, program_pj=program_pj,
        array_area_mm2=array_area_mm2, adc_area_mm2=adc_area_mm2,
        encoder_area_mm2=encoder_area_mm2, num_arrays=num_arrays,
        substrate="racetrack", shift_pj=shift_pj)
