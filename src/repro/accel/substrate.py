"""The ``Substrate`` protocol: what any in-memory device model must provide.

The paper's platform-independence claim says the AM search runs on *any*
in-memory substrate; :mod:`repro.accel.crossbar` makes that concrete by
depending only on this protocol — the tiling, differential banks and
behavioral ADC are substrate-independent, while everything device-physical
(what programming stores, what a read event sees, where the noise and the
energy come from) lives behind four hooks:

  ``program(bits, stream)``        one-time write: {0,1} bits -> stored
                                   physical state (conductances, domains);
  ``read_weights(state, stream)``  the effective per-cell weight an AM
                                   read sees (ideal: exactly the bits) —
                                   calibration, drift residue, shift-fault
                                   misalignment all land here;
  ``read_noise(key, shape, ...)``  additive per-read-event noise on the
                                   accumulated match count;
  ``cost(...)``                    the substrate's analytical
                                   latency/energy/area entry.

Substrates register by name with their declared options
(:class:`repro.pipeline.options.Option` rows, the same machinery every
backend's options ride), so backend construction, ``--list-backends`` and
the shared contract test all discover them uniformly:

  ``pcm``        phase-change crossbar cells (multi-bit levels, drift,
                 stuck-at faults) — :mod:`repro.accel.device`;
  ``racetrack``  domain-wall nanowire tracks (shift-based access faults,
                 transverse-read sensing) — :mod:`repro.accel.racetrack`.

Every hook is pure JAX and seeded: the same seed always reproduces the
same device instance, which is what keeps the noisy backends deterministic
and the zero-noise configurations bit-exact with the digital reference.
"""

from __future__ import annotations

from typing import Callable, Mapping, Protocol, runtime_checkable

import jax

from repro.pipeline.options import Option, OptionsSchema, non_negative


@runtime_checkable
class Substrate(Protocol):
    """Device-physics hooks the substrate-generic crossbar runs through."""

    name: str

    @property
    def is_ideal(self) -> bool:
        """True when every non-ideality is off (the bit-exact path)."""
        ...

    def program(self, bits: jax.Array, *, stream: int = 0) -> jax.Array:
        """One-time write of a {0,1} bit array into physical state.

        ``stream`` tags physically distinct arrays (the positive and
        complement banks of the differential design) so they draw
        independent noise/fault maps from the same seed.  Deterministic in
        the substrate's seed: reprogramming the same bits yields the same
        device (write-once discipline).
        """
        ...

    def read_weights(self, state: jax.Array, *, stream: int = 0
                     ) -> jax.Array:
        """Stored state -> effective per-cell weights for an AM read.

        The ideal value is exactly the programmed bit (0.0 or 1.0); every
        static read-path non-ideality — drift residue after calibration,
        a shift-misaligned track, a pinned domain — shows up as a weight
        that differs from the bit.  The crossbar accumulates
        ``query @ weights.T`` per tile, so this is the seam where "what
        the bit line integrates" is defined per substrate.
        """
        ...

    def read_event_key(self, stream: int, digest) -> jax.Array:
        """PRNG key for one read event on one bank (digest may be traced)."""
        ...

    def read_noise(self, key: jax.Array, shape: tuple[int, ...],
                   active_rows: jax.Array) -> jax.Array:
        """Additive noise on the accumulated match count for one event.

        Returned in *count* units (the unit of one agreement): the
        substrate folds its own sensing physics (bit-line current noise
        over the conductance window, transverse-read fluctuation) into
        that normalization.
        """
        ...

    def fault_census(self, shape: tuple[int, ...], *, stream: int = 0
                     ) -> dict[str, int]:
        """Static defect counts of one programmed bank (host-side only).

        Replays the seeded fault draws for ``shape`` — stuck cells,
        misaligned tracks — without touching the programming graph; keys
        are substrate-specific (``on``/``off`` for PCM, plus
        ``misaligned`` tracks for racetrack).
        """
        ...

    def cost(self, num_protos: int, dim: int, read_len: int, ngram: int,
             xcfg) -> "object":
        """The substrate's analytical cost entry (a ``CostReport``)."""
        ...


#: Geometry + selection options shared by every substrate backend.
COMMON_OPTIONS: tuple[Option, ...] = (
    Option("substrate", "str",
           help="device model running the AM search (see docs/ACC_DEMETER.md)"),
    Option("rows", "int", 256, "word lines / domains per array tile",
           check=lambda v: None if v >= 1 else "must be >= 1"),
    Option("cols", "int", 256, "bit lines (prototypes) per array tile",
           check=lambda v: None if v >= 1 else "must be >= 1"),
    Option("adc_bits", "int", 9, "converter resolution; lossless when "
           "2^bits - 1 >= rows",
           check=lambda v: None if v >= 1 else "must be >= 1"),
    Option("seed", "int", 0xACC_DE, "device PRNG seed (all noise + faults)",
           check=non_negative),
)

#: option names routed to CrossbarConfig (the rest go to the substrate).
CROSSBAR_KEYS = frozenset(("rows", "cols", "adc_bits"))

SubstrateFactory = Callable[[Mapping[str, object]], Substrate]

_SUBSTRATES: dict[str, tuple[SubstrateFactory, tuple[Option, ...]]] = {}


def register_substrate(name: str, options: tuple[Option, ...]
                       ) -> Callable[[SubstrateFactory], SubstrateFactory]:
    """Decorator: register ``options-dict -> Substrate`` under ``name``.

    ``options`` declares the substrate-specific knobs (device physics,
    preset, fault rates); the geometry/selection options in
    :data:`COMMON_OPTIONS` are contributed by the backend.
    """
    def deco(factory: SubstrateFactory) -> SubstrateFactory:
        if name in _SUBSTRATES:
            raise ValueError(f"substrate {name!r} already registered")
        _SUBSTRATES[name] = (factory, tuple(options))
        return factory
    return deco


def available_substrates() -> tuple[str, ...]:
    """Names of every registered substrate (import :mod:`repro.accel`
    or the backend module first; registration happens on import)."""
    return tuple(sorted(_SUBSTRATES))


def substrate_options(name: str) -> tuple[Option, ...]:
    """The declared substrate-specific options of ``name``."""
    _require(name)
    return _SUBSTRATES[name][1]


def resolve_substrate(name: str, options: Mapping[str, object]) -> Substrate:
    """Instantiate the substrate registered as ``name`` from its options."""
    _require(name)
    return _SUBSTRATES[name][0](dict(options))


def _require(name: str) -> None:
    if name not in _SUBSTRATES:
        raise ValueError(f"unknown substrate {name!r}; registered: "
                         f"{available_substrates()}")


def narrowed_schema(backend: str, substrate: str) -> OptionsSchema:
    """The exact option set valid for ``backend`` once ``substrate`` is
    chosen: common geometry/selection options + that substrate's own.

    This is what actually validates a config — a PCM-only knob under
    ``substrate=racetrack`` is an unknown option here, with the error
    naming the narrowed context.
    """
    return OptionsSchema(backend=f"{backend} (substrate={substrate})",
                         options=COMMON_OPTIONS + substrate_options(substrate))


def union_schema(backend: str, default_substrate: str) -> OptionsSchema:
    """The display/CLI schema of a substrate backend: common options plus
    every registered substrate's options (shared names merged).

    ``--list-backends`` prints this union and the CLI coerces against it;
    validation then narrows to the selected substrate's exact set.
    """
    merged: dict[str, Option] = {}
    for opt in COMMON_OPTIONS:
        if opt.name == "substrate":
            opt = Option("substrate", "str", default_substrate, opt.help,
                         choices=available_substrates())
        merged[opt.name] = opt
    for sub in available_substrates():
        for opt in substrate_options(sub):
            prev = merged.get(opt.name)
            if prev is None:
                merged[opt.name] = opt
            elif prev.choices is not None and opt.choices is not None \
                    and prev.choices != opt.choices:
                # e.g. `preset`: each substrate narrows to its own names.
                joint = prev.choices + tuple(c for c in opt.choices
                                             if c not in prev.choices)
                merged[opt.name] = Option(prev.name, prev.kind, prev.default,
                                          prev.help, choices=joint)
    return OptionsSchema(backend=backend, options=tuple(merged.values()))
