"""Racetrack (domain-wall) memory substrate for the simulated AM search.

The second registered :class:`~repro.accel.substrate.Substrate` — and the
forcing function that keeps the device API genuinely substrate-generic.
Follows the HDCR design point (Khan et al., PAPERS.md): each prototype
segment of ``rows`` HD bits lives as magnetic domains along one
ferromagnetic nanowire *track*; access ports read the track via
*transverse read* (TR), which senses the number of domain walls — i.e. a
popcount — instead of converting an analog current, and the track is
*shifted* under its ports to bring the next segment into reach.

The non-idealities are therefore nothing like PCM's, which is the point:

* **shift-based access faults** — the dominant racetrack error mode: a
  track whose shift path over/under-steps presents its domains offset by
  one position at every access.  Modeled as a seeded per-track fault map
  drawn at program time (``shift_fault_rate`` tracks get a ±1 circular
  misalignment), so it is a *static, census-able* defect like a stuck
  cell, not fresh noise per read;
* **stuck domains** — pinning sites that hold a domain's magnetization
  regardless of what was written (``stuck_on_rate`` / ``stuck_off_rate``);
* **TR sense noise** — per-read-event fluctuation of the transverse-read
  popcount, Gaussian with std ``read_sigma * sqrt(active domains)``
  (already in count units: TR senses domains, not microamps).

Zero-rate defaults make every hook the identity on the stored bits, which
is what the shared substrate contract test pins as bit-exactness with the
``reference`` backend.  The cost entry (:func:`repro.accel.cost
.racetrack_cost`) swaps the PCM picture — expensive ADCs, cheap static
reads — for the racetrack one: cheap dense cells and sense amps, with the
energy/latency dominated by shifting.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.accel.substrate import register_substrate
from repro.pipeline.options import Option, non_negative, unit_interval


@dataclasses.dataclass(frozen=True)
class RacetrackConfig:
    """Frozen racetrack nanowire parameters (defaults = ideal device).

    Attributes:
      shift_fault_rate: fraction of tracks with a permanent ±1 access
        misalignment (split evenly between the two directions).
      read_sigma: transverse-read sense-noise std per sqrt(active domain),
        in count units; 0 disables.
      stuck_on_rate: fraction of domains pinned at logical 1.
      stuck_off_rate: fraction of domains pinned at logical 0.
      ports: access ports per track (cost model: shifts per access scale
        with ``rows / ports``).
      tr_span: domains one transverse read senses at once (cost model).
      seed: base PRNG seed for fault maps and read noise.
    """

    shift_fault_rate: float = 0.0
    read_sigma: float = 0.0
    stuck_on_rate: float = 0.0
    stuck_off_rate: float = 0.0
    ports: int = 4
    tr_span: int = 5
    seed: int = 0xACC_DE

    def __post_init__(self) -> None:
        if self.read_sigma < 0:
            raise ValueError("read_sigma must be >= 0")
        for f in ("shift_fault_rate", "stuck_on_rate", "stuck_off_rate"):
            if not 0.0 <= getattr(self, f) <= 1.0:
                raise ValueError(f"{f} must be in [0, 1]")
        if self.stuck_on_rate + self.stuck_off_rate > 1.0:
            raise ValueError("stuck_on_rate + stuck_off_rate must be <= 1")
        if self.ports < 1 or self.tr_span < 1:
            raise ValueError("ports and tr_span must be >= 1")

    @property
    def is_ideal(self) -> bool:
        """True when every non-ideality is switched off (bit-exact path)."""
        return (self.shift_fault_rate == 0.0 and self.read_sigma == 0.0
                and self.stuck_on_rate == 0.0 and self.stuck_off_rate == 0.0)

    @classmethod
    def racetrack(cls, **overrides) -> "RacetrackConfig":
        """Literature-flavored noisy device: ~0.2% misaligned tracks
        (the HDCR papers' shift-error regime), 2% TR sense fluctuation,
        5e-4 pinned domains per polarity."""
        base = dict(shift_fault_rate=2e-3, read_sigma=0.02,
                    stuck_on_rate=5e-4, stuck_off_rate=5e-4)
        base.update(overrides)
        return cls(**base)


def _key(cfg: RacetrackConfig, stream: int, source: int) -> jax.Array:
    """Deterministic sub-key: one per (bank, noise source)."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.key(cfg.seed), stream), source)


# Noise-source tags — one per physically distinct mechanism.
_FAULT, _SHIFT, _READ = 0, 1, 2


def _shift_offsets(cfg: RacetrackConfig, track_shape: tuple[int, ...],
                   stream: int) -> jax.Array:
    """Seeded per-track access misalignment: -1 / 0 / +1 domain offsets."""
    u = jax.random.uniform(_key(cfg, stream, _SHIFT), track_shape)
    return jnp.where(u < cfg.shift_fault_rate / 2, -1,
                     jnp.where(u < cfg.shift_fault_rate, 1, 0))


#: Declared racetrack-specific backend options (geometry/selection options
#: come from :data:`repro.accel.substrate.COMMON_OPTIONS`).
RACETRACK_OPTIONS: tuple[Option, ...] = (
    Option("preset", "str", "ideal", "named device parameterization "
           "(ideal = zero noise, racetrack = literature-flavored faults)",
           choices=("ideal", "racetrack")),
    Option("shift_fault_rate", "number", 0.0,
           "fraction of tracks with a permanent +-1 access misalignment",
           check=unit_interval),
    Option("read_sigma", "number", 0.0,
           "transverse-read sense-noise std per sqrt(active domain)",
           check=non_negative),
    Option("stuck_on_rate", "number", 0.0, "domains pinned at 1",
           check=unit_interval),
    Option("stuck_off_rate", "number", 0.0, "domains pinned at 0",
           check=unit_interval),
    Option("ports", "int", 4, "access ports per track (cost model)",
           check=lambda v: None if v >= 1 else "must be >= 1"),
    Option("tr_span", "int", 5, "domains sensed per transverse read "
           "(cost model)",
           check=lambda v: None if v >= 1 else "must be >= 1"),
)

_PRESETS = {"ideal": RacetrackConfig, "racetrack": RacetrackConfig.racetrack}


@dataclasses.dataclass(frozen=True)
class RacetrackSubstrate:
    """:class:`~repro.accel.substrate.Substrate` over domain-wall tracks.

    Stored state is the {0,1} domain-magnetization map (one track per
    trailing ``rows``-length slice).  ``read_weights`` applies the seeded
    shift-misalignment — a circular roll of the faulted tracks — so a
    misaligned track contributes systematically wrong partial counts on
    *every* read, which is exactly how shift errors bite in hardware.
    """

    config: RacetrackConfig = RacetrackConfig()

    name = "racetrack"

    @classmethod
    def from_options(cls, options: dict) -> "RacetrackSubstrate":
        opts = dict(options)
        preset = opts.pop("preset", "ideal")
        return cls(_PRESETS[preset](**opts))

    @property
    def is_ideal(self) -> bool:
        return self.config.is_ideal

    def program(self, bits: jax.Array, *, stream: int = 0) -> jax.Array:
        """Shift-in write: bits become domains, pinning sites win."""
        cfg = self.config
        state = bits.astype(jnp.float32)
        if cfg.stuck_on_rate > 0.0 or cfg.stuck_off_rate > 0.0:
            u = jax.random.uniform(_key(cfg, stream, _FAULT), state.shape)
            state = jnp.where(u < cfg.stuck_on_rate, 1.0, state)
            state = jnp.where(u > 1.0 - cfg.stuck_off_rate, 0.0, state)
        return state

    def read_weights(self, state: jax.Array, *, stream: int = 0
                     ) -> jax.Array:
        cfg = self.config
        if cfg.shift_fault_rate == 0.0:
            return state
        rows = state.shape[-1]
        off = _shift_offsets(cfg, state.shape[:-1], stream)
        idx = (jnp.arange(rows) + off[..., None]) % rows
        return jnp.take_along_axis(state, idx, axis=-1)

    def read_event_key(self, stream: int, digest) -> jax.Array:
        return jax.random.fold_in(_key(self.config, stream, _READ),
                                  jnp.asarray(digest, jnp.uint32))

    def read_noise(self, key: jax.Array, shape: tuple[int, ...],
                   active_rows: jax.Array) -> jax.Array:
        cfg = self.config
        if cfg.read_sigma == 0.0:
            return jnp.zeros(shape, jnp.float32)
        std = cfg.read_sigma * jnp.sqrt(
            jnp.maximum(active_rows.astype(jnp.float32), 0.0))
        return std * jax.random.normal(key, shape, jnp.float32)

    def fault_census(self, shape: tuple[int, ...], *, stream: int = 0
                     ) -> dict[str, int]:
        cfg = self.config
        n_on = n_off = n_mis = 0
        if cfg.stuck_on_rate > 0.0 or cfg.stuck_off_rate > 0.0:
            u = jax.random.uniform(_key(cfg, stream, _FAULT), shape)
            n_on = int(jnp.sum(u < cfg.stuck_on_rate))
            n_off = int(jnp.sum(u > 1.0 - cfg.stuck_off_rate))
        if cfg.shift_fault_rate > 0.0:
            n_mis = int(jnp.sum(_shift_offsets(cfg, shape[:-1], stream) != 0))
        return {"on": n_on, "off": n_off, "misaligned": n_mis}

    def cost(self, num_protos: int, dim: int, read_len: int, ngram: int,
             xcfg):
        from repro.accel import cost as cost_mod
        return cost_mod.racetrack_cost(num_protos, dim, read_len, ngram,
                                       xcfg, ports=self.config.ports,
                                       tr_span=self.config.tr_span)


@register_substrate("racetrack", RACETRACK_OPTIONS)
def _make_racetrack(options: dict) -> RacetrackSubstrate:
    return RacetrackSubstrate.from_options(options)
