"""Acc-Demeter device-model subsystem: the simulated PCM-crossbar substrate.

The paper's accelerator (§5-6) runs the AM search inside analog
memristor crossbars; this package models that substrate end to end so the
platform-independence claim is testable in software:

* :mod:`~repro.accel.device` — PCM cell physics: conductance levels,
  programming/read noise, drift, stuck-at faults (:class:`DeviceConfig`).
* :mod:`~repro.accel.crossbar` — differential crossbar tiling, bit-line
  current accumulation, behavioral ADC (:class:`CrossbarConfig`).
* :mod:`~repro.accel.backend_pcm` — the registered ``pcm_sim`` execution
  backend (bit-exact with ``reference`` at zero noise).
* :mod:`~repro.accel.cost` — analytical 65nm/PCM latency, energy and
  area model (:func:`accel_cost`, Table-3-style breakdowns).
* :mod:`~repro.accel.sweep` — accuracy-vs-non-ideality sweep harness
  (:func:`noise_sweep`).

See ``docs/ACC_DEMETER.md`` for the paper-section-to-module map.
"""

from repro.accel.device import DeviceConfig, program_conductances
from repro.accel.crossbar import (CrossbarConfig, adc_quantize,
                                  crossbar_agreement, program_prototypes)
from repro.accel.backend_pcm import PCMBackend, split_options
from repro.accel.cost import UMC65_PCM, CostReport, PCMChip, accel_cost
from repro.accel.sweep import SWEEPABLE, SweepPoint, noise_sweep

__all__ = [
    "DeviceConfig", "program_conductances",
    "CrossbarConfig", "adc_quantize", "crossbar_agreement",
    "program_prototypes",
    "PCMBackend", "split_options",
    "UMC65_PCM", "CostReport", "PCMChip", "accel_cost",
    "SWEEPABLE", "SweepPoint", "noise_sweep",
]
