"""Acc-Demeter device-model subsystem: simulated in-memory AM substrates.

The paper's accelerator (§5-6) runs the AM search inside analog
memristor crossbars; this package models that substrate end to end — and
generalizes it behind an explicit protocol, so the platform-independence
claim is testable in software on more than one device physics:

* :mod:`~repro.accel.substrate` — the :class:`Substrate` protocol
  (program / read-weights / noise-event / cost hooks) + the substrate
  registry and declared per-substrate options.
* :mod:`~repro.accel.device` — PCM cell physics: multi-bit conductance
  levels, programming/read noise, drift, stuck-at faults
  (:class:`DeviceConfig`, :class:`PCMSubstrate`).
* :mod:`~repro.accel.racetrack` — domain-wall nanowire physics:
  shift-based access faults, stuck domains, transverse-read sensing
  (:class:`RacetrackConfig`, :class:`RacetrackSubstrate`).
* :mod:`~repro.accel.crossbar` — substrate-generic differential tiling,
  bit-line accumulation, behavioral ADC (:class:`CrossbarConfig`).
* :mod:`~repro.accel.backend_pcm` — the registered ``pcm_sim`` and
  ``racetrack_sim`` execution backends (bit-exact with ``reference`` at
  zero noise on every substrate).
* :mod:`~repro.accel.cost` — analytical latency, energy and area models
  per substrate (:func:`accel_cost`, :func:`racetrack_cost`,
  Table-3-style breakdowns).
* :mod:`~repro.accel.sweep` — accuracy-vs-non-ideality sweep harness
  (:func:`noise_sweep`).
* :mod:`~repro.accel.codesign` — noise-aware RefDB co-design: fault-aware
  write-verify programming (:func:`write_verify_bits`) plus a
  margin-maximizing bundling pass on simulated readout, validation-gated
  (:func:`noise_aware_refdb`).

See ``docs/ACC_DEMETER.md`` for the paper-section-to-module map and the
substrate comparison matrix.
"""

from repro.accel.substrate import (Substrate, available_substrates,
                                   narrowed_schema, register_substrate,
                                   resolve_substrate, substrate_options,
                                   union_schema)
from repro.accel.device import (DeviceConfig, PCMSubstrate,
                                program_conductances)
from repro.accel.racetrack import RacetrackConfig, RacetrackSubstrate
from repro.accel.crossbar import (CrossbarConfig, adc_quantize,
                                  crossbar_agreement, program_prototypes,
                                  write_verify_bits)
from repro.accel.backend_pcm import (PCMBackend, PCMSimBackend,
                                     RacetrackSimBackend, SubstrateBackend,
                                     split_options)
from repro.accel.cost import (DW_RACETRACK, UMC65_PCM, CostReport, PCMChip,
                              RacetrackChip, accel_cost, racetrack_cost)
from repro.accel.sweep import SWEEPABLE, SweepPoint, noise_sweep
from repro.accel.codesign import noise_aware_refdb

__all__ = [
    "Substrate", "available_substrates", "narrowed_schema",
    "register_substrate", "resolve_substrate", "substrate_options",
    "union_schema",
    "DeviceConfig", "PCMSubstrate", "program_conductances",
    "RacetrackConfig", "RacetrackSubstrate",
    "CrossbarConfig", "adc_quantize", "crossbar_agreement",
    "program_prototypes", "write_verify_bits",
    "PCMBackend", "PCMSimBackend", "RacetrackSimBackend",
    "SubstrateBackend", "split_options",
    "DW_RACETRACK", "UMC65_PCM", "CostReport", "PCMChip", "RacetrackChip",
    "accel_cost", "racetrack_cost",
    "SWEEPABLE", "SweepPoint", "noise_sweep",
    "noise_aware_refdb",
]
