"""Tiled PCM-crossbar associative-memory search (paper §5.4).

The AM prototypes live as conductances in fixed-size crossbar arrays; a
query is applied as word-line voltages and each bit-line current is the
dot product of the query bits with one prototype's bits (Kirchhoff
accumulation).  Demeter's similarity is *agreement* (matching bits, both
1-1 and 0-0), so the simulator models the standard differential design:

  bank 0 stores the prototype bits      and is driven by the query bits,
  bank 1 stores the complement bits     and is driven by the complement,

``agreement = count(bank 0) + count(bank 1)``.

Physical arrays are ``rows x cols``: the HD dimension is split across
row tiles (each contributing a partial count, digitized by that tile's
ADC and accumulated digitally) and the prototype set is split across
column tiles.  Both tilings are expressed with ``vmap`` over a leading
tile axis, so a community whose AM spans hundreds of arrays is one
batched matmul, not a Python loop.

The ADC is behavioral: the analog front-end recovers a per-tile match
count in ``[0, rows]`` (current minus the ``g_off`` pedestal, divided by
the conductance window) and quantizes it to ``2**adc_bits`` uniform
levels.  With ``adc_bits >= log2(rows + 1)`` the step is one count and a
zero-noise read is bit-exact with the digital agreement — the property
``tests/test_accel.py`` pins down.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.accel import device
from repro.accel.device import DeviceConfig
from repro.core import bitops
from repro.core.bitops import pad_to_multiple


@dataclasses.dataclass(frozen=True)
class CrossbarConfig:
    """Frozen geometry of one physical crossbar array + its converters.

    Attributes:
      rows: word lines per array (HD dimensions per row tile).
      cols: bit lines per array (prototypes per column tile).
      adc_bits: ADC resolution; needs ``>= log2(rows + 1)`` for lossless
        count readout (the default 9 bits covers 256 rows), smaller
        values model a cheaper, lossy converter.
    """

    rows: int = 256
    cols: int = 256
    adc_bits: int = 9

    def __post_init__(self) -> None:
        if self.rows < 1 or self.cols < 1:
            raise ValueError("rows and cols must be >= 1")
        if self.adc_bits < 1:
            raise ValueError("adc_bits must be >= 1")

    @property
    def lossless(self) -> bool:
        """True when the ADC resolves every one of ``rows + 1`` counts."""
        return (1 << self.adc_bits) - 1 >= self.rows

    def num_tiles(self, dim: int, num_protos: int) -> tuple[int, int]:
        """(row tiles, column tiles) covering a ``dim x num_protos`` AM."""
        return (math.ceil(dim / self.rows),
                math.ceil(num_protos / self.cols))

    def num_arrays(self, dim: int, num_protos: int) -> int:
        """Physical arrays for one differential AM (both banks)."""
        rt, ct = self.num_tiles(dim, num_protos)
        return 2 * rt * ct


def _adc_params(cfg: CrossbarConfig) -> tuple[int, float]:
    """``(levels, step)`` of the ADC transfer function."""
    levels = (1 << cfg.adc_bits) - 1
    step = 1.0 if cfg.lossless else cfg.rows / levels
    return levels, step


def adc_quantize(count: jax.Array, cfg: CrossbarConfig) -> jax.Array:
    """Digitize an analog per-tile match count to the ADC's level grid.

    The full-scale range ``[0, rows]`` maps onto ``2**adc_bits - 1``
    uniform steps; when the ADC has at least ``rows + 1`` levels the step
    is clamped to exactly one count so quantization is the identity on
    integer counts (the lossless regime).
    """
    levels, step = _adc_params(cfg)
    code = jnp.clip(jnp.round(count / step), 0, levels)
    return code * step


def _bank_counts(qbits: jax.Array, gtiles: jax.Array, read_key: jax.Array,
                 xcfg: CrossbarConfig, dcfg: DeviceConfig, *,
                 with_clips: bool = False):
    """Analog partial-count readout of one bank, all tiles at once.

    Args:
      qbits: ``(T, B, rows)`` float32 query bits per row tile.
      gtiles: ``(T, S_pad, rows)`` float32 conductances per row tile.
      read_key: key for this bank's read event.
      xcfg / dcfg: geometry and device parameters.
      with_clips: also count ADC saturation events (codes the converter
        clamped to its range).  Trace-time static, so the default graph
        is untouched; the counts come from the same pre-clip codes the
        quantizer rounds, never a re-derivation.

    Returns:
      ``(B, S_pad)`` float32 accumulated (post-ADC) match counts; with
      ``with_clips`` a ``(counts, clip_count)`` pair.
    """
    levels, step = _adc_params(xcfg)

    def one_tile(q_tile, g_tile, key):
        active = q_tile.sum(axis=-1, keepdims=True)          # (B, 1)
        current = q_tile @ g_tile.T                          # (B, S_pad) µS
        current = current + device.bitline_read_noise(
            key, current.shape, active, dcfg)
        # The periphery divides out its reference-cell drift estimate
        # (drift_factor**drift_calibration), then inverts with the
        # *nominal* window and g_off pedestal (`active` is popcounted
        # digitally).  The residual drift scale error and any noise pass
        # through to the count — those ARE the non-idealities.
        calibrated = current / (dcfg.drift_factor ** dcfg.drift_calibration)
        count = (calibrated - dcfg.g_off_us * active) / dcfg.g_window_us
        if not with_clips:
            return adc_quantize(count, xcfg)
        code = jnp.round(count / step)
        clips = jnp.sum((code < 0) | (code > levels), dtype=jnp.int32)
        return adc_quantize(count, xcfg), clips

    keys = jax.random.split(read_key, qbits.shape[0])
    out = jax.vmap(one_tile)(qbits, gtiles, keys)
    if not with_clips:
        return out.sum(axis=0)
    counts, clips = out
    return counts.sum(axis=0), clips.sum()


def _to_row_tiles(bits: jax.Array, rows: int) -> jax.Array:
    """``(N, D)`` bits -> ``(T, N, rows)`` zero-padded row tiles."""
    padded = pad_to_multiple(bits, 1, rows)
    n, d_pad = padded.shape
    return jnp.moveaxis(padded.reshape(n, d_pad // rows, rows), 1, 0)


def program_prototypes(prototypes: jax.Array, xcfg: CrossbarConfig,
                       dcfg: DeviceConfig) -> tuple[jax.Array, jax.Array]:
    """Unpack + tile + program the packed AM into both conductance banks.

    Returns ``(g_pos, g_neg)`` each of shape ``(T, S_pad, rows)``: the
    per-row-tile conductance maps of the positive (bit) and complement
    banks.  Deterministic in ``dcfg.seed`` — reprogramming the same
    prototypes yields the same device, matching the paper's write-once
    AM discipline.
    """
    pbits = bitops.unpack_bits(prototypes).astype(jnp.float32)   # (S, D)
    pbits = pad_to_multiple(pbits, 0, xcfg.cols)
    # Complement before the dim-axis padding (inside _to_row_tiles): pad
    # cells must stay OFF in both banks so they never contribute current.
    pos = _to_row_tiles(pbits, xcfg.rows)
    neg = _to_row_tiles(1.0 - pbits, xcfg.rows)
    g_pos = device.program_conductances(pos, dcfg, stream=0)
    g_neg = device.program_conductances(neg, dcfg, stream=1)
    return g_pos, g_neg


def crossbar_read(queries: jax.Array, g_pos: jax.Array, g_neg: jax.Array,
                  dim: int, xcfg: CrossbarConfig, dcfg: DeviceConfig, *,
                  with_stats: bool = False):
    """One AM read event against already-programmed conductance banks.

    ``(B, W)`` packed queries vs the ``(T, S_pad, rows)`` banks from
    :func:`program_prototypes` -> ``(B, S_pad)`` int32 agreement
    estimates clipped to ``[0, dim]`` (callers slice off the padded
    prototype columns).  Splitting programming from reading mirrors the
    hardware's write-once/read-many discipline: a profiling session
    programs the AM once and issues one read per batch.

    With ``with_stats`` (trace-time static) the return is a ``(result,
    adc_clips)`` pair — the result math, noise keys and rounding are
    identical to the plain read; the extra output just counts the ADC
    codes that saturated.  The ``pcm_sim`` backend compiles this variant
    only when observability is enabled.
    """
    qbits = bitops.unpack_bits(queries).astype(jnp.float32)      # (B, D)
    q_pos = _to_row_tiles(qbits, xcfg.rows)
    q_neg = _to_row_tiles(1.0 - qbits, xcfg.rows)

    # One read event per distinct batch content, reproducibly keyed.
    digest = jnp.sum(queries, dtype=jnp.uint32)
    pos = _bank_counts(q_pos, g_pos, device.read_event_key(dcfg, 0, digest),
                       xcfg, dcfg, with_clips=with_stats)
    neg = _bank_counts(q_neg, g_neg, device.read_event_key(dcfg, 1, digest),
                       xcfg, dcfg, with_clips=with_stats)
    if with_stats:
        (c_pos, k_pos), (c_neg, k_neg) = pos, neg
        result = jnp.clip(jnp.round(c_pos + c_neg), 0, dim).astype(jnp.int32)
        return result, k_pos + k_neg
    return jnp.clip(jnp.round(pos + neg), 0, dim).astype(jnp.int32)


def crossbar_agreement(queries: jax.Array, prototypes: jax.Array, dim: int,
                       xcfg: CrossbarConfig, dcfg: DeviceConfig
                       ) -> jax.Array:
    """Full differential AM search: ``(B, W) x (S, W) -> (B, S)`` int32.

    Convenience composition of :func:`program_prototypes` +
    :func:`crossbar_read` for one-shot use; the ``pcm_sim`` backend
    caches the programmed banks instead so repeated batches against the
    same AM pay the programming cost once.  With ``dcfg.is_ideal`` and a
    lossless ADC the result equals the digital agreement exactly.
    """
    b, s = queries.shape[0], prototypes.shape[0]
    g_pos, g_neg = program_prototypes(prototypes, xcfg, dcfg)
    return crossbar_read(queries, g_pos, g_neg, dim, xcfg, dcfg)[:b, :s]
