"""Tiled in-memory associative-memory search, generic over substrates.

The AM prototypes live as physical state in fixed-size arrays; a query is
applied to the word lines and each bit line accumulates the dot product
of the query bits with one prototype's effective cell weights (Kirchhoff
accumulation on a crossbar, transverse-read popcounts on a racetrack).
Demeter's similarity is *agreement* (matching bits, both 1-1 and 0-0), so
the simulator models the standard differential design:

  bank 0 stores the prototype bits      and is driven by the query bits,
  bank 1 stores the complement bits     and is driven by the complement,

``agreement = count(bank 0) + count(bank 1)``.

Physical arrays are ``rows x cols``: the HD dimension is split across
row tiles (each contributing a partial count, digitized by that tile's
converter and accumulated digitally) and the prototype set is split
across column tiles.  Both tilings are expressed with ``vmap`` over a
leading tile axis, so a community whose AM spans hundreds of arrays is
one batched matmul, not a Python loop.

Everything device-physical is delegated to a
:class:`repro.accel.substrate.Substrate` (paper §5's PCM crossbar in
:mod:`repro.accel.device`, the racetrack alternative in
:mod:`repro.accel.racetrack`): programming turns bits into stored state,
``read_weights`` turns stored state into the effective per-cell weights
one read event sees, and ``read_noise`` adds that event's sensing noise
in count units.  The tiling, the differential trick and the behavioral
ADC below are substrate-independent.

The ADC is behavioral: the analog front-end recovers a per-tile match
count in ``[0, rows]`` and quantizes it to ``2**adc_bits`` uniform
levels.  With ``adc_bits >= log2(rows + 1)`` the step is one count and a
zero-noise read is bit-exact with the digital agreement — the property
the shared substrate contract test pins for every registered substrate.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.accel.substrate import Substrate
from repro.core import bitops
from repro.core.bitops import pad_to_multiple


@dataclasses.dataclass(frozen=True)
class CrossbarConfig:
    """Frozen geometry of one physical array + its converters.

    Attributes:
      rows: word lines per array (HD dimensions per row tile).
      cols: bit lines per array (prototypes per column tile).
      adc_bits: ADC resolution; needs ``>= log2(rows + 1)`` for lossless
        count readout (the default 9 bits covers 256 rows), smaller
        values model a cheaper, lossy converter.
    """

    rows: int = 256
    cols: int = 256
    adc_bits: int = 9

    def __post_init__(self) -> None:
        if self.rows < 1 or self.cols < 1:
            raise ValueError("rows and cols must be >= 1")
        if self.adc_bits < 1:
            raise ValueError("adc_bits must be >= 1")

    @property
    def lossless(self) -> bool:
        """True when the ADC resolves every one of ``rows + 1`` counts."""
        return (1 << self.adc_bits) - 1 >= self.rows

    def num_tiles(self, dim: int, num_protos: int) -> tuple[int, int]:
        """(row tiles, column tiles) covering a ``dim x num_protos`` AM."""
        return (math.ceil(dim / self.rows),
                math.ceil(num_protos / self.cols))

    def num_arrays(self, dim: int, num_protos: int) -> int:
        """Physical arrays for one differential AM (both banks)."""
        rt, ct = self.num_tiles(dim, num_protos)
        return 2 * rt * ct


def _adc_params(cfg: CrossbarConfig) -> tuple[int, float]:
    """``(levels, step)`` of the ADC transfer function."""
    levels = (1 << cfg.adc_bits) - 1
    step = 1.0 if cfg.lossless else cfg.rows / levels
    return levels, step


def adc_quantize(count: jax.Array, cfg: CrossbarConfig) -> jax.Array:
    """Digitize an analog per-tile match count to the ADC's level grid.

    The full-scale range ``[0, rows]`` maps onto ``2**adc_bits - 1``
    uniform steps; when the ADC has at least ``rows + 1`` levels the step
    is clamped to exactly one count so quantization is the identity on
    integer counts (the lossless regime).
    """
    levels, step = _adc_params(cfg)
    code = jnp.clip(jnp.round(count / step), 0, levels)
    return code * step


def _bank_counts(qbits: jax.Array, wtiles: jax.Array, read_key: jax.Array,
                 xcfg: CrossbarConfig, substrate: Substrate, *,
                 with_clips: bool = False):
    """Analog partial-count readout of one bank, all tiles at once.

    Args:
      qbits: ``(T, B, rows)`` float32 query bits per row tile.
      wtiles: ``(T, S_pad, rows)`` float32 *effective weights* per row
        tile — the substrate's ``read_weights`` applied to the programmed
        state, so on an ideal device this is exactly the stored bits.
      read_key: key for this bank's read event.
      xcfg / substrate: geometry and device model.
      with_clips: also count ADC saturation events (codes the converter
        clamped to its range).  Trace-time static, so the default graph
        is untouched; the counts come from the same pre-clip codes the
        quantizer rounds, never a re-derivation.

    Returns:
      ``(B, S_pad)`` float32 accumulated (post-ADC) match counts; with
      ``with_clips`` a ``(counts, clip_count)`` pair.
    """
    levels, step = _adc_params(xcfg)

    def one_tile(q_tile, w_tile, key):
        active = q_tile.sum(axis=-1, keepdims=True)          # (B, 1)
        count = q_tile @ w_tile.T                            # (B, S_pad)
        count = count + substrate.read_noise(key, count.shape, active)
        if not with_clips:
            return adc_quantize(count, xcfg)
        code = jnp.round(count / step)
        clips = jnp.sum((code < 0) | (code > levels), dtype=jnp.int32)
        return adc_quantize(count, xcfg), clips

    keys = jax.random.split(read_key, qbits.shape[0])
    out = jax.vmap(one_tile)(qbits, wtiles, keys)
    if not with_clips:
        return out.sum(axis=0)
    counts, clips = out
    return counts.sum(axis=0), clips.sum()


def _to_row_tiles(bits: jax.Array, rows: int) -> jax.Array:
    """``(N, D)`` bits -> ``(T, N, rows)`` zero-padded row tiles."""
    padded = pad_to_multiple(bits, 1, rows)
    n, d_pad = padded.shape
    return jnp.moveaxis(padded.reshape(n, d_pad // rows, rows), 1, 0)


def program_prototypes(prototypes: jax.Array, xcfg: CrossbarConfig,
                       substrate: Substrate) -> tuple[jax.Array, jax.Array]:
    """Unpack + tile + program the packed AM into both physical banks.

    Returns ``(state_pos, state_neg)`` each of shape ``(T, S_pad, rows)``:
    the per-row-tile stored state of the positive (bit) and complement
    banks.  Deterministic in the substrate's seed — reprogramming the
    same prototypes yields the same device, matching the paper's
    write-once AM discipline.
    """
    pbits = bitops.unpack_bits(prototypes).astype(jnp.float32)   # (S, D)
    pbits = pad_to_multiple(pbits, 0, xcfg.cols)
    # Complement before the dim-axis padding (inside _to_row_tiles): pad
    # cells must stay OFF in both banks so they never contribute current.
    pos = _to_row_tiles(pbits, xcfg.rows)
    neg = _to_row_tiles(1.0 - pbits, xcfg.rows)
    return (substrate.program(pos, stream=0),
            substrate.program(neg, stream=1))


def crossbar_read(queries: jax.Array, s_pos: jax.Array, s_neg: jax.Array,
                  dim: int, xcfg: CrossbarConfig, substrate: Substrate, *,
                  with_stats: bool = False):
    """One AM read event against already-programmed banks.

    ``(B, W)`` packed queries vs the ``(T, S_pad, rows)`` banks from
    :func:`program_prototypes` -> ``(B, S_pad)`` int32 agreement
    estimates clipped to ``[0, dim]`` (callers slice off the padded
    prototype columns).  Splitting programming from reading mirrors the
    hardware's write-once/read-many discipline: a profiling session
    programs the AM once and issues one read per batch.

    With ``with_stats`` (trace-time static) the return is a ``(result,
    adc_clips)`` pair — the result math, noise keys and rounding are
    identical to the plain read; the extra output just counts the ADC
    codes that saturated.  The substrate backends compile this variant
    only when observability is enabled.
    """
    qbits = bitops.unpack_bits(queries).astype(jnp.float32)      # (B, D)
    q_pos = _to_row_tiles(qbits, xcfg.rows)
    q_neg = _to_row_tiles(1.0 - qbits, xcfg.rows)
    w_pos = substrate.read_weights(s_pos, stream=0)
    w_neg = substrate.read_weights(s_neg, stream=1)

    # One read event per distinct batch content, reproducibly keyed.
    digest = jnp.sum(queries, dtype=jnp.uint32)
    pos = _bank_counts(q_pos, w_pos, substrate.read_event_key(0, digest),
                       xcfg, substrate, with_clips=with_stats)
    neg = _bank_counts(q_neg, w_neg, substrate.read_event_key(1, digest),
                       xcfg, substrate, with_clips=with_stats)
    if with_stats:
        (c_pos, k_pos), (c_neg, k_neg) = pos, neg
        result = jnp.clip(jnp.round(c_pos + c_neg), 0, dim).astype(jnp.int32)
        return result, k_pos + k_neg
    return jnp.clip(jnp.round(pos + neg), 0, dim).astype(jnp.int32)


def _roll_tracks(x: jax.Array, k: jax.Array) -> jax.Array:
    """Per-track circular roll: ``out[..., j] = x[..., (j - k) % rows]``.

    ``k`` broadcasts over the leading (track) axes; a scalar 0 is the
    identity.  Used to move between *stored* and *observed* domain
    positions once a track's access misalignment is known.
    """
    rows = x.shape[-1]
    idx = (jnp.arange(rows) - k[..., None]) % rows
    return jnp.take_along_axis(x, idx, axis=-1)


def write_verify_bits(prototypes: jax.Array, xcfg: CrossbarConfig,
                      substrate: Substrate, *,
                      probe_seed: int = 0x5EED) -> jax.Array:
    """Fault-aware programming: pick stored bits that minimize readout bias.

    The write-verify discipline every production PCM/racetrack part
    ships with, applied to the AM: before committing the prototypes, the
    programmer *probes* the device and then chooses, cell by cell, the
    stored bit whose readout lands closest to the intended content.

    Three probe programs per bank fully identify the (deterministic)
    device transfer:

    * all-zeros / all-ones — the per-cell read-back ``W0``/``W1`` at each
      observed position, capturing stuck cells, programming error and
      residual drift exactly (the simulator keys static non-idealities by
      (seed, bank, shape), never by the programmed pattern, mirroring
      defects that live in the cell rather than the pulse);
    * a fixed pseudo-random pattern — exposes per-track access
      *misalignment* (racetrack shift faults): the observed read of track
      ``t`` matches ``W0 + (W1 - W0) * roll(pattern, k)`` only at the
      track's true offset ``k``.

    A stored bit ``b`` at track position ``i`` is then read at observed
    position ``i + k`` paired with query bit ``i + k``, so the bias-
    minimizing choice is per-dim independent across the differential
    pair: ``err(b) = |pos_read(b) - c| + |neg_read(1-b) - (1-c)|`` with
    ``c`` the bundled content bit, ties keeping ``c``.  A stuck-ON cell
    under a stored 0 inflates *every* read by one count — flipping that
    stored bit trades one bit of bundle content for removing the
    deterministic bias, and a misaligned track gets its content stored
    pre-rolled so the faulty access presents it correctly.

    Ideal substrates (and the digital backends, which never call this)
    are a no-op — the returned array is ``prototypes`` itself, keeping
    the zero-noise path bit-exact by construction.
    """
    if substrate.is_ideal:
        return prototypes
    pbits = bitops.unpack_bits(prototypes).astype(jnp.float32)   # (S, D)
    s, d = pbits.shape
    padded = pad_to_multiple(pbits, 0, xcfg.cols)
    pos_c = _to_row_tiles(padded, xcfg.rows)                     # (T, S_pad, R)
    neg_c = _to_row_tiles(1.0 - padded, xcfg.rows)
    shape = pos_c.shape

    probe = (jax.random.uniform(jax.random.key(probe_seed), shape)
             < 0.5).astype(jnp.float32)
    offsets = (-1, 0, 1)

    def transfer(stream: int):
        def readback(bits):
            return substrate.read_weights(
                substrate.program(bits, stream=stream), stream=stream)
        w0 = readback(jnp.zeros(shape, jnp.float32))
        w1 = readback(jnp.ones(shape, jnp.float32))
        wr = readback(probe)
        preds = jnp.stack([w0 + (w1 - w0) * jnp.roll(probe, k, axis=-1)
                           for k in offsets])
        err = jnp.abs(preds - wr[None]).sum(axis=-1)             # (K, T, S_pad)
        k = jnp.asarray(offsets)[jnp.argmin(err, axis=0)]        # (T, S_pad)
        # align the observed-position transfer back to stored positions:
        # stored bit i is read at observed position i + k
        return _roll_tracks(w0, -k), _roll_tracks(w1, -k), k

    p0, p1, k_pos = transfer(0)
    n0, n1, k_neg = transfer(1)
    # content targets at the observed (query-paired) positions
    c_pos = _roll_tracks(pos_c, -k_pos)
    c_neg = _roll_tracks(neg_c, -k_neg)
    err0 = jnp.abs(p0 - c_pos) + jnp.abs(n1 - c_neg)   # store 0: neg holds 1
    err1 = jnp.abs(p1 - c_pos) + jnp.abs(n0 - c_neg)   # store 1: neg holds 0
    chosen = jnp.where(err1 < err0, 1.0,
                       jnp.where(err0 < err1, 0.0, pos_c))
    flat = jnp.moveaxis(chosen, 0, 1).reshape(shape[1], -1)[:s, :d]
    return bitops.pack_bits(flat.astype(jnp.uint8))


def crossbar_agreement(queries: jax.Array, prototypes: jax.Array, dim: int,
                       xcfg: CrossbarConfig, substrate: Substrate
                       ) -> jax.Array:
    """Full differential AM search: ``(B, W) x (S, W) -> (B, S)`` int32.

    Convenience composition of :func:`program_prototypes` +
    :func:`crossbar_read` for one-shot use; the substrate backends cache
    the programmed banks instead so repeated batches against the same AM
    pay the programming cost once.  With ``substrate.is_ideal`` and a
    lossless ADC the result equals the digital agreement exactly.
    """
    b, s = queries.shape[0], prototypes.shape[0]
    state_pos, state_neg = program_prototypes(prototypes, xcfg, substrate)
    return crossbar_read(queries, state_pos, state_neg, dim, xcfg,
                         substrate)[:b, :s]
