"""``pcm_sim`` / ``racetrack_sim``: the simulated-substrate backends.

One generic :class:`SubstrateBackend` runs the AM search (step 4) through
the substrate-generic differential array simulator of
:mod:`repro.accel.crossbar`, while read conversion (step 3) stays on the
digital reference encoder — mirroring the paper's split between
Acc-Demeter's CMOS encoding periphery (§5.2-5.3) and its analog
in-memory AM (§5.4).  Because ``encode`` is bit-exact with every other
backend, the RefDB cache remains shared across all backends and the
digital prototypes are what gets "programmed" (with noise) into the
device on each search.

Which device physics runs underneath is a registered
:class:`repro.accel.substrate.Substrate`; the two backend names are the
same class with different default substrates, and the ``substrate``
option can override either::

    ProfilerConfig(backend="pcm_sim",
                   backend_options={"preset": "pcm", "levels": 4,
                                    "read_sigma": 0.5, "adc_bits": 8})
    ProfilerConfig(backend="racetrack_sim",
                   backend_options={"preset": "racetrack", "seed": 1})

Every option is declared (see ``profile_run --list-backends``): the
registered schema is the union over substrates, and once the substrate is
chosen the option set narrows to geometry + that substrate's knobs, so a
PCM-only knob under ``substrate=racetrack`` fails with the uniform
unknown-option error.  With default (ideal, zero-noise) options both
backends are bit-exact with ``reference`` — enforced per substrate by the
shared contract test — and with noise enabled they are deterministic in
the ``seed`` option.
"""

from __future__ import annotations

import functools

import jax

from repro import obs
from repro.accel import device as _device          # registers "pcm"
from repro.accel import racetrack as _racetrack    # registers "racetrack"
from repro.accel import substrate as substrate_mod
from repro.accel.crossbar import (CrossbarConfig, crossbar_read,
                                  program_prototypes)
from repro.accel.substrate import (CROSSBAR_KEYS, Substrate,
                                   resolve_substrate, union_schema)
from repro.pipeline.backend import ReferenceBackend, register_backend
from repro.pipeline.config import ProfilerConfig

del _device, _racetrack  # imported for their registration side effects


def split_options(options: dict, *, backend: str = "pcm_sim",
                  default_substrate: str = "pcm"
                  ) -> tuple[CrossbarConfig, Substrate]:
    """Build ``(CrossbarConfig, Substrate)`` from flat backend options.

    The flat dict is validated against the substrate-narrowed schema
    (geometry keys + the selected substrate's declared knobs), then split:
    geometry to :class:`CrossbarConfig`, the rest to the substrate
    factory.  Unknown names or mistyped values raise the uniform
    friendly ``ValueError`` (so CLI typos surface as messages, not
    tracebacks from deep inside jax).
    """
    sub_name = options.get("substrate", default_substrate)
    if not isinstance(sub_name, str) \
            or sub_name not in substrate_mod.available_substrates():
        # Normally pre-empted by the union schema's choices check; kept
        # for direct callers of this function.
        raise ValueError(
            f"{backend} option 'substrate' must be one of "
            f"{list(substrate_mod.available_substrates())}, got {sub_name!r}")
    narrowed = substrate_mod.narrowed_schema(backend, sub_name)
    own, _ = narrowed.validate(options)
    xcfg = CrossbarConfig(**{k: v for k, v in own.items()
                             if k in CROSSBAR_KEYS})
    sub_opts = {k: v for k, v in own.items()
                if k not in CROSSBAR_KEYS and k != "substrate"}
    return xcfg, resolve_substrate(sub_name, sub_opts)


class SubstrateBackend(ReferenceBackend):
    """Digital reference encoder + simulated in-memory AM search.

    The physical banks are programmed once per distinct prototype array
    and cached (the hardware's write-once/read-many discipline): every
    subsequent batch pays only the array *read*.  The cache holds a
    strong reference to the prototype array it was programmed from, so
    the identity check can never alias a recycled ``id``.
    """

    name = "abstract_substrate"
    default_substrate = "pcm"

    def __init__(self, config: ProfilerConfig):
        super().__init__(config)
        self.crossbar_config, self.substrate = split_options(
            config.options, backend=self.name,
            default_substrate=self.default_substrate)
        self._program = jax.jit(functools.partial(
            program_prototypes, xcfg=self.crossbar_config,
            substrate=self.substrate))
        self._read = jax.jit(functools.partial(
            crossbar_read, dim=self.space.dim, xcfg=self.crossbar_config,
            substrate=self.substrate))
        # The stats read is a *separate* compiled graph (identical result
        # math, one extra clip-count output) used only when observability
        # is on — the plain read path is byte-for-byte what it always was.
        self._read_stats = jax.jit(functools.partial(
            crossbar_read, dim=self.space.dim, xcfg=self.crossbar_config,
            substrate=self.substrate, with_stats=True))
        self._programmed: tuple[jax.Array, jax.Array, jax.Array] | None = None
        prefix = self.name.removesuffix("_sim")
        self._obs = obs.resolve_metrics(None)
        self._m_prog_events = self._obs.counter(
            f"{prefix}_program_events_total",
            "Array programming events (prototype-array cache misses).")
        self._m_reads = self._obs.counter(
            f"{prefix}_reads_total", "AM read events (one per batch).")
        self._m_adc_clips = self._obs.counter(
            f"{prefix}_adc_clips_total",
            "Converter codes saturated at the range limits.")
        self._m_stuck = self._obs.gauge(
            f"{prefix}_stuck_cells",
            "Static fault sites in the programmed banks, by kind.")

    def agreement(self, queries: jax.Array, prototypes: jax.Array
                  ) -> jax.Array:
        b, s = queries.shape[0], prototypes.shape[0]
        if isinstance(prototypes, jax.core.Tracer):
            # Inside someone else's jit: programming must stay in-graph
            # (and tracers must not leak into the cache).  No metrics
            # here — nothing host-side may touch a traced value.
            s_pos, s_neg = self._program(prototypes)
            return self._read(queries, s_pos, s_neg)[:b, :s]
        if self._programmed is None or self._programmed[0] is not prototypes:
            self._programmed = (prototypes, *self._program(prototypes))
            if self._obs.enabled:
                self._note_programmed(self._programmed[1].shape)
        _, s_pos, s_neg = self._programmed
        if self._obs.enabled:
            out, clips = self._read_stats(queries, s_pos, s_neg)
            self._m_reads.inc(1)
            self._m_adc_clips.inc(int(clips))
            return out[:b, :s]
        return self._read(queries, s_pos, s_neg)[:b, :s]

    def _note_programmed(self, bank_shape: tuple[int, ...]) -> None:
        """Record one programming event + the banks' fault census."""
        self._m_prog_events.inc(1)
        for stream, bank in ((0, "pos"), (1, "neg")):
            census = self.substrate.fault_census(bank_shape, stream=stream)
            for kind, n in census.items():
                self._m_stuck.set(n, bank=bank, polarity=kind)


@register_backend("pcm_sim", schema=union_schema("pcm_sim", "pcm"))
class PCMSimBackend(SubstrateBackend):
    """The simulated AM search on the PCM crossbar substrate."""

    name = "pcm_sim"
    default_substrate = "pcm"


@register_backend("racetrack_sim",
                  schema=union_schema("racetrack_sim", "racetrack"))
class RacetrackSimBackend(SubstrateBackend):
    """The simulated AM search on the racetrack (domain-wall) substrate."""

    name = "racetrack_sim"
    default_substrate = "racetrack"


#: historical alias (the backend predates the substrate split).
PCMBackend = PCMSimBackend
