"""``pcm_sim``: the Acc-Demeter simulated-substrate execution backend.

Registers a fifth backend in the :mod:`repro.pipeline.backend` registry
whose AM search (step 4) runs through the simulated differential PCM
crossbar of :mod:`repro.accel.crossbar`, while read conversion (step 3)
stays on the digital reference encoder — mirroring the paper's split
between Acc-Demeter's CMOS encoding periphery (§5.2-5.3) and its analog
in-memory AM (§5.4).  Because ``encode`` is bit-exact with every other
backend, the RefDB cache remains shared across all backends and the
digital prototypes are what gets "programmed" (with noise) into the
crossbar on each search.

Device and geometry knobs thread through ``ProfilerConfig.backend_options``::

    ProfilerConfig(backend="pcm_sim",
                   backend_options={"preset": "pcm", "read_sigma": 0.05,
                                    "rows": 256, "adc_bits": 8, "seed": 1})

With the default (ideal, zero-noise) options the backend is bit-exact
with ``reference`` — enforced by the registry-wide parity tests — and
with noise enabled it is deterministic in the ``seed`` option.
"""

from __future__ import annotations

import dataclasses
import functools

import jax

from repro import obs
from repro.accel import device
from repro.accel.crossbar import (CrossbarConfig, crossbar_read,
                                  program_prototypes)
from repro.accel.device import DeviceConfig
from repro.pipeline.backend import ReferenceBackend, register_backend
from repro.pipeline.config import ProfilerConfig

#: Option names routed to CrossbarConfig; everything else goes to
#: DeviceConfig (plus the "preset" selector handled here).
_CROSSBAR_KEYS = frozenset(f.name for f in dataclasses.fields(CrossbarConfig))
_DEVICE_KEYS = frozenset(f.name for f in dataclasses.fields(DeviceConfig))
_INT_KEYS = _CROSSBAR_KEYS | {"seed"}

_PRESETS = {
    "ideal": DeviceConfig,
    "pcm": DeviceConfig.pcm,
}


def split_options(options: dict) -> tuple[CrossbarConfig, DeviceConfig]:
    """Build (CrossbarConfig, DeviceConfig) from flat backend options.

    ``preset`` selects the device baseline ("ideal" default, "pcm" =
    literature-parameterized noisy device); named device fields override
    the preset; unknown names or mistyped values raise a ValueError
    naming the option (so CLI typos surface as messages, not tracebacks
    from deep inside jax).
    """
    opts = dict(options)
    preset = opts.pop("preset", "ideal")
    if not isinstance(preset, str) or preset not in _PRESETS:
        raise ValueError(f"unknown pcm_sim preset {preset!r}; "
                         f"choose from {sorted(_PRESETS)}")
    unknown = set(opts) - _CROSSBAR_KEYS - _DEVICE_KEYS
    if unknown:
        raise ValueError(
            f"unknown pcm_sim option(s) {sorted(unknown)}; valid: "
            f"{sorted(_CROSSBAR_KEYS | _DEVICE_KEYS | {'preset'})}")
    for name, value in opts.items():
        if name in _INT_KEYS:
            if isinstance(value, bool) or not isinstance(value, int):
                raise ValueError(f"pcm_sim option {name!r} must be an "
                                 f"integer, got {value!r}")
        elif isinstance(value, bool) or not isinstance(value, (int, float)):
            raise ValueError(f"pcm_sim option {name!r} must be a number, "
                             f"got {value!r}")
    xcfg = CrossbarConfig(**{k: v for k, v in opts.items()
                             if k in _CROSSBAR_KEYS})
    dcfg = _PRESETS[preset](**{k: v for k, v in opts.items()
                               if k in _DEVICE_KEYS})
    return xcfg, dcfg


@register_backend("pcm_sim")
class PCMBackend(ReferenceBackend):
    """Digital reference encoder + simulated PCM-crossbar AM search.

    The conductance banks are programmed once per distinct prototype
    array and cached (the hardware's write-once/read-many discipline):
    every subsequent batch pays only the crossbar *read*.  The cache
    holds a strong reference to the prototype array it was programmed
    from, so the identity check can never alias a recycled ``id``.
    """

    name = "pcm_sim"

    def __init__(self, config: ProfilerConfig):
        super().__init__(config)
        self.crossbar_config, self.device_config = split_options(
            config.options)
        self._program = jax.jit(functools.partial(
            program_prototypes, xcfg=self.crossbar_config,
            dcfg=self.device_config))
        self._read = jax.jit(functools.partial(
            crossbar_read, dim=self.space.dim, xcfg=self.crossbar_config,
            dcfg=self.device_config))
        # The stats read is a *separate* compiled graph (identical result
        # math, one extra clip-count output) used only when observability
        # is on — the plain read path is byte-for-byte what it always was.
        self._read_stats = jax.jit(functools.partial(
            crossbar_read, dim=self.space.dim, xcfg=self.crossbar_config,
            dcfg=self.device_config, with_stats=True))
        self._programmed: tuple[jax.Array, jax.Array, jax.Array] | None = None
        self._obs = obs.resolve_metrics(None)
        self._m_prog_events = self._obs.counter(
            "pcm_program_events_total",
            "Crossbar programming events (prototype-array cache misses).")
        self._m_reads = self._obs.counter(
            "pcm_reads_total", "Crossbar AM read events (one per batch).")
        self._m_adc_clips = self._obs.counter(
            "pcm_adc_clips_total",
            "ADC codes saturated at the converter's range limits.")
        self._m_stuck = self._obs.gauge(
            "pcm_stuck_cells",
            "Stuck-at fault cells in the programmed banks, by polarity.")

    def agreement(self, queries: jax.Array, prototypes: jax.Array
                  ) -> jax.Array:
        b, s = queries.shape[0], prototypes.shape[0]
        if isinstance(prototypes, jax.core.Tracer):
            # Inside someone else's jit: programming must stay in-graph
            # (and tracers must not leak into the cache).  No metrics
            # here — nothing host-side may touch a traced value.
            g_pos, g_neg = self._program(prototypes)
            return self._read(queries, g_pos, g_neg)[:b, :s]
        if self._programmed is None or self._programmed[0] is not prototypes:
            self._programmed = (prototypes, *self._program(prototypes))
            if self._obs.enabled:
                self._note_programmed(self._programmed[1].shape)
        _, g_pos, g_neg = self._programmed
        if self._obs.enabled:
            out, clips = self._read_stats(queries, g_pos, g_neg)
            self._m_reads.inc(1)
            self._m_adc_clips.inc(int(clips))
            return out[:b, :s]
        return self._read(queries, g_pos, g_neg)[:b, :s]

    def _note_programmed(self, bank_shape: tuple[int, ...]) -> None:
        """Record one programming event + the banks' stuck-cell census."""
        self._m_prog_events.inc(1)
        for stream, bank in ((0, "pos"), (1, "neg")):
            n_on, n_off = device.stuck_cell_counts(
                bank_shape, self.device_config, stream=stream)
            self._m_stuck.set(n_on, bank=bank, polarity="on")
            self._m_stuck.set(n_off, bank=bank, polarity="off")
