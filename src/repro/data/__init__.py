"""Data pipelines: deterministic synthetic LM streams + genomics reads."""

from repro.data.lm_data import DataConfig, batch_at

__all__ = ["DataConfig", "batch_at"]
