"""Deterministic synthetic LM data pipeline (training substrate).

Two token sources:

* ``genome_stream`` — DNA tokens from the synthetic community mapped into
  the model vocab; the "food profiling meets LM" corpus used by examples.
* ``structured_stream`` — a mixture of copy/repeat/arithmetic patterns
  with genuine sequential structure, so a ~100M model's loss visibly
  drops within a few hundred steps (examples/train_lm.py).

Determinism contract (fault tolerance): ``batch_at(step)`` is a pure
function of (seed, step), so a restarted job replays the identical data
order with no iterator state to checkpoint.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "structured"          # structured | genome


def _structured_row(rng: np.random.Generator, seq_len: int, vocab: int
                    ) -> np.ndarray:
    """One sequence with learnable structure."""
    mode = rng.integers(0, 3)
    usable = max(vocab - 4, 8)
    if mode == 0:                     # periodic repeat of a random motif
        p = int(rng.integers(2, 9))
        motif = rng.integers(0, usable, p)
        reps = -(-seq_len // p)
        return np.tile(motif, reps)[:seq_len].astype(np.int32)
    if mode == 1:                     # arithmetic ramp mod usable
        start = int(rng.integers(0, usable))
        stride = int(rng.integers(1, 5))
        return ((start + stride * np.arange(seq_len)) % usable).astype(np.int32)
    # copy task: random prefix, then the same prefix again, repeated
    half = max(seq_len // 2, 1)
    prefix = rng.integers(0, usable, half)
    reps = -(-seq_len // half)
    return np.tile(prefix, reps)[:seq_len].astype(np.int32)


def batch_at(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Batch for a given step: {'tokens', 'labels'} (labels = shifted)."""
    rng = np.random.default_rng((cfg.seed << 32) ^ step)
    b, s = cfg.global_batch, cfg.seq_len
    if cfg.kind == "genome":
        toks = rng.integers(0, 4, (b, s + 1)).astype(np.int32)
    else:
        toks = np.stack([_structured_row(rng, s + 1, cfg.vocab)
                         for _ in range(b)])
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}
