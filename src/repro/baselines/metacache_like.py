"""MetaCache-style baseline: context-aware minhash sketching.

MetaCache sketches genome windows with minhash (the w smallest k-mer
hashes per window) and classifies reads by matching read sketches against
window sketches, accumulating votes per species.  This keeps the database
much smaller than Kraken2's while staying the accuracy reference in the
paper's comparisons.
"""

from __future__ import annotations

import numpy as np

from repro.baselines import kmer_table
from repro.core import classifier
from repro.genomics import kmers


class MetaCacheLike:
    name = "metacache-like"

    def __init__(self, k: int = 16, window: int = 128, sketch: int = 16,
                 min_hits: int = 2):
        self.k = k
        self.window = window
        self.sketch = sketch
        self.min_hits = min_hits
        self.table: kmer_table.KmerTable | None = None

    def _sketch(self, h: np.ndarray) -> np.ndarray:
        if len(h) <= self.sketch:
            return np.unique(h)
        return np.unique(np.partition(h, self.sketch)[:self.sketch])

    def build(self, genomes: dict[str, np.ndarray]) -> "MetaCacheLike":
        num_species = len(genomes)
        hashes, masks = [], []
        for s, toks in enumerate(genomes.values()):
            sketches = []
            for start in range(0, max(len(toks) - self.k + 1, 1), self.window):
                win = toks[start:start + self.window + self.k - 1]
                h = kmers.splitmix64(kmers.pack_kmers(win, self.k))
                if len(h):
                    sketches.append(self._sketch(h))
            if sketches:
                hs = np.unique(np.concatenate(sketches))
                hashes.append(hs)
                masks.append(np.full(len(hs), np.uint64(1) << np.uint64(s)))
        all_h = np.concatenate(hashes)
        all_m = np.concatenate(masks)
        order = np.argsort(all_h, kind="stable")
        all_h, all_m = all_h[order], all_m[order]
        uniq, start = np.unique(all_h, return_index=True)
        merged = np.bitwise_or.reduceat(all_m, start)
        self.table = kmer_table.KmerTable(hashes=uniq, masks=merged,
                                          num_species=num_species, k=self.k)
        return self

    def memory_bytes(self) -> int:
        assert self.table is not None
        return self.table.memory_bytes()

    def classify_reads(self, tokens: np.ndarray, lengths: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray]:
        assert self.table is not None, "call build() first"
        s = self.table.num_species
        r = len(tokens)
        hits = np.zeros((r, s), bool)
        for i in range(r):
            h = kmers.read_kmer_hashes(tokens[i], int(lengths[i]), self.k)
            sk = self._sketch(h) if len(h) else h
            votes = kmer_table.masks_to_votes(self.table.lookup_masks(sk), s)
            top = votes.max() if len(votes) else 0
            if top >= self.min_hits:
                hits[i] = votes == top
        n = hits.sum(axis=1)
        category = np.where(n == 0, classifier.UNMAPPED,
                            np.where(n == 1, classifier.UNIQUE,
                                     classifier.MULTI)).astype(np.int32)
        return hits, category
