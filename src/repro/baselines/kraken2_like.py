"""Kraken2-style baseline: exact k-mer hash lookups + per-read voting.

Faithful to Kraken2's classification logic at species rank with a flat
taxonomy: every k-mer of the read votes for the species containing it;
the read is assigned to the max-vote species (ties -> multi-assignment,
matching LCA semantics flattened to species level); reads with fewer than
``min_hits`` voting k-mers stay unclassified.  Minimizer database
subsampling is exposed as ``subsample``.
"""

from __future__ import annotations

import numpy as np

from repro.baselines import kmer_table
from repro.core import classifier
from repro.genomics import kmers


class Kraken2Like:
    name = "kraken2-like"

    def __init__(self, k: int = 21, subsample: int = 1, min_hits: int = 2):
        self.k = k
        self.subsample = subsample
        self.min_hits = min_hits
        self.table: kmer_table.KmerTable | None = None

    def build(self, genomes: dict[str, np.ndarray]) -> "Kraken2Like":
        self.table = kmer_table.build_table(genomes, self.k,
                                            subsample=self.subsample)
        return self

    def memory_bytes(self) -> int:
        assert self.table is not None
        return self.table.memory_bytes()

    def classify_reads(self, tokens: np.ndarray, lengths: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray]:
        """Returns (hits (R,S) bool, category (R,) int32)."""
        assert self.table is not None, "call build() first"
        s = self.table.num_species
        r = len(tokens)
        hits = np.zeros((r, s), bool)
        for i in range(r):
            h = kmers.read_kmer_hashes(tokens[i], int(lengths[i]), self.k)
            votes = kmer_table.masks_to_votes(self.table.lookup_masks(h), s)
            top = votes.max() if len(votes) else 0
            if top >= self.min_hits:
                hits[i] = votes == top
        n = hits.sum(axis=1)
        category = np.where(n == 0, classifier.UNMAPPED,
                            np.where(n == 1, classifier.UNIQUE,
                                     classifier.MULTI)).astype(np.int32)
        return hits, category
