"""CLARK-style baseline: voting restricted to *discriminative* k-mers.

CLARK discards any k-mer shared by more than one target; classification
then uses only species-unique k-mers, which makes unique assignments very
precise but loses reads falling entirely in homologous regions.
"""

from __future__ import annotations

import numpy as np

from repro.baselines import kmer_table
from repro.core import classifier
from repro.genomics import kmers


class ClarkLike:
    name = "clark-like"

    def __init__(self, k: int = 21, min_hits: int = 2):
        self.k = k
        self.min_hits = min_hits
        self.table: kmer_table.KmerTable | None = None

    def build(self, genomes: dict[str, np.ndarray]) -> "ClarkLike":
        t = kmer_table.build_table(genomes, self.k)
        # Keep only k-mers whose mask has exactly one set bit.
        m = t.masks
        discriminative = (m & (m - np.uint64(1))) == np.uint64(0)
        self.table = kmer_table.KmerTable(
            hashes=t.hashes[discriminative], masks=m[discriminative],
            num_species=t.num_species, k=t.k)
        return self

    def memory_bytes(self) -> int:
        assert self.table is not None
        return self.table.memory_bytes()

    def classify_reads(self, tokens: np.ndarray, lengths: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray]:
        assert self.table is not None, "call build() first"
        s = self.table.num_species
        r = len(tokens)
        hits = np.zeros((r, s), bool)
        for i in range(r):
            h = kmers.read_kmer_hashes(tokens[i], int(lengths[i]), self.k)
            votes = kmer_table.masks_to_votes(self.table.lookup_masks(h), s)
            top = votes.max() if len(votes) else 0
            if top >= self.min_hits:
                hits[i] = votes == top
        n = hits.sum(axis=1)
        category = np.where(n == 0, classifier.UNMAPPED,
                            np.where(n == 1, classifier.UNIQUE,
                                     classifier.MULTI)).astype(np.int32)
        return hits, category
