"""Bracken-style abundance redistribution on top of any classifier.

Bracken reassigns reads classified at higher/ambiguous ranks down to
species using the unique-assignment distribution — with a flat species
taxonomy this is exactly Demeter's step-5 proportional split, so we reuse
the shared estimator; Kraken2+Bracken in the benchmarks is
``Kraken2Like`` + this redistribution.
"""

from __future__ import annotations

import numpy as np

from repro.core import abundance as abundance_mod


def estimate_abundance(hits: np.ndarray, category: np.ndarray,
                       genome_lengths: np.ndarray):
    """(R,S) hits + categories -> AbundanceResult (shared step-5 math)."""
    import jax.numpy as jnp
    return abundance_mod.estimate(
        jnp.asarray(hits), jnp.asarray(category), jnp.asarray(genome_lengths))
