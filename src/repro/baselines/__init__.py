"""Baseline profilers the paper compares against (software reproductions).

Kraken2Like (exact k-mer votes), MetaCacheLike (windowed minhash),
ClarkLike (discriminative k-mers), plus Bracken-style abundance
redistribution. All share the classify_reads() -> (hits, category)
contract so the accuracy/memory/speed benchmarks are apples-to-apples.
"""

from repro.baselines.kraken2_like import Kraken2Like
from repro.baselines.metacache_like import MetaCacheLike
from repro.baselines.clark_like import ClarkLike
from repro.baselines import bracken_like

__all__ = ["Kraken2Like", "MetaCacheLike", "ClarkLike", "bracken_like"]
