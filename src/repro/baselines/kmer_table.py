"""Shared substrate for the k-mer-table baselines (Kraken2/CLARK-like).

A sorted uint64 hash table mapping k-mer hashes to species bitmasks —
the "humongous hash table" working structure the paper identifies as the
bottleneck of SOTA profilers (§2.2).  Deliberately honest about size: the
memory benchmark (Fig. 6 analogue) reads ``memory_bytes()`` off these
arrays.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.genomics import kmers


@dataclasses.dataclass
class KmerTable:
    hashes: np.ndarray        # (T,) uint64 sorted
    masks: np.ndarray         # (T,) uint64 species bitmask
    num_species: int
    k: int

    def memory_bytes(self) -> int:
        return self.hashes.nbytes + self.masks.nbytes

    def lookup_masks(self, read_hashes: np.ndarray) -> np.ndarray:
        """Species bitmask for each hash (0 when absent)."""
        idx = np.searchsorted(self.hashes, read_hashes)
        idx = np.minimum(idx, len(self.hashes) - 1)
        found = self.hashes[idx] == read_hashes if len(self.hashes) else \
            np.zeros(len(read_hashes), bool)
        return np.where(found, self.masks[idx], np.uint64(0))


def build_table(genomes: dict[str, np.ndarray], k: int, *,
                subsample: int = 1) -> KmerTable:
    """Union of per-species k-mer hash sets with species bitmasks.

    ``subsample > 1`` keeps only hashes < 2^64/subsample (minimizer-style
    database shrinking, as Kraken2's minimizers do).
    """
    num_species = len(genomes)
    if num_species > 64:
        raise ValueError("bitmask substrate supports up to 64 species")
    limit = np.uint64(2**64 - 1) // np.uint64(subsample)

    per_species: list[np.ndarray] = []
    for s, toks in enumerate(genomes.values()):
        h = kmers.splitmix64(kmers.pack_kmers(toks, k))
        if subsample > 1:
            h = h[h <= limit]
        per_species.append(np.unique(h))

    all_h = np.concatenate(per_species) if per_species else np.empty(0, np.uint64)
    all_m = np.concatenate([
        np.full(len(h), np.uint64(1) << np.uint64(s), np.uint64)
        for s, h in enumerate(per_species)]) if per_species else \
        np.empty(0, np.uint64)
    order = np.argsort(all_h, kind="stable")
    all_h, all_m = all_h[order], all_m[order]
    # OR the masks of duplicate hashes.
    uniq, start = np.unique(all_h, return_index=True)
    masks = np.bitwise_or.reduceat(all_m, start) if len(all_m) else all_m
    return KmerTable(hashes=uniq, masks=masks, num_species=num_species, k=k)


def masks_to_votes(masks: np.ndarray, num_species: int) -> np.ndarray:
    """(H,) uint64 bitmasks -> (S,) int64 per-species vote counts."""
    bits = (masks[:, None] >> np.arange(num_species, dtype=np.uint64)[None, :]
            ) & np.uint64(1)
    return bits.sum(axis=0).astype(np.int64)
