"""Full language models: segment-planned stacks with scan + remat.

A model is a sequence of *segments* — homogeneous runs of one block kind —
so heterogeneous stacks (DeepSeek-V2's dense first layer, Hymba's three
global-attention layers) still compile as a handful of `lax.scan`s over
stacked params instead of L unrolled layers (small HLO, fast compiles,
friendly to the XLA latency-hiding scheduler).

Entry points:
  init_lm / forward           training + prefill (optionally returns caches)
  init_cache / prefill        decode-cache construction
  decode_step                 one-token decode across all segments
  encode_audio                whisper encoder over stub frame embeddings
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed import sharding
from repro.models import blocks, layers, ssm as ssm_mod

Params = dict


def segments(cfg: ModelConfig) -> tuple[tuple[str, int], ...]:
    """Plan the layer stack as (kind, count) runs."""
    if cfg.family == "ssm":
        return (("ssm", cfg.n_layers),)
    if cfg.family == "hybrid":
        n = cfg.n_layers  # global full-attention at layers {0, n//2, n-1}
        return (("hybrid_global", 1), ("hybrid_swa", n // 2 - 1),
                ("hybrid_global", 1), ("hybrid_swa", n - n // 2 - 2),
                ("hybrid_global", 1))
    if cfg.family == "audio":
        return (("dec", cfg.n_layers),)
    if cfg.moe is not None:
        if cfg.n_dense_layers:
            return (("dense", cfg.n_dense_layers),
                    ("moe", cfg.n_layers - cfg.n_dense_layers))
        return (("moe", cfg.n_layers),)
    return (("dense", cfg.n_layers),)


def _stack_init(key: jax.Array, cfg: ModelConfig, kind: str, count: int
                ) -> Params:
    return jax.vmap(lambda k: blocks.init_block(k, cfg, kind))(
        jax.random.split(key, count))


def init_lm(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4 + len(segments(cfg)))
    p: Params = {
        "embed": layers.init_embed(ks[0], cfg),
        "final_norm": layers.init_norm(cfg, cfg.d_model),
        "segments": tuple(
            _stack_init(ks[3 + i], cfg, kind, count)
            for i, (kind, count) in enumerate(segments(cfg))),
    }
    if cfg.is_encdec:
        p["enc_segments"] = (_stack_init(ks[1], cfg, "enc", cfg.n_enc_layers),)
        p["enc_norm"] = layers.init_norm(cfg, cfg.d_model)
    return p


def encode_audio(params: Params, frame_embeds: jax.Array, cfg: ModelConfig,
                 enc_valid: jax.Array | None = None,
                 q_chunk: int = 512, kv_chunk: int = 512,
                 remat: bool = True, unroll: bool = False) -> jax.Array:
    """Whisper encoder over stub conv-frontend frame embeddings (B, S, d)."""
    s = frame_embeds.shape[1]
    pos = jnp.arange(s)
    h = frame_embeds + layers.sinusoidal_embed(pos, cfg.d_model)[None]
    h = h.astype(jnp.dtype(cfg.param_dtype))

    def body(h, lp):
        h2, _, _ = blocks.block_forward(
            lp, h, cfg, "enc", positions=pos, kv_valid=enc_valid,
            q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll)
        return h2, None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, h, params["enc_segments"][0],
                        unroll=unroll)
    return layers.apply_norm(params["enc_norm"], h, cfg.norm).astype(h.dtype)


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig, *,
            pos0: int = 0,
            prefix_embeds: jax.Array | None = None,
            enc_embeds: jax.Array | None = None,
            enc_valid: jax.Array | None = None,
            kv_valid: jax.Array | None = None,
            return_caches: bool = False,
            return_hidden: bool = False,
            remat: bool = True, unroll: bool = False,
            q_chunk: int = 512, kv_chunk: int = 512):
    """Full-sequence forward.

    Returns (logits (B, S_total, vocab), aux_loss, caches_per_segment);
    with ``return_hidden`` the first element is the final hidden state
    instead (callers run their own chunked loss over it — see
    train.train_step.chunked_ce_loss).
    ``prefix_embeds``: VLM patch embeddings prepended (prefix-LM mask).
    ``enc_embeds``: whisper encoder frame embeddings (enc-dec only).
    """
    h = layers.embed_tokens(params["embed"], tokens, cfg)
    prefix_len = 0
    if prefix_embeds is not None:
        prefix_len = prefix_embeds.shape[1]
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    s_total = h.shape[1]
    positions = pos0 + jnp.arange(s_total)
    if cfg.pos == "sinusoidal":
        h = h + layers.sinusoidal_embed(positions, cfg.d_model)[None].astype(h.dtype)
    h = sharding.constrain_safe(h, ("batch", "seq", None))

    enc_out = None
    if cfg.is_encdec:
        assert enc_embeds is not None, "enc-dec model needs enc_embeds"
        enc_out = encode_audio(params, enc_embeds, cfg, enc_valid,
                               q_chunk, kv_chunk, remat=remat, unroll=unroll)

    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for seg_params, (kind, count) in zip(params["segments"], segments(cfg)):
        def body(h, lp, kind=kind):
            h2, aux, cache = blocks.block_forward(
                lp, h, cfg, kind, positions=positions, prefix_len=prefix_len,
                kv_valid=kv_valid, enc_out=enc_out, enc_valid=enc_valid,
                q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll,
                return_cache=return_caches)
            if kind == "dec" and return_caches:
                cache = dict(cache,
                             xk=jnp.einsum("bsd,dhk->bshk", enc_out,
                                           lp["xattn"]["wk"]),
                             xv=jnp.einsum("bsd,dhk->bshk", enc_out,
                                           lp["xattn"]["wv"]))
            return h2, (aux, cache)

        body_fn = jax.checkpoint(body) if (remat and not return_caches) else body
        h, (auxs, cache) = jax.lax.scan(body_fn, h, seg_params, unroll=unroll)
        aux_total = aux_total + auxs.sum()
        caches.append(cache)

    h = layers.apply_norm(params["final_norm"], h, cfg.norm).astype(h.dtype)
    if return_hidden:
        return h, aux_total, caches
    logits = layers.lm_logits(params["embed"], h, cfg)
    logits = sharding.constrain_safe(logits, ("batch", "seq", "vocab"))
    return logits, aux_total, caches


# -- decode caches ---------------------------------------------------------------

def _attn_cache_shape(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      dtype) -> dict:
    a = cfg.attn
    length = max_len
    if kind == "hybrid_swa" and a.window is not None:
        length = min(a.window, max_len)
    if a.kind == "mla":
        return {
            "c": jnp.zeros((batch, length, a.kv_lora), dtype),
            "kr": jnp.zeros((batch, length, a.rope_head_dim), dtype),
            "kpos": jnp.full((batch, length), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, length, a.num_kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((batch, length, a.num_kv_heads, a.vdim), dtype),
        "kpos": jnp.full((batch, length), -1, jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               enc_len: int = 0, dtype=jnp.bfloat16) -> list:
    """Zeroed decode caches, one stacked pytree per segment."""
    caches = []
    for kind, count in segments(cfg):
        def one(_key=None, kind=kind):
            if kind == "ssm":
                return ssm_mod.init_ssm_cache(batch, cfg, cfg.ssm, dtype)
            c = _attn_cache_shape(cfg, kind, batch, max_len, dtype)
            if kind in ("hybrid_global", "hybrid_swa"):
                return {"attn": c,
                        "ssm": ssm_mod.init_ssm_cache(batch, cfg, cfg.ssm,
                                                      dtype)}
            if kind == "dec":
                a = cfg.attn
                c = dict(c,
                         xk=jnp.zeros((batch, enc_len, a.num_kv_heads,
                                       a.head_dim), dtype),
                         xv=jnp.zeros((batch, enc_len, a.num_kv_heads,
                                       a.vdim), dtype),
                         xkpos=jnp.tile(jnp.arange(enc_len, dtype=jnp.int32)[None],
                                        (batch, 1)))
            return c
        unit = one()
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (count,) + x.shape), unit))
    return caches


def decode_step(params: Params, token: jax.Array, caches: list,
                cur_pos: jax.Array, cfg: ModelConfig, *,
                unroll: bool = False):
    """One-token decode. token: (B,) int32; cur_pos: scalar int32.

    Returns (logits (B, vocab) fp32, new_caches).
    """
    h = layers.embed_tokens(params["embed"], token[:, None], cfg)
    if cfg.pos == "sinusoidal":
        h = h + layers.sinusoidal_embed(
            cur_pos[None][None], cfg.d_model).astype(h.dtype)
    h = sharding.constrain_safe(h, ("batch", None, None))

    new_caches = []
    for seg_params, seg_cache, (kind, count) in zip(
            params["segments"], caches, segments(cfg)):
        # The cache rides in the scan CARRY with per-layer in-place
        # dynamic updates (not xs->ys), so XLA aliases one buffer instead
        # of double-buffering the full multi-GB cache (§Perf H2a iter 2).
        def body(carry, xs, kind=kind):
            h, cache_full = carry
            lp, i = xs
            lc = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, i, 0,
                                                       keepdims=False),
                cache_full)
            h2, nc = blocks.block_decode(lp, h, lc, cfg, kind, cur_pos)
            cache_full = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), i, 0), cache_full, nc)
            return (h2, cache_full), None

        (h, new_cache), _ = jax.lax.scan(
            body, (h, seg_cache), (seg_params, jnp.arange(count)),
            unroll=unroll)
        new_caches.append(new_cache)

    h = layers.apply_norm(params["final_norm"], h, cfg.norm).astype(h.dtype)
    logits = layers.lm_logits(params["embed"], h, cfg)[:, 0]
    return logits, new_caches


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig, max_len: int,
            *, prefix_embeds=None, enc_embeds=None, enc_valid=None,
            kv_valid=None, q_chunk: int = 512, kv_chunk: int = 512):
    """Run the prompt and build decode caches padded to ``max_len``.

    Returns (logits, caches, s_prompt).
    """
    logits, _, seg_caches = forward(
        params, tokens, cfg, prefix_embeds=prefix_embeds,
        enc_embeds=enc_embeds, enc_valid=enc_valid, kv_valid=kv_valid,
        return_caches=True, remat=False, q_chunk=q_chunk, kv_chunk=kv_chunk)
    b = tokens.shape[0]
    s = logits.shape[1]
    out_caches = []
    for (kind, count), cache in zip(segments(cfg), seg_caches):
        out_caches.append(_assemble_cache(cache, cfg, kind, b, s, max_len))
    return logits, out_caches, s


def _assemble_cache(cache, cfg: ModelConfig, kind: str, b: int, s: int,
                    max_len: int):
    """Pad/ring-place prefill caches into decode layout (adds kpos)."""
    if kind == "ssm":
        return cache
    a = cfg.attn
    pos = jnp.arange(s, dtype=jnp.int32)

    def place(x, length):
        # x: (count, B, s, ...) -> (count, B, length, ...) at slot pos%length
        pad = [(0, 0)] * x.ndim
        if s <= length:
            pad[2] = (0, length - s)
            return jnp.pad(x, pad)
        # ring placement of the last `length` positions
        tail = x[:, :, s - length:]
        slots = (pos[s - length:]) % length
        order = jnp.argsort(slots)
        return tail[:, :, order]

    def build(attn_cache, length):
        out = {}
        if a.kind == "mla":
            out["c"] = place(attn_cache["c"], length)
            out["kr"] = place(attn_cache["kr"], length)
        else:
            out["k"] = place(attn_cache["k"], length)
            out["v"] = place(attn_cache["v"], length)
        count = next(iter(out.values())).shape[0]
        if s <= length:
            kp = jnp.concatenate([pos, jnp.full((length - s,), -1, jnp.int32)])
        else:
            tailp = pos[s - length:]
            kp = tailp[jnp.argsort(tailp % length)]
        out["kpos"] = jnp.broadcast_to(kp[None, None], (count, b, length))
        return out

    if kind in ("hybrid_global", "hybrid_swa"):
        length = max_len if kind == "hybrid_global" else min(
            a.window or max_len, max_len)
        return {"attn": build(cache["attn"], length), "ssm": cache["ssm"]}
    if kind == "dec":
        out = build({k: cache[k] for k in ("k", "v")}, max_len)
        enc_len = cache["xk"].shape[2]
        count = cache["xk"].shape[0]
        out["xk"], out["xv"] = cache["xk"], cache["xv"]
        out["xkpos"] = jnp.broadcast_to(
            jnp.arange(enc_len, dtype=jnp.int32)[None, None],
            (count, b, enc_len))
        return out
    return build(cache, max_len)
