"""Transformer/SSM/hybrid blocks + per-block decode steps with caches.

Block kinds (config.segments() plans a model as homogeneous runs of):
  dense          attn + MLP
  moe            attn + MoE FFN
  ssm            Mamba-2 only (mamba2-1.3b has no MLP)
  hybrid_global  (attn ∥ mamba) heads, full attention, + MLP   (hymba)
  hybrid_swa     (attn ∥ mamba) heads, sliding window, + MLP   (hymba)
  enc            bidirectional attn + MLP                       (whisper enc)
  dec            causal self-attn + cross-attn + MLP            (whisper dec)

Decode caches are uniform dicts:
  attention: {k, v, kpos} — kpos holds the absolute position stored in each
  slot (-1 = empty), which makes full, sliding-window (ring-buffer) and
  prefix caches share one masking rule.
  MLA: {c, kr, kpos} (compressed latent — the MLA memory win).
  SSM: {conv, state}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed import sharding
from repro.models import attention, layers, moe as moe_mod, ssm as ssm_mod

Params = dict
NEG_INF = -1e30


# -- init ----------------------------------------------------------------------

def init_block(key: jax.Array, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {}
    if kind in ("dense", "moe", "enc", "dec", "hybrid_global", "hybrid_swa"):
        p["ln1"] = layers.init_norm(cfg, cfg.d_model)
        p["attn"] = attention.init_attention(ks[0], cfg, cfg.attn)
    if kind in ("hybrid_global", "hybrid_swa"):
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg, cfg.ssm)
        p["attn_norm"] = layers.init_norm(cfg, cfg.d_model)
        p["ssm_norm"] = layers.init_norm(cfg, cfg.d_model)
        p["branch_scale"] = jnp.ones((2,), jnp.float32)
    if kind == "ssm":
        p["ln1"] = layers.init_norm(cfg, cfg.d_model)
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg, cfg.ssm)
        return p
    if kind == "dec":
        p["ln_x"] = layers.init_norm(cfg, cfg.d_model)
        p["xattn"] = attention.init_attention(ks[2], cfg, cfg.attn)
    # FFN
    p["ln2"] = layers.init_norm(cfg, cfg.d_model)
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(ks[3], cfg, cfg.moe)
    else:
        d_ff = cfg.dense_d_ff if (kind == "dense" and cfg.dense_d_ff) else cfg.d_ff
        p["mlp"] = layers.init_mlp(ks[3], cfg, d_ff)
    return p


# -- full-sequence forward (train / prefill) ------------------------------------

def block_forward(p: Params, x: jax.Array, cfg: ModelConfig, kind: str, *,
                  positions: jax.Array, prefix_len: int = 0,
                  kv_valid: jax.Array | None = None,
                  enc_out: jax.Array | None = None,
                  enc_valid: jax.Array | None = None,
                  q_chunk: int = 512, kv_chunk: int = 512,
                  unroll: bool = False,
                  return_cache: bool = False):
    """Returns (x, aux_loss, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    a = cfg.attn
    window = a.window if (a and kind == "hybrid_swa") else None
    causal = kind != "enc"
    # Sequence-parallel residual stream (no-op outside a mesh / at decode).
    x = sharding.constrain_safe(x, ("batch", "residual_seq", None))

    if kind == "ssm":
        h = layers.apply_norm(p["ln1"], x, cfg.norm)
        if return_cache:
            y, cache = ssm_forward_with_state(p["ssm"], h, cfg)
        else:
            y = ssm_mod.ssm_forward(p["ssm"], h, cfg, cfg.ssm)
        out = sharding.constrain_safe(x + y, ("batch", "residual_seq", None))
        return out, aux, cache

    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    if a.kind == "mla":
        if return_cache:
            y, mla_cache = attention.mla_forward(
                p["attn"], h, a, positions=positions, norm_kind=cfg.norm,
                kv_valid=kv_valid, q_chunk=q_chunk, kv_chunk=kv_chunk,
                unroll=unroll, return_cache=True)
            cache = {"c": mla_cache[0], "kr": mla_cache[1]}
        else:
            y = attention.mla_forward(
                p["attn"], h, a, positions=positions, norm_kind=cfg.norm,
                kv_valid=kv_valid, q_chunk=q_chunk, kv_chunk=kv_chunk,
                unroll=unroll)
    else:
        out = attention.gqa_forward(
            p["attn"], h, a, positions=positions, causal=causal,
            window=window, prefix_len=prefix_len, kv_valid=kv_valid,
            q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll,
            return_kv=return_cache)
        if return_cache:
            y, (k, v) = out
            cache = {"k": k, "v": v}
        else:
            y = out

    if kind in ("hybrid_global", "hybrid_swa"):
        y_ssm = ssm_mod.ssm_forward(p["ssm"], h, cfg, cfg.ssm) \
            if not return_cache else None
        if return_cache:
            y_ssm, ssm_cache = ssm_forward_with_state(p["ssm"], h, cfg)
            cache = {"attn": cache, "ssm": ssm_cache}
        b = p["branch_scale"]
        y = 0.5 * (b[0] * layers.apply_norm(p["attn_norm"], y, cfg.norm)
                   + b[1] * layers.apply_norm(p["ssm_norm"], y_ssm, cfg.norm))
        y = y.astype(x.dtype)

    x = x + y

    if kind == "dec":
        h = layers.apply_norm(p["ln_x"], x, cfg.norm)
        y = attention.gqa_forward(
            p["xattn"], h, a, positions=positions, causal=False,
            kv_x=enc_out, kv_valid=enc_valid,
            q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll)
        x = x + y

    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    if kind == "moe":
        y, aux = moe_mod.moe_forward(p["moe"], h, cfg, cfg.moe)
    else:
        y = layers.apply_mlp(p["mlp"], h, cfg)
    # Pin the block output back to the sequence-sharded residual layout so
    # wo/w_out contractions lower to reduce-scatter, not full all-reduce
    # (§Perf H1 iteration 2: 35 x ~4GB all-reduces -> scattered).
    out = sharding.constrain_safe(x + y, ("batch", "residual_seq", None))
    return out, aux, cache


def ssm_forward_with_state(p: Params, h: jax.Array, cfg: ModelConfig):
    """SSD forward that also returns the decode cache (prefill path)."""
    s = cfg.ssm
    y = ssm_mod.ssm_forward(p, h, cfg, s)
    # Recompute the final state cheaply via the decode recurrence over the
    # last chunk is wasteful; instead run the chunked state recurrence.
    cache = _ssm_prefill_state(p, h, cfg)
    return y, cache


def _ssm_prefill_state(p: Params, h: jax.Array, cfg: ModelConfig) -> dict:
    """Final (conv, ssm) state after consuming h (B, L, d)."""
    s = cfg.ssm
    dd = ssm_mod.dims(cfg, s)
    bsz, l, _ = h.shape
    z, xbc_raw, dt_raw, d_in, nh, gn = ssm_mod._split(p, h, cfg, s)
    # conv cache: last d_conv-1 raw xbc inputs
    w = s.d_conv
    pad = max(w - 1 - l, 0)
    conv_cache = jnp.pad(xbc_raw, ((0, 0), (pad, 0), (0, 0)))[:, -(w - 1):, :]

    xbc = ssm_mod._causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_in].reshape(bsz, l, nh, s.head_dim)
    bmat = xbc[..., d_in:d_in + gn].reshape(bsz, l, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    adt = dt * a                                           # (B, L, H)
    hpg = nh // s.n_groups
    bh = jnp.repeat(bmat, hpg, axis=2)                     # (B, L, H, N)
    xdt = xs * dt[..., None]

    # state = sum_t exp(sum_{k>t} adt_k) * dt_t * B_t x_t^T
    acs = jnp.cumsum(adt, axis=1)
    decay = jnp.exp(acs[:, -1:, :] - acs)                  # (B, L, H)
    state = jnp.einsum("blhn,blh,blhp->bhpn",
                       bh.astype(jnp.float32), decay,
                       xdt.astype(jnp.float32))
    return {"conv": conv_cache, "state": state}


# -- decode step -----------------------------------------------------------------

def cached_attention(q: jax.Array, cache: dict, cur_pos: jax.Array,
                     window: int | None) -> jax.Array:
    """Single-token attention over a position-tagged cache.

    q: (B, H, dh); cache k/v: (B, S, KV, dh/dv); kpos: (B, S) int32.
    """
    k, v, kpos = cache["k"], cache["v"], cache["kpos"]
    b, s, kv, dh = k.shape
    g = q.shape[1] // kv
    qg = q.reshape(b, kv, g, q.shape[-1])
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32)
    logits *= q.shape[-1] ** -0.5
    valid = (kpos >= 0) & (kpos <= cur_pos)
    if window is not None:
        valid &= kpos > cur_pos - window
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskv->bkgv", w.astype(v.dtype), v)
    return out.reshape(b, q.shape[1], v.shape[-1])


def _store(cache: dict, names: tuple[str, ...], values: tuple[jax.Array, ...],
           cur_pos: jax.Array, ring: int | None) -> dict:
    """Insert one token's cache entries at slot (pos or pos % ring)."""
    s = cache[names[0]].shape[1]
    slot = cur_pos % ring if ring else cur_pos
    new = dict(cache)
    for name, val in zip(names, values):
        new[name] = jax.lax.dynamic_update_slice_in_dim(
            cache[name], val.astype(cache[name].dtype), slot, axis=1)
    new["kpos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["kpos"], jnp.full((cache["kpos"].shape[0], 1), cur_pos,
                                jnp.int32), slot, axis=1)
    return new


def block_decode(p: Params, x: jax.Array, cache: dict, cfg: ModelConfig,
                 kind: str, cur_pos: jax.Array) -> tuple[jax.Array, dict]:
    """One-token decode: x (B, 1, d) -> (x, new_cache)."""
    a = cfg.attn
    new_cache = dict(cache) if cache is not None else None

    if kind == "ssm":
        h = layers.apply_norm(p["ln1"], x, cfg.norm)
        y, new_cache = ssm_mod.ssm_decode_step(p["ssm"], h, cache, cfg, cfg.ssm)
        return x + y, new_cache

    window = a.window if kind == "hybrid_swa" else None
    ring = cache["attn"]["k"].shape[1] if kind in ("hybrid_global", "hybrid_swa") \
        and window is not None else None
    attn_cache = cache["attn"] if "attn" in cache else cache

    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    b = h.shape[0]

    if a.kind == "mla":
        y, attn_cache = _mla_decode(p["attn"], h, attn_cache, cfg, cur_pos)
    else:
        q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])[:, 0]
        k1 = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])[:, 0]
        v1 = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])[:, 0]
        rot = int(a.head_dim * a.rope_fraction)
        if rot:
            cos, sin = layers.rope_angles(cur_pos[None], rot, a.rope_theta)
            q = layers.apply_rope(q[:, None], cos[None], sin[None], rot)[:, 0]
            k1 = layers.apply_rope(k1[:, None], cos[None], sin[None], rot)[:, 0]
        attn_cache = _store(attn_cache, ("k", "v"),
                            (k1[:, None], v1[:, None]), cur_pos,
                            ring if window is not None else None)
        # q-side head padding (cache keeps original kv heads) — §Perf H1
        plan = attention.head_padding_plan(
            a.num_heads, a.num_kv_heads, sharding.axis_size("heads"),
            pad_kv=False)
        if plan is not None:
            qp, _, _ = attention.pad_heads(q[:, None], None, None, plan)
            out = cached_attention(qp[:, 0], attn_cache, cur_pos, window)
            out = attention.unpad_heads(out, plan)
        else:
            out = cached_attention(q, attn_cache, cur_pos, window)
        y = jnp.einsum("bhv,hvd->bd", out, p["attn"]["wo"])[:, None]

    if kind in ("hybrid_global", "hybrid_swa"):
        y_ssm, ssm_cache = ssm_mod.ssm_decode_step(
            p["ssm"], h, cache["ssm"], cfg, cfg.ssm)
        bsc = p["branch_scale"]
        y = 0.5 * (bsc[0] * layers.apply_norm(p["attn_norm"], y, cfg.norm)
                   + bsc[1] * layers.apply_norm(p["ssm_norm"], y_ssm, cfg.norm))
        y = y.astype(x.dtype)
        new_cache = {"attn": attn_cache, "ssm": ssm_cache}
    else:
        new_cache = attn_cache

    x = x + y

    if kind == "dec":                      # cross-attn over precomputed enc KV
        h = layers.apply_norm(p["ln_x"], x, cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", h, p["xattn"]["wq"])[:, 0]
        xc = {"k": cache["xk"], "v": cache["xv"], "kpos": cache["xkpos"]}
        out = cached_attention(q, xc, jnp.int32(2**30), None)
        y = jnp.einsum("bhv,hvd->bd", out, p["xattn"]["wo"])[:, None]
        x = x + y
        new_cache = dict(new_cache, xk=cache["xk"], xv=cache["xv"],
                         xkpos=cache["xkpos"])

    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    if kind == "moe":
        y, _ = moe_mod.moe_forward(p["moe"], h, cfg, cfg.moe)
    else:
        y = layers.apply_mlp(p["mlp"], h, cfg)
    return x + y, new_cache


def _mla_decode(p: Params, h: jax.Array, cache: dict, cfg: ModelConfig,
                cur_pos: jax.Array) -> tuple[jax.Array, dict]:
    """Absorbed-form MLA decode: attention in the compressed latent space.

    scores = (q_nope W_uk) . c  +  q_rope . k_rope ; ctx = w . c ; out = W_uv ctx.
    Never materializes per-head K/V — the whole point of caching latents.
    """
    a = cfg.attn
    b = h.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])[:, 0]      # (B,H,nope+rope)
    q_nope, q_rope = q[..., :a.head_dim], q[..., a.head_dim:]
    c1 = layers.apply_norm(p["c_norm"], h @ p["w_dkv"], cfg.norm)[:, 0]
    kr1 = (h @ p["w_kr"])[:, 0]                            # (B, rope)

    cos, sin = layers.rope_angles(cur_pos[None], a.rope_head_dim, a.rope_theta)
    q_rope = layers.apply_rope(q_rope[:, None], cos[None], sin[None],
                               a.rope_head_dim)[:, 0]
    kr1 = layers.apply_rope(kr1[:, None, None], cos[None], sin[None],
                            a.rope_head_dim)[:, 0, 0]

    cache = _store(cache, ("c", "kr"),
                   (c1[:, None].astype(cache["c"].dtype),
                    kr1[:, None].astype(cache["kr"].dtype)), cur_pos, None)

    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope, p["w_uk"])  # (B,H,lora)
    scale = (a.head_dim + a.rope_head_dim) ** -0.5
    scores = (jnp.einsum("bhl,bsl->bhs", q_abs, cache["c"])
              + jnp.einsum("bhr,bsr->bhs", q_rope, cache["kr"])
              ).astype(jnp.float32) * scale
    valid = (cache["kpos"] >= 0) & (cache["kpos"] <= cur_pos)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", w.astype(cache["c"].dtype), cache["c"])
    out = jnp.einsum("bhl,lhv->bhv", ctx, p["w_uv"])
    y = jnp.einsum("bhv,hvd->bd", out, p["wo"])[:, None]
    return y, cache
