"""Shared model layers: norms, MLPs, embeddings, RoPE (pure-pytree style).

Every module is an (init, apply) pair over plain nested-dict params — no
framework dependency.  Compute runs in bf16 with fp32 norm/softmax
internals; params are created in ``cfg.param_dtype``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Params = dict


def _norm_dtype(x: jax.Array) -> jax.Array:
    return x.astype(jnp.float32)


# -- Norms -------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = _norm_dtype(x)
    if kind == "rmsnorm":
        rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        out = xf * rms * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# -- Activations --------------------------------------------------------------

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                      # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# -- Dense MLP ----------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int) -> Params:
    d, dt = cfg.d_model, jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    std = d ** -0.5
    p = {
        "w_in": (jax.random.normal(k1, (d, d_ff)) * std).astype(dt),
        "w_out": (jax.random.normal(k2, (d_ff, d)) * d_ff ** -0.5).astype(dt),
    }
    if cfg.glu:
        p["w_gate"] = (jax.random.normal(k3, (d, d_ff)) * std).astype(dt)
    return p


def apply_mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    from repro.distributed import sharding
    act = act_fn(cfg.act)
    h = x @ p["w_in"]
    if cfg.glu:
        h = act(x @ p["w_gate"]) * h
    else:
        h = act(h)
    h = sharding.constrain_safe(h, ("batch", "seq", "ff"))
    return h @ p["w_out"]


# -- Embeddings ---------------------------------------------------------------

def init_embed(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"tok_embed": (jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02
                       ).astype(dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab))
                        * cfg.d_model ** -0.5).astype(dt)
    return p


def embed_tokens(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["tok_embed"][tokens].astype(jnp.dtype(cfg.param_dtype))


def lm_logits(p: Params, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return (h @ p["tok_embed"].T.astype(h.dtype)).astype(jnp.float32)
    return (h @ p["lm_head"]).astype(jnp.float32)


# -- RoPE ---------------------------------------------------------------------

def rope_angles(positions: jax.Array, rot_dim: int, theta: float
                ) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables: positions (...,) -> (..., rot_dim//2)."""
    freqs = theta ** (-jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               rot_dim: int) -> jax.Array:
    """Rotate the first ``rot_dim`` features of ``x`` (..., S, H, dh).

    cos/sin are (..., S, rot_dim//2) and broadcast over heads.
    """
    if rot_dim == 0:
        return x
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    c, s = cos[..., None, :], sin[..., None, :]       # add head axis
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    rotated = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([rotated, xp], axis=-1).astype(x.dtype)


def sinusoidal_embed(positions: jax.Array, d: int) -> jax.Array:
    """Absolute sinusoidal position embeddings (whisper-style stub)."""
    half = d // 2
    freqs = 10_000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
