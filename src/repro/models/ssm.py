"""Mamba-2 (SSD, state-space duality) block — chunked scan + decode step.

Follows arXiv:2405.21060's minimal SSD formulation: within chunks of
length Q the quadratic "attention-like" form runs on the MXU; across
chunks a linear recurrence carries the (H, P, N) state.  The decode path
is the O(1) recurrent update.  Includes the depthwise causal conv on
(x, B, C), the gated RMSNorm, and the z-gate, matching mamba2's block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SSMConfig
from repro.distributed import sharding
from repro.models import layers

Params = dict


def dims(cfg: ModelConfig, s: SSMConfig) -> dict:
    d_in = s.expand * cfg.d_model
    return dict(
        d_in=d_in,
        n_heads=d_in // s.head_dim,
        conv_dim=d_in + 2 * s.n_groups * s.d_state,
    )


def init_ssm(key: jax.Array, cfg: ModelConfig, s: SSMConfig) -> Params:
    d, dt_ = cfg.d_model, jnp.dtype(cfg.param_dtype)
    dd = dims(cfg, s)
    d_in, h, conv_dim = dd["d_in"], dd["n_heads"], dd["conv_dim"]
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    # in_proj emits [z (d_in), xBC (conv_dim), dt (H)]
    proj_out = d_in + conv_dim + h
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * std).astype(dt_),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim)) * 0.1
                   ).astype(dt_),
        "conv_b": jnp.zeros((conv_dim,), dt_),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, h))).astype(jnp.float32),
        "gate_norm": {"scale": jnp.ones((d_in,), jnp.float32)},
        "out_proj": (jax.random.normal(ks[2], (d_in, d)) * d_in ** -0.5
                     ).astype(dt_),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width d_conv: (B, L, C) -> (B, L, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum(a: jax.Array) -> jax.Array:
    """(..., Q) -> (..., Q, Q): S[i,j] = sum_{k in (j, i]} a_k, -inf above diag."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _split(p: Params, x: jax.Array, cfg: ModelConfig, s: SSMConfig):
    dd = dims(cfg, s)
    d_in, h = dd["d_in"], dd["n_heads"]
    gn = s.n_groups * s.d_state
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + dd["conv_dim"]]
    dt_raw = zxbcdt[..., d_in + dd["conv_dim"]:]
    return z, xbc, dt_raw, d_in, h, gn


def ssm_forward(p: Params, x: jax.Array, cfg: ModelConfig, s: SSMConfig,
                ) -> jax.Array:
    """Full-sequence SSD: (B, L, d) -> (B, L, d)."""
    bsz, l, _ = x.shape
    z, xbc, dt_raw, d_in, h, gn = _split(p, x, cfg, s)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_in].reshape(bsz, l, h, s.head_dim)
    bmat = xbc[..., d_in:d_in + gn].reshape(bsz, l, s.n_groups, s.d_state)
    cmat = xbc[..., d_in + gn:].reshape(bsz, l, s.n_groups, s.d_state)
    xs = sharding.constrain_safe(xs, ("batch", "seq", "ssm_heads", None))

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    a = -jnp.exp(p["a_log"])                                         # (H,)
    # heads per group for broadcasting B/C
    hpg = h // s.n_groups

    q = min(s.chunk, l)
    pad = (-l) % q
    def padl(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
    xs_, b_, c_, dt_ = map(padl, (xs, bmat, cmat, dt))
    lp = xs_.shape[1]
    nc = lp // q
    xs_ = xs_.reshape(bsz, nc, q, h, s.head_dim)
    b_ = b_.reshape(bsz, nc, q, s.n_groups, s.d_state)
    c_ = c_.reshape(bsz, nc, q, s.n_groups, s.d_state)
    dt_ = dt_.reshape(bsz, nc, q, h)

    adt = dt_ * a                                          # (B,nc,Q,H)
    acs = jnp.cumsum(adt, axis=2)                          # (B,nc,Q,H)
    xdt = xs_ * dt_[..., None]

    # Intra-chunk (quadratic) term.
    lmat = jnp.exp(_segsum(jnp.moveaxis(adt, -1, 2)))      # (B,nc,H,Q,Q)
    bh = jnp.repeat(b_, hpg, axis=3)                       # (B,nc,Q,H,N)
    ch = jnp.repeat(c_, hpg, axis=3)
    scores = jnp.einsum("bcqhn,bcshn->bchqs", ch, bh)      # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp",
                        scores * lmat, xdt)

    # Chunk states + inter-chunk recurrence.
    decay_states = jnp.exp(acs[:, :, -1:, :] - acs)        # (B,nc,Q,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn",
                        bh, decay_states, xdt)             # (B,nc,H,P,N)
    chunk_decay = jnp.exp(acs[:, :, -1, :])                # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                   # emit state BEFORE chunk

    init = jnp.zeros((bsz, h, s.head_dim, s.d_state), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states.astype(jnp.float32), 1, 0),
         jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (B,nc,H,P,N)

    state_decay = jnp.exp(acs)                             # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       ch, prev_states.astype(ch.dtype), state_decay)

    y = (y_diag + y_off).reshape(bsz, lp, h, s.head_dim)[:, :l]
    y = y + xs * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, d_in)

    y = layers.apply_norm(p["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    return y.astype(x.dtype) @ p["out_proj"]


def init_ssm_cache(batch: int, cfg: ModelConfig, s: SSMConfig,
                   dtype=jnp.float32) -> dict:
    dd = dims(cfg, s)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, dd["conv_dim"]), dtype),
        "state": jnp.zeros((batch, dd["n_heads"], s.head_dim, s.d_state),
                           jnp.float32),
    }


def ssm_decode_step(p: Params, x: jax.Array, cache: dict, cfg: ModelConfig,
                    s: SSMConfig) -> tuple[jax.Array, dict]:
    """One-token recurrent update: x (B, 1, d) -> (y (B, 1, d), new cache)."""
    bsz = x.shape[0]
    z, xbc, dt_raw, d_in, h, gn = _split(p, x, cfg, s)
    # conv over [cached w-1 inputs, current]
    win = jnp.concatenate([cache["conv"], xbc], axis=1)     # (B, w, C)
    conv_out = (win * p["conv_w"][None]).sum(axis=1, keepdims=True)
    xbc1 = jax.nn.silu(conv_out + p["conv_b"])              # (B,1,C)
    new_conv = win[:, 1:, :]

    xs = xbc1[..., :d_in].reshape(bsz, h, s.head_dim)
    bvec = xbc1[..., d_in:d_in + gn].reshape(bsz, s.n_groups, s.d_state)
    cvec = xbc1[..., d_in + gn:].reshape(bsz, s.n_groups, s.d_state)
    hpg = h // s.n_groups
    bh = jnp.repeat(bvec, hpg, axis=1)                      # (B,H,N)
    chh = jnp.repeat(cvec, hpg, axis=1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                                 # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, xs.astype(jnp.float32),
                     bh.astype(jnp.float32))
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", chh.astype(jnp.float32), state)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_in)
    y = layers.apply_norm(p["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, {"conv": new_conv, "state": state}
