"""Attention: GQA/MQA, MLA (DeepSeek-V2), sliding-window, prefix-LM.

The workhorse is :func:`blockwise_attention` — a chunked online-softmax
(flash-style) attention in pure JAX: the (Sq, Skv) logit matrix is never
materialized beyond a (q_chunk, kv_chunk) tile, which is what makes the
32k-prefill shapes fit per-chip HBM.  Cost-model note: the kernel computes
the *full* rectangle with masking (no causal early-exit), so HLO FLOPs
count full S^2 attention; EXPERIMENTS.md uses the same convention for
MODEL_FLOPS.

MLA follows arXiv:2405.04434: queries carry per-head no-PE + shared-RoPE
parts; K/V are up-projected from a compressed latent c (kv_lora wide) that
is also what the decode cache stores (serve/decode_attn.py uses the
absorbed form).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AttnConfig, ModelConfig
from repro.distributed import sharding
from repro.models import layers

Params = dict
NEG_INF = -1e30


# -- init ----------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ModelConfig, a: AttnConfig,
                   kv_d_model: int | None = None) -> Params:
    """GQA/MQA/MLA projection params. kv_d_model: cross-attn KV source width."""
    d = cfg.d_model
    dkv = kv_d_model or d
    dt = jnp.dtype(cfg.param_dtype)
    std = d ** -0.5
    ks = jax.random.split(key, 8)
    if a.kind == "mla":
        qk = a.head_dim + a.rope_head_dim
        p = {
            "wq": (jax.random.normal(ks[0], (d, a.num_heads, qk)) * std).astype(dt),
            "w_dkv": (jax.random.normal(ks[1], (d, a.kv_lora)) * std).astype(dt),
            "w_kr": (jax.random.normal(ks[2], (d, a.rope_head_dim)) * std).astype(dt),
            "w_uk": (jax.random.normal(ks[3], (a.kv_lora, a.num_heads, a.head_dim))
                     * a.kv_lora ** -0.5).astype(dt),
            "w_uv": (jax.random.normal(ks[4], (a.kv_lora, a.num_heads, a.vdim))
                     * a.kv_lora ** -0.5).astype(dt),
            "wo": (jax.random.normal(ks[5], (a.num_heads, a.vdim, d))
                   * (a.num_heads * a.vdim) ** -0.5).astype(dt),
            "c_norm": {"scale": jnp.ones((a.kv_lora,), jnp.float32)},
        }
        return p
    return {
        "wq": (jax.random.normal(ks[0], (d, a.num_heads, a.head_dim)) * std).astype(dt),
        "wk": (jax.random.normal(ks[1], (dkv, a.num_kv_heads, a.head_dim))
               * dkv ** -0.5).astype(dt),
        "wv": (jax.random.normal(ks[2], (dkv, a.num_kv_heads, a.vdim))
               * dkv ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[3], (a.num_heads, a.vdim, d))
               * (a.num_heads * a.vdim) ** -0.5).astype(dt),
    }


# -- head padding (TP divisibility) ----------------------------------------------

def head_padding_plan(h: int, kv: int, tp: int, *,
                      pad_kv: bool = True) -> tuple | None:
    """Plan q/kv head padding so the q-head dim divides the TP axis.

    Without this, a head count like 36 (starcoder2) or 25 (hymba) on a
    16-way model axis makes GSPMD *replicate* the whole attention — 16x
    wasted FLOPs and an all-reduce per einsum (§Perf H1).  Padding to the
    nearest (tp, kv)-compatible head count costs only hp/h extra compute.

    Returns (hp, kvp, slots) — q head i moves to slot[i] in the padded
    layout (grouped under its original kv head); None = no padding needed
    or padding would not beat replication.
    """
    if tp <= 1 or h % tp == 0:
        return None
    g0 = max(h // kv, 1)
    best = None
    kvp_range = range(kv, 4 * tp + 1) if pad_kv else (kv,)
    for kvp in kvp_range:
        l = math.lcm(kvp, tp)
        hp = -(-max(h, g0 * kvp) // l) * l
        while hp // kvp < g0:
            hp += l
        if best is None or (hp, kvp) < best:
            best = (hp, kvp)
    hp, kvp = best
    if hp / h >= tp:          # padding waste would exceed replication
        return None
    g = hp // kvp
    slots = np.asarray([(i // g0) * g + (i % g0) for i in range(h)])
    return hp, kvp, slots


def pad_heads(q: jax.Array, k: jax.Array | None, v: jax.Array | None,
              plan: tuple):
    """Scatter real heads into the padded layout (zeros elsewhere)."""
    hp, kvp, slots = plan
    qp = jnp.zeros(q.shape[:-2] + (hp, q.shape[-1]), q.dtype)
    qp = qp.at[..., slots, :].set(q)
    def padkv(t):
        if t is None or t.shape[-2] == kvp:
            return t
        pad = [(0, 0)] * t.ndim
        pad[-2] = (0, kvp - t.shape[-2])
        return jnp.pad(t, pad)
    return qp, padkv(k), padkv(v)


def unpad_heads(out: jax.Array, plan: tuple) -> jax.Array:
    return out[..., plan[2], :]


# -- chunked online-softmax attention ------------------------------------------

def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        q_pos0: int | jax.Array = 0,
                        kv_valid: jax.Array | None = None,
                        causal: bool = True,
                        window: int | None = None,
                        prefix_len: int = 0,
                        q_chunk: int = 512, kv_chunk: int = 512,
                        unroll: bool = False) -> jax.Array:
    """Memory-bounded attention.

    Args:
      q: ``(B, Sq, H, dh)``; k: ``(B, Skv, KV, dh)``; v: ``(B, Skv, KV, dv)``.
      q_pos0: absolute position of q[0] (continuation chunks / decode).
      kv_valid: ``(B,)`` valid KV length (padding mask).
      causal: causal masking (q_pos >= kv_pos).
      window: sliding-window width (only kv in [q_pos-window, q_pos]).
      prefix_len: kv positions < prefix_len are visible to every query
        (PaliGemma prefix-LM).

    Returns:
      ``(B, Sq, H, dv)``.
    """
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    dv = v.shape[-1]
    scale = dh ** -0.5

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    qp = _pad_axis(q, 1, qc)
    kp = _pad_axis(k, 1, kc)
    vp = _pad_axis(v, 1, kc)
    sq_p, skv_p = qp.shape[1], kp.shape[1]
    nq, nk = sq_p // qc, skv_p // kc

    qp = qp.reshape(b, nq, qc, kv, g, dh)
    kp = kp.reshape(b, nk, kc, kv, dh)
    vp = vp.reshape(b, nk, kc, kv, dv)
    kv_valid_ = (jnp.full((b,), skv, jnp.int32) if kv_valid is None
                 else kv_valid.astype(jnp.int32))

    def q_step(qi, q_blk):
        q_positions = q_pos0 + qi * qc + jnp.arange(qc)          # (qc,)

        def kv_step(carry, blk):
            m, l, acc = carry
            k_blk, v_blk, ki = blk
            kv_positions = ki * kc + jnp.arange(kc)              # (kc,)
            # bf16 dot, fp32 upcast AFTER: with preferred_element_type
            # =f32 here, GSPMD reshards the *converted fp32* operands and
            # cotangents — 2x collective width (§Perf H3 iteration 3).
            logits = jnp.einsum("bqkgd,bskd->bqkgs", q_blk,
                                k_blk).astype(jnp.float32) * scale
            mask = (kv_positions[None, :] < kv_valid_[:, None])  # (b, kc)
            mask = mask[:, None, :]                              # (b, 1, kc)
            rel = q_positions[:, None] - kv_positions[None, :]   # (qc, kc)
            vis = jnp.ones_like(rel, bool)
            if causal:
                vis &= rel >= 0
            if window is not None:
                vis &= rel < window
            if prefix_len:
                vis |= kv_positions[None, :] < prefix_len
            mask = mask & vis[None, :, :]                        # (b, qc, kc)
            logits = jnp.where(mask[:, :, None, None, :], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgs,bskv->bqkgv", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, qc, kv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, qc, kv, g), jnp.float32)
        a0 = jnp.zeros((b, qc, kv, g, dv), jnp.float32)
        if unroll:
            # straight-line tiles (dry-run cost-exact mode: while-loop
            # bodies are cost-counted once, so loops must disappear)
            carry = (m0, l0, a0)
            for ki in range(nk):
                carry, _ = kv_step(carry, (kp[:, ki], vp[:, ki],
                                           jnp.int32(ki)))
            m, l, acc = carry
        else:
            # Flash-style backward: recompute each (q, kv) tile's logits in
            # the backward pass instead of saving them (checkpointed body).
            (m, l, acc), _ = jax.lax.scan(
                jax.checkpoint(kv_step), (m0, l0, a0),
                (jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0),
                 jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)

    if unroll:
        outs = jnp.stack([q_step(jnp.int32(qi), qp[:, qi])
                          for qi in range(nq)])
    else:
        outs = jax.lax.map(lambda args: jax.checkpoint(q_step)(*args),
                           (jnp.arange(nq), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq_p, h, dv)
    return out[:, :sq]


# -- GQA forward ---------------------------------------------------------------

def gqa_forward(p: Params, x: jax.Array, a: AttnConfig, *,
                positions: jax.Array, causal: bool = True,
                window: int | None = None, prefix_len: int = 0,
                kv_x: jax.Array | None = None,
                kv_valid: jax.Array | None = None,
                q_chunk: int = 512, kv_chunk: int = 512,
                unroll: bool = False,
                return_kv: bool = False):
    """Standard multi/grouped-query attention over ``x`` (B, S, d).

    kv_x: cross-attention source (defaults to x). positions: (S,) absolute.
    """
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    q = sharding.constrain_safe(q, ("batch", "seq", "heads", None))
    k = sharding.constrain_safe(k, ("batch", "kv_seq", "kv_heads", None))
    v = sharding.constrain_safe(v, ("batch", "kv_seq", "kv_heads", None))

    rot = int(a.head_dim * a.rope_fraction)
    if rot and kv_x is None:
        cos, sin = layers.rope_angles(positions, rot, a.rope_theta)
        q = layers.apply_rope(q, cos[None], sin[None], rot)
        k = layers.apply_rope(k, cos[None], sin[None], rot)

    # TP-divisibility head padding (§Perf H1). The cache (return_kv) keeps
    # the ORIGINAL kv heads; padding is purely an attention-compute layout.
    plan = head_padding_plan(a.num_heads, a.num_kv_heads,
                             sharding.axis_size("heads"))
    k_orig, v_orig = k, v
    if plan is not None:
        q, k, v = pad_heads(q, k, v, plan)
        q = sharding.constrain_safe(q, ("batch", "seq", "heads", None))

    q_pos0 = positions[0] if positions.ndim else positions
    out = blockwise_attention(
        q, k, v, q_pos0=0 if kv_x is not None else q_pos0,
        kv_valid=kv_valid, causal=causal and kv_x is None,
        window=window, prefix_len=prefix_len,
        q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll)
    if plan is not None:
        out = unpad_heads(out, plan)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    if return_kv:
        return y, (k_orig, v_orig)
    return y


# -- MLA forward ---------------------------------------------------------------

def mla_forward(p: Params, x: jax.Array, a: AttnConfig, *,
                positions: jax.Array, norm_kind: str = "rmsnorm",
                kv_valid: jax.Array | None = None,
                q_chunk: int = 512, kv_chunk: int = 512,
                unroll: bool = False,
                return_cache: bool = False):
    """Multi-head latent attention (training/prefill form).

    Cache content is the compressed latent (c, k_rope) — the point of MLA.
    """
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])     # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :a.head_dim], q[..., a.head_dim:]

    c = layers.apply_norm(p["c_norm"], x @ p["w_dkv"], norm_kind)
    c = c.astype(x.dtype)                            # (B,S,kv_lora)
    k_rope = (x @ p["w_kr"])[:, :, None, :]          # (B,S,1,rope_dim)

    cos, sin = layers.rope_angles(positions, a.rope_head_dim, a.rope_theta)
    q_rope = layers.apply_rope(q_rope, cos[None], sin[None], a.rope_head_dim)
    k_rope = layers.apply_rope(k_rope, cos[None], sin[None], a.rope_head_dim)

    k_nope = jnp.einsum("bsc,chk->bshk", c, p["w_uk"])
    vv = jnp.einsum("bsc,chk->bshk", c, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, a.num_heads, a.rope_head_dim))],
        axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    qq = sharding.constrain_safe(qq, ("batch", "seq", "heads", None))

    out = blockwise_attention(qq, k, vv, q_pos0=positions[0],
                              kv_valid=kv_valid, causal=True,
                              q_chunk=q_chunk, kv_chunk=kv_chunk,
                              unroll=unroll)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    if return_cache:
        return y, (c, k_rope[:, :, 0, :])
    return y
