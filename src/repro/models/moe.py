"""Mixture-of-Experts: top-k routing with grouped, capacity-bounded dispatch.

GShard-style einsum dispatch: tokens are split into groups of
``group_size``; within each group every token picks top-k experts, gets a
position-in-expert by cumulative sum, and is dropped beyond the capacity
``C = ceil(group_size * k / E * capacity_factor)``.  Dispatch/combine are
one-hot einsums so that, under pjit with experts sharded over 'model' and
groups over ('pod','data'), XLA lowers token exchange to all-to-alls — the
production EP pattern.  Shared (always-on) experts are a fused dense MLP.

Router runs in fp32; top-k weights renormalize to sum to 1 (DeepSeek
convention) when ``router_scale``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.distributed import sharding
from repro.models import layers

Params = dict


def init_moe(key: jax.Array, cfg: ModelConfig, m: MoEConfig) -> Params:
    d, dt = cfg.d_model, jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, m.num_experts)) * std,  # fp32
        "e_in": (jax.random.normal(ks[1], (m.num_experts, d, m.d_expert))
                 * std).astype(dt),
        "e_out": (jax.random.normal(ks[2], (m.num_experts, m.d_expert, d))
                  * m.d_expert ** -0.5).astype(dt),
    }
    if cfg.glu:
        p["e_gate"] = (jax.random.normal(ks[3], (m.num_experts, d, m.d_expert))
                       * std).astype(dt)
    if m.num_shared:
        shared_cfg = cfg  # same act/glu
        p["shared"] = layers.init_mlp(ks[4], shared_cfg,
                                      m.num_shared * m.d_expert)
    return p


def capacity(m: MoEConfig) -> int:
    c = math.ceil(m.group_size * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_forward(p: Params, x: jax.Array, cfg: ModelConfig, m: MoEConfig
                ) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    gs = min(m.group_size, t)
    pad = (-t) % gs
    xt = x.reshape(t, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    g = xt.shape[0] // gs
    xg = xt.reshape(g, gs, d)
    xg = sharding.constrain_safe(xg, ("expert_group", None, None))

    # Router: bf16 operands, fp32 accumulation. Converting xg to fp32
    # before the matmul looks harmless but XLA fuses the convert BEFORE
    # the seq->group reshard, doubling the all-gather width (§Perf H3
    # iteration 3 — the dominant collective in the MoE train cells).
    logits = jnp.einsum("gtd,de->gte", xg,
                        p["router"].astype(xg.dtype),
                        preferred_element_type=jnp.float32)  # (G, gs, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)             # (G, gs, k)
    if m.router_scale:
        weights = weights / jnp.maximum(
            weights.sum(axis=-1, keepdims=True), 1e-9)

    e = m.num_experts
    c = capacity(m)
    oh = jax.nn.one_hot(idx, e, dtype=jnp.float32)           # (G, gs, k, E)
    # Position of each (token, k) slot within its expert queue (group-local).
    flat = oh.reshape(g, gs * m.top_k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, gs, m.top_k, e)
    keep = (pos < c) & (oh > 0)
    cap_oh = jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=jnp.float32)
    cap_oh = cap_oh * keep[..., None].astype(jnp.float32)    # (G,gs,k,E,C)

    # bf16 one-hot dispatch/combine, pinned expert-sharded at creation —
    # fp32 combine tensors resharded between fwd/bwd were the dominant
    # all-gather traffic in the MoE train cells (§Perf H3 iteration 2).
    combine = jnp.einsum("gtk,gtkec->gtec", weights, cap_oh)  # (G,gs,E,C)
    combine = sharding.constrain_safe(
        combine.astype(jnp.bfloat16), ("expert_group", None, "experts", None))
    dispatch = (combine > 0).astype(x.dtype)

    # Token exchange (all-to-all under EP sharding) + expert FFN.
    ein = jnp.einsum("gtec,gtd->gecd", dispatch, xg)          # (G,E,C,d)
    ein = sharding.constrain_safe(ein, ("expert_group", "experts", None, None))
    h = jnp.einsum("gecd,edf->gecf", ein, p["e_in"])
    if cfg.glu:
        h = layers.act_fn(cfg.act)(
            jnp.einsum("gecd,edf->gecf", ein, p["e_gate"])) * h
    else:
        h = layers.act_fn(cfg.act)(h)
    eout = jnp.einsum("gecf,efd->gecd", h, p["e_out"])        # (G,E,C,d)
    eout = sharding.constrain_safe(eout, ("expert_group", "experts", None, None))
    y = jnp.einsum("gecd,gtec->gtd", eout.astype(x.dtype),
                   combine.astype(x.dtype))

    y = y.reshape(-1, d)[:t].reshape(b, s, d)
    if m.num_shared:
        y = y + layers.apply_mlp(p["shared"], x, cfg)

    # Switch-style load-balancing aux loss.
    frac_tokens = oh.sum(axis=2).mean(axis=(0, 1))            # (E,)
    frac_probs = probs.mean(axis=(0, 1))                      # (E,)
    aux = (frac_tokens * frac_probs).sum() * e * m.aux_loss_coef
    return y, aux
