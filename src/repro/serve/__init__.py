"""Serving substrate: prefill/decode steps, sampling, request batching."""
