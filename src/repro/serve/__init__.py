"""Serving layer: single-DB service, multi-tenant control plane, legacy LM.

:class:`ProfilingService` (:mod:`repro.serve.profiler_service`) is the
data plane — many concurrent requests over one RefDB, bit-exact with
sequential runs — on top of the generic :class:`FixedShapeScheduler`
(:mod:`repro.serve.scheduler`).  Above it, :class:`RefDBRegistry`
(:mod:`repro.serve.registry`) owns named databases with versioned,
delta-updatable snapshots, and :class:`TenantRouter`
(:mod:`repro.serve.router`) maps tenants to databases with per-tenant
quotas and zero-downtime hot-swap.  :mod:`repro.serve.fleet` replicates
that whole stack across simulated hosts behind one
:class:`FleetController` — pull-based version replication, load-aware
tenant routing with mid-flight failover, and fleet-coordinated
two-phase hot-swaps.  The LM prefill/decode modules
(:mod:`repro.serve.serve_step`, :mod:`repro.serve.batching`) are the
seed repo's stack, kept working as legacy entry points.
"""

from repro.serve.scheduler import Cohort, FixedShapeScheduler, pow2_buckets
from repro.serve.profiler_service import (ProfileHandle, ProfileRequest,
                                          ProfilingService, RequestState,
                                          ServiceOverloaded)
from repro.serve.registry import RefDBRegistry, RefDBSnapshot
from repro.serve.router import (RoutedHandle, RouterClosed, TenantRouter,
                                TenantSpec)
from repro.serve.fleet import (FleetController, FleetHandle, HostDown,
                               HostReplica, HostState, NoHealthyHosts)

__all__ = [
    "Cohort", "FixedShapeScheduler", "pow2_buckets",
    "ProfileHandle", "ProfileRequest", "ProfilingService", "RequestState",
    "ServiceOverloaded",
    "RefDBRegistry", "RefDBSnapshot",
    "RoutedHandle", "RouterClosed", "TenantRouter", "TenantSpec",
    "FleetController", "FleetHandle", "HostDown", "HostReplica",
    "HostState", "NoHealthyHosts",
]
