"""Serving layer: the profiler-first service plus the legacy LM stack.

New serving work goes through :class:`ProfilingService`
(:mod:`repro.serve.profiler_service`) on top of the generic
:class:`FixedShapeScheduler` (:mod:`repro.serve.scheduler`).  The LM
prefill/decode modules (:mod:`repro.serve.serve_step`,
:mod:`repro.serve.batching`) are the seed repo's stack, kept working as
legacy entry points.
"""

from repro.serve.scheduler import Cohort, FixedShapeScheduler, pow2_buckets
from repro.serve.profiler_service import (ProfileHandle, ProfileRequest,
                                          ProfilingService, RequestState,
                                          ServiceOverloaded)

__all__ = [
    "Cohort", "FixedShapeScheduler", "pow2_buckets",
    "ProfileHandle", "ProfileRequest", "ProfilingService", "RequestState",
    "ServiceOverloaded",
]
