"""`RefDBRegistry`: named reference databases with versioned live updates.

Production food monitoring is not one static database: a service hosts
*many* reference sets (food, clinical, environmental), and each one's
genomes change under live traffic — new contaminant species get added,
withdrawn references get removed.  The registry is the control plane for
that: it owns a set of **named databases**, each a chain of **versioned
immutable snapshots**, and publishes updates atomically so the serving
layer (:class:`repro.serve.router.TenantRouter`) can hot-swap without
downtime.

    registry = RefDBRegistry(root="dbs/")            # root=None: in-memory
    registry.create("food", genomes, config)         # -> version 1
    snap = registry.apply_delta("food", add={"listeria": toks})   # -> v2
    registry.apply_delta("food", remove=["species_00"])           # -> v3
    registry.current("food").db                      # newest RefDB

Deltas are **incremental**: an add encodes only the new genomes (one
streaming :class:`~repro.core.assoc_memory.RefDBBuilder` pass, same
space/window/stride as the original build, so the new prototype rows are
bit-identical to what a from-scratch build would produce) and a remove
drops rows without re-encoding, via
:func:`repro.core.assoc_memory.apply_delta`.  Every snapshot records its
``version``, ``parent_version`` and the delta that produced it in the
:mod:`repro.pipeline.refdb_store` manifest — the provenance chain back to
the full build.

Publishing is atomic at both layers.  On disk each snapshot is its own
``v<N>.npz`` store entry (atomic temp + ``os.replace``) and the
``CURRENT.json`` pointer flips to it with another ``os.replace``, so a
concurrent loader always observes a complete old-or-new version, never a
torn one.  In memory the current-version pointer swaps under the registry
lock, then subscribers (the router's auto-swap hook) are notified outside
it.

Snapshots hand out *host-resident* databases; placement (sharding across
a device mesh, programming simulated PCM conductances) happens when a
serving session adopts one (:meth:`ProfilingSession.adopt_refdb`).
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
import tempfile
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro import obs
from repro.core import assoc_memory
from repro.core.assoc_memory import RefDB, RefDBBuilder
from repro.pipeline import refdb_store
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.session import _genomes_digest

_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")

#: CURRENT.json pointer schema version.
_POINTER_VERSION = 1


@dataclasses.dataclass(frozen=True)
class RefDBSnapshot:
    """One immutable published version of a named database."""

    database: str
    version: int                        # 1-based, monotone per database
    db: RefDB                           # host-resident (unplaced)
    parent_version: int | None = None   # None for the initial full build
    delta: dict | None = None           # {"added": [...], "removed": [...]}
    path: pathlib.Path | None = None    # on-disk entry (None in-memory)
    created_at: float = 0.0             # epoch seconds of the publish

    @property
    def species(self) -> tuple[str, ...]:
        return self.db.species_names


@dataclasses.dataclass(frozen=True)
class GCResult:
    """What one :meth:`RefDBRegistry.gc` sweep retired (or would retire).

    With ``dry_run=True`` the sweep is a pure report: ``collected`` are
    the victims an identical real sweep would take right now and
    ``reclaimed_bytes`` what their on-disk files measure — nothing was
    deleted.
    """

    collected: tuple[tuple[str, int], ...]   # (database, version) pairs
    reclaimed_bytes: int                     # on-disk bytes unlinked
    dry_run: bool = False


class _Entry:
    """Registry-internal mutable state of one named database."""

    def __init__(self, name: str, config: ProfilerConfig, encode_fn=None):
        self.name = name
        self.config = config
        self.encode_fn = encode_fn
        self.snapshots: dict[int, RefDBSnapshot] = {}
        self.current_version = 0
        # version -> live-service refcount (routers pin versions they
        # serve; gc never collects a pinned version).
        self.pins: dict[int, int] = {}
        # Serializes builds/deltas per database so version numbers are a
        # gapless chain even under concurrent writers; the registry-wide
        # lock is only held for pointer reads/swaps.
        self.mutate = threading.Lock()


class RefDBRegistry:
    """Named, versioned RefDBs with atomic publish and live deltas."""

    def __init__(self, root: str | pathlib.Path | None = None, *,
                 metrics: obs.MetricsRegistry | None = None):
        """Args:
          root: snapshot directory (one subdirectory per database).  None
            keeps everything in memory — versioning, deltas, and hot-swap
            all work; nothing survives the process.
          metrics: explicit metrics registry (default: the process
            global, a no-op unless ``obs.enable_metrics()`` ran).
        """
        self.root = pathlib.Path(root) if root is not None else None
        self._lock = threading.RLock()
        self._entries: dict[str, _Entry] = {}
        self._subscribers: list[Callable[[RefDBSnapshot], None]] = []
        self._obs = obs.resolve_metrics(metrics)
        self._m_publishes = self._obs.counter(
            "refdb_publishes_total",
            "Snapshot versions published, by database.")
        self._m_installs = self._obs.counter(
            "refdb_installs_total",
            "Snapshot versions installed from another registry "
            "(replication), by database.")
        self._m_build_time = self._obs.histogram(
            "refdb_build_seconds",
            "Wall time of a full build or delta, publish included.",
            unit="s")
        self._m_live_version = self._obs.gauge(
            "refdb_current_version",
            "Newest published version number, by database.")
        self._m_gc_versions = self._obs.counter(
            "refdb_gc_versions_total",
            "Snapshot versions retired by the garbage collector.")
        self._m_gc_bytes = self._obs.counter(
            "refdb_gc_reclaimed_bytes_total",
            "On-disk snapshot bytes reclaimed by the garbage collector.")

    # -- creation -----------------------------------------------------------
    def create(self, name: str, genomes: dict[str, np.ndarray],
               config: ProfilerConfig, *, encode_fn=None,
               on_genome: Callable[[str, int], None] | None = None
               ) -> RefDBSnapshot:
        """Build and publish version 1 of a new named database.

        The build streams genome-by-genome through
        :class:`RefDBBuilder`; ``config`` pins the content-determining
        fields (space/window/stride) every later delta must match.

        Args:
          encode_fn: optional encoder override (kept for this database's
            future deltas too).  The default reference encoder is
            bit-exact with every backend, so serving through any backend
            needs no override.
          on_genome: streaming-build progress hook ``(name, total_rows)``.
        """
        if not _NAME_RE.match(name):
            raise ValueError(
                f"invalid database name {name!r} (need alphanumeric plus "
                f"'._-', not starting with a separator)")
        with self._lock:
            if name in self._entries:
                raise ValueError(f"database {name!r} already exists "
                                 f"(apply_delta to update it)")
            entry = _Entry(name, config, encode_fn)
            self._entries[name] = entry
        try:
            with entry.mutate:
                t0 = time.perf_counter()
                builder = self._builder(entry)
                db = refdb_store.build_streaming(genomes, builder,
                                                 on_genome=on_genome)
                snap = self._publish(
                    entry, db, parent=None, delta=None,
                    genomes_digest=_genomes_digest(genomes))
                if self._obs.enabled:
                    self._m_build_time.observe(time.perf_counter() - t0,
                                               database=name, kind="create")
        except BaseException:
            with self._lock:
                self._entries.pop(name, None)   # failed create leaves no stub
            raise
        self._notify(snap)
        return snap

    # -- live updates -------------------------------------------------------
    def apply_delta(self, name: str, *,
                    add: dict[str, np.ndarray] | None = None,
                    remove: Sequence[str] = ()) -> RefDBSnapshot:
        """Publish version N+1 = current version with species added/removed.

        Incremental: only ``add``'s genomes are encoded (streamed through
        a fresh builder under the database's pinned config), ``remove``
        drops prototype rows without touching the rest.  Removal applies
        first, so replacing a genome is one delta (``remove=[x],
        add={x: new_tokens}``).  The new snapshot is written and the
        current pointer flipped atomically; subscribers are notified
        after the in-memory swap.
        """
        if not add and not remove:
            raise ValueError("empty delta: pass add= genomes and/or "
                             "remove= species names")
        entry = self._entry(name)
        with entry.mutate:
            t0 = time.perf_counter()
            base = self.current(name)
            addition = None
            if add:
                builder = self._builder(entry)
                for gname, toks in add.items():
                    builder.add_genome(gname, toks)
                addition = builder.finish()
            db = assoc_memory.apply_delta(base.db, add=addition,
                                          remove=tuple(remove))
            delta = {"added": sorted(add) if add else [],
                     "removed": sorted(remove)}
            snap = self._publish(entry, db, parent=base.version, delta=delta)
            if self._obs.enabled:
                self._m_build_time.observe(time.perf_counter() - t0,
                                           database=name, kind="delta")
        self._notify(snap)
        return snap

    # -- replication --------------------------------------------------------
    def install(self, name: str, snapshot: RefDBSnapshot, *,
                config: ProfilerConfig) -> RefDBSnapshot:
        """Install an already-built snapshot from another registry.

        The replication seam: a fleet host's mirror registry pulls
        published versions from the source-of-truth registry without
        re-encoding anything — the immutable ``RefDB`` object is shared.
        Installs keep the *source's* version number (so fleet-wide
        version talk is unambiguous) and tolerate gaps: a host that was
        down across publishes installs whatever the source currently
        retains and the chain simply skips the versions it missed.
        Idempotent per version; never moves the current pointer
        backwards; in-memory only (``path=None`` — durability lives at
        the source).  ``config`` must agree with the entry's pinned
        content fields (same ``refdb_fingerprint``), or the mirror would
        serve prototypes that mean something else than their name says.
        """
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid database name {name!r}")
        with self._lock:
            entry = self._entries.get(name)
            if entry is None:
                entry = _Entry(name, config)
                self._entries[name] = entry
        if entry.config.refdb_fingerprint() != config.refdb_fingerprint():
            raise ValueError(
                f"database {name!r}: install config disagrees with the "
                f"pinned content fields (fingerprint mismatch)")
        with entry.mutate:
            with self._lock:
                existing = entry.snapshots.get(snapshot.version)
                if existing is not None:
                    return existing
                local = RefDBSnapshot(
                    database=name, version=snapshot.version, db=snapshot.db,
                    parent_version=snapshot.parent_version,
                    delta=snapshot.delta, path=None,
                    created_at=time.time())
                entry.snapshots[local.version] = local
                if local.version > entry.current_version:
                    entry.current_version = local.version
        if self._obs.enabled:
            self._m_installs.inc(1, database=name)
            self._m_live_version.set(entry.current_version, database=name)
        return local

    # -- reads --------------------------------------------------------------
    def databases(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._entries))

    def config(self, name: str) -> ProfilerConfig:
        """The build config pinned at ``create`` (content fields bind all
        later deltas; execution fields are just its defaults — the router
        overrides backend/batch per serving deployment)."""
        return self._entry(name).config

    def current(self, name: str) -> RefDBSnapshot:
        """The newest published snapshot of ``name``."""
        entry = self._entry(name)
        with self._lock:
            if entry.current_version == 0:
                raise KeyError(f"database {name!r} has no published version")
            return entry.snapshots[entry.current_version]

    def snapshot(self, name: str, version: int) -> RefDBSnapshot:
        """A specific retained version (every publish is retained)."""
        entry = self._entry(name)
        with self._lock:
            try:
                return entry.snapshots[version]
            except KeyError:
                raise KeyError(
                    f"database {name!r} has no version {version} "
                    f"(have {sorted(entry.snapshots)})") from None

    def versions(self, name: str) -> tuple[int, ...]:
        entry = self._entry(name)
        with self._lock:
            return tuple(sorted(entry.snapshots))

    # -- liveness pins + garbage collection ---------------------------------
    def pin(self, name: str, version: int) -> None:
        """Refcount ``version`` as held by a live service.

        The router pins every version it serves (current and draining);
        :meth:`gc` refuses to collect a pinned version no matter how old
        or deep in the chain it is.
        """
        entry = self._entry(name)
        with self._lock:
            if version not in entry.snapshots:
                raise KeyError(f"database {name!r} has no version "
                               f"{version} to pin")
            entry.pins[version] = entry.pins.get(version, 0) + 1

    def release(self, name: str, version: int) -> None:
        """Drop one pin of ``version`` (idempotent past zero)."""
        entry = self._entry(name)
        with self._lock:
            n = entry.pins.get(version, 0) - 1
            if n > 0:
                entry.pins[version] = n
            else:
                entry.pins.pop(version, None)

    def pins(self, name: str) -> dict[int, int]:
        """Live pin counts by version (a copy, for inspection/tests)."""
        entry = self._entry(name)
        with self._lock:
            return dict(entry.pins)

    def gc(self, name: str | None = None, *, keep_last: int = 2,
           max_age_s: float | None = None, dry_run: bool = False
           ) -> "GCResult":
        """Retire old snapshot versions no live service references.

        A version is collected only when it is **all** of: not the
        current version, not pinned by any service, not among the
        ``keep_last`` newest retained versions, and — when ``max_age_s``
        is given — older than that.  Collection drops the in-memory
        snapshot and unlinks its on-disk ``v*.npz`` file (on-disk-only
        versions from before :meth:`open` are swept by the same rules,
        aged by file mtime).

        Args:
          name: one database, or None for every database.
          keep_last: hard floor of newest versions always retained.
          max_age_s: additionally require a collected version to be at
            least this old (seconds since publish).
          dry_run: report the victims and reclaimable bytes an identical
            real sweep would take, deleting nothing — the safe preview
            operators (and the fleet retire phase) run first.

        Returns:
          :class:`GCResult` with the collected ``(database, version)``
          pairs and total bytes reclaimed on disk.
        """
        if keep_last < 1:
            raise ValueError("keep_last must be >= 1 (the current "
                             "version is always retained)")
        names = [name] if name is not None else list(self.databases())
        collected: list[tuple[str, int]] = []
        reclaimed = 0
        now = time.time()
        for dbname in names:
            entry = self._entry(dbname)
            with entry.mutate:      # serialize against concurrent publish
                got, nbytes = self._gc_one(entry, keep_last, max_age_s, now,
                                           dry_run)
            collected.extend((dbname, v) for v in got)
            reclaimed += nbytes
        if self._obs.enabled and collected and not dry_run:
            self._m_gc_versions.inc(len(collected))
            self._m_gc_bytes.inc(reclaimed)
        return GCResult(collected=tuple(collected),
                        reclaimed_bytes=reclaimed, dry_run=dry_run)

    def _gc_one(self, entry: _Entry, keep_last: int,
                max_age_s: float | None, now: float, dry_run: bool
                ) -> tuple[list[int], int]:
        """Collect one database's eligible versions; runs under
        ``entry.mutate``."""
        disk: dict[int, pathlib.Path] = {}
        if self.root is not None:
            for p in (self.root / entry.name).glob("v*.npz"):
                try:
                    disk[int(p.stem[1:])] = p
                except ValueError:
                    continue
        with self._lock:
            known = sorted(set(entry.snapshots) | set(disk))
            keep = set(known[-keep_last:])
            keep.add(entry.current_version)
            keep.update(v for v, n in entry.pins.items() if n > 0)
            victims = []
            for v in known:
                if v in keep:
                    continue
                if max_age_s is not None:
                    snap = entry.snapshots.get(v)
                    born = snap.created_at if snap is not None \
                        else disk[v].stat().st_mtime
                    if now - born < max_age_s:
                        continue
                victims.append(v)
            if not dry_run:
                for v in victims:
                    entry.snapshots.pop(v, None)
        nbytes = 0
        for v in victims:
            p = disk.get(v)
            if p is None:
                continue
            try:
                nbytes += p.stat().st_size
                if not dry_run:
                    p.unlink()
            except OSError:
                pass                # already gone: nothing reclaimed
        return victims, nbytes

    # -- change notification (the router's auto-swap hook) ------------------
    def subscribe(self, fn: Callable[[RefDBSnapshot], None]
                  ) -> Callable[[RefDBSnapshot], None]:
        """Call ``fn(snapshot)`` after every publish; returns ``fn``.

        Called outside registry locks, after the new version is already
        current — a subscriber that re-reads ``current`` sees it.
        """
        with self._lock:
            self._subscribers.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[RefDBSnapshot], None]) -> None:
        with self._lock:
            if fn in self._subscribers:
                self._subscribers.remove(fn)

    # -- persistence --------------------------------------------------------
    @classmethod
    def open(cls, root: str | pathlib.Path) -> "RefDBRegistry":
        """Reopen a persisted registry: every database's CURRENT version.

        Only the current snapshot of each database is loaded into memory
        (older versions stay on disk for audit via their manifests); the
        version counter continues from the published chain.
        """
        root = pathlib.Path(root)
        reg = cls(root)
        for pointer in sorted(root.glob("*/CURRENT.json")):
            try:
                meta = json.loads(pointer.read_text())
            except (OSError, json.JSONDecodeError):
                continue                      # torn dir: skip, don't poison
            if meta.get("pointer_version") != _POINTER_VERSION:
                continue
            name = meta["database"]
            path = pointer.parent / meta["file"]
            db = refdb_store.load(path)
            if db is None:
                continue                      # defect reads as absent
            m = refdb_store.manifest(path) or {}
            entry = _Entry(name, ProfilerConfig.from_dict(meta["config"]))
            snap = RefDBSnapshot(
                database=name, version=int(meta["version"]), db=db,
                parent_version=m.get("parent_version"),
                delta=m.get("delta"), path=path,
                created_at=path.stat().st_mtime)
            entry.snapshots[snap.version] = snap
            entry.current_version = snap.version
            reg._entries[name] = entry
        return reg

    # -- internals ----------------------------------------------------------
    def _entry(self, name: str) -> _Entry:
        with self._lock:
            try:
                return self._entries[name]
            except KeyError:
                raise KeyError(
                    f"unknown database {name!r}; registry has "
                    f"{list(sorted(self._entries))}") from None

    def _builder(self, entry: _Entry) -> RefDBBuilder:
        c = entry.config
        return RefDBBuilder(c.space, window=c.window,
                            stride=c.effective_stride,
                            batch_size=c.batch_size,
                            encode_fn=entry.encode_fn)

    def _publish(self, entry: _Entry, db: RefDB, *, parent: int | None,
                 delta: dict | None, genomes_digest: str = ""
                 ) -> RefDBSnapshot:
        """Write (optional) + swap the current pointer; runs under
        ``entry.mutate`` so versions are a gapless chain."""
        version = entry.current_version + 1
        path = None
        if self.root is not None:
            d = self.root / entry.name
            path = d / f"v{version:04d}.npz"
            c = entry.config
            refdb_store.save(
                path, db,
                refdb_fingerprint=c.refdb_fingerprint(),
                genomes_digest=genomes_digest,
                config_fields={"space": dataclasses.asdict(c.space),
                               "window": c.window,
                               "stride": c.effective_stride,
                               "database": entry.name},
                version=version, parent_version=parent, delta=delta)
            self._flip_pointer(d, entry, version, path.name)
        snap = RefDBSnapshot(database=entry.name, version=version, db=db,
                             parent_version=parent, delta=delta, path=path,
                             created_at=time.time())
        with self._lock:
            entry.snapshots[version] = snap
            entry.current_version = version
        if self._obs.enabled:
            self._m_publishes.inc(1, database=entry.name)
            self._m_live_version.set(version, database=entry.name)
        return snap

    def _flip_pointer(self, d: pathlib.Path, entry: _Entry, version: int,
                      filename: str) -> None:
        """Atomically repoint CURRENT.json at the new snapshot file."""
        meta = {
            "pointer_version": _POINTER_VERSION,
            "database": entry.name,
            "version": version,
            "file": filename,
            "config": entry.config.to_dict(),
        }
        fd, tmp = tempfile.mkstemp(dir=d, prefix="CURRENT.json.tmp-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(meta, f, sort_keys=True, indent=2)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, d / "CURRENT.json")
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _notify(self, snap: RefDBSnapshot) -> None:
        with self._lock:
            subs = list(self._subscribers)
        for fn in subs:
            fn(snap)
