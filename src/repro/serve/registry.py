"""`RefDBRegistry`: named reference databases with versioned live updates.

Production food monitoring is not one static database: a service hosts
*many* reference sets (food, clinical, environmental), and each one's
genomes change under live traffic — new contaminant species get added,
withdrawn references get removed.  The registry is the control plane for
that: it owns a set of **named databases**, each a chain of **versioned
immutable snapshots**, and publishes updates atomically so the serving
layer (:class:`repro.serve.router.TenantRouter`) can hot-swap without
downtime.

    registry = RefDBRegistry(root="dbs/")            # root=None: in-memory
    registry.create("food", genomes, config)         # -> version 1
    snap = registry.apply_delta("food", add={"listeria": toks})   # -> v2
    registry.apply_delta("food", remove=["species_00"])           # -> v3
    registry.current("food").db                      # newest RefDB

Deltas are **incremental**: an add encodes only the new genomes (one
streaming :class:`~repro.core.assoc_memory.RefDBBuilder` pass, same
space/window/stride as the original build, so the new prototype rows are
bit-identical to what a from-scratch build would produce) and a remove
drops rows without re-encoding, via
:func:`repro.core.assoc_memory.apply_delta`.  Every snapshot records its
``version``, ``parent_version`` and the delta that produced it in the
:mod:`repro.pipeline.refdb_store` manifest — the provenance chain back to
the full build.

Publishing is atomic at both layers.  On disk each snapshot is its own
``v<N>.npz`` store entry (atomic temp + ``os.replace``) and the
``CURRENT.json`` pointer flips to it with another ``os.replace``, so a
concurrent loader always observes a complete old-or-new version, never a
torn one.  In memory the current-version pointer swaps under the registry
lock, then subscribers (the router's auto-swap hook) are notified outside
it.

Snapshots hand out *host-resident* databases; placement (sharding across
a device mesh, programming simulated PCM conductances) happens when a
serving session adopts one (:meth:`ProfilingSession.adopt_refdb`).
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
import tempfile
import threading
from typing import Callable, Sequence

import numpy as np

from repro.core import assoc_memory
from repro.core.assoc_memory import RefDB, RefDBBuilder
from repro.pipeline import refdb_store
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.session import _genomes_digest

_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")

#: CURRENT.json pointer schema version.
_POINTER_VERSION = 1


@dataclasses.dataclass(frozen=True)
class RefDBSnapshot:
    """One immutable published version of a named database."""

    database: str
    version: int                        # 1-based, monotone per database
    db: RefDB                           # host-resident (unplaced)
    parent_version: int | None = None   # None for the initial full build
    delta: dict | None = None           # {"added": [...], "removed": [...]}
    path: pathlib.Path | None = None    # on-disk entry (None in-memory)

    @property
    def species(self) -> tuple[str, ...]:
        return self.db.species_names


class _Entry:
    """Registry-internal mutable state of one named database."""

    def __init__(self, name: str, config: ProfilerConfig, encode_fn=None):
        self.name = name
        self.config = config
        self.encode_fn = encode_fn
        self.snapshots: dict[int, RefDBSnapshot] = {}
        self.current_version = 0
        # Serializes builds/deltas per database so version numbers are a
        # gapless chain even under concurrent writers; the registry-wide
        # lock is only held for pointer reads/swaps.
        self.mutate = threading.Lock()


class RefDBRegistry:
    """Named, versioned RefDBs with atomic publish and live deltas."""

    def __init__(self, root: str | pathlib.Path | None = None):
        """Args:
          root: snapshot directory (one subdirectory per database).  None
            keeps everything in memory — versioning, deltas, and hot-swap
            all work; nothing survives the process.
        """
        self.root = pathlib.Path(root) if root is not None else None
        self._lock = threading.RLock()
        self._entries: dict[str, _Entry] = {}
        self._subscribers: list[Callable[[RefDBSnapshot], None]] = []

    # -- creation -----------------------------------------------------------
    def create(self, name: str, genomes: dict[str, np.ndarray],
               config: ProfilerConfig, *, encode_fn=None,
               on_genome: Callable[[str, int], None] | None = None
               ) -> RefDBSnapshot:
        """Build and publish version 1 of a new named database.

        The build streams genome-by-genome through
        :class:`RefDBBuilder`; ``config`` pins the content-determining
        fields (space/window/stride) every later delta must match.

        Args:
          encode_fn: optional encoder override (kept for this database's
            future deltas too).  The default reference encoder is
            bit-exact with every backend, so serving through any backend
            needs no override.
          on_genome: streaming-build progress hook ``(name, total_rows)``.
        """
        if not _NAME_RE.match(name):
            raise ValueError(
                f"invalid database name {name!r} (need alphanumeric plus "
                f"'._-', not starting with a separator)")
        with self._lock:
            if name in self._entries:
                raise ValueError(f"database {name!r} already exists "
                                 f"(apply_delta to update it)")
            entry = _Entry(name, config, encode_fn)
            self._entries[name] = entry
        try:
            with entry.mutate:
                builder = self._builder(entry)
                db = refdb_store.build_streaming(genomes, builder,
                                                 on_genome=on_genome)
                snap = self._publish(
                    entry, db, parent=None, delta=None,
                    genomes_digest=_genomes_digest(genomes))
        except BaseException:
            with self._lock:
                self._entries.pop(name, None)   # failed create leaves no stub
            raise
        self._notify(snap)
        return snap

    # -- live updates -------------------------------------------------------
    def apply_delta(self, name: str, *,
                    add: dict[str, np.ndarray] | None = None,
                    remove: Sequence[str] = ()) -> RefDBSnapshot:
        """Publish version N+1 = current version with species added/removed.

        Incremental: only ``add``'s genomes are encoded (streamed through
        a fresh builder under the database's pinned config), ``remove``
        drops prototype rows without touching the rest.  Removal applies
        first, so replacing a genome is one delta (``remove=[x],
        add={x: new_tokens}``).  The new snapshot is written and the
        current pointer flipped atomically; subscribers are notified
        after the in-memory swap.
        """
        if not add and not remove:
            raise ValueError("empty delta: pass add= genomes and/or "
                             "remove= species names")
        entry = self._entry(name)
        with entry.mutate:
            base = self.current(name)
            addition = None
            if add:
                builder = self._builder(entry)
                for gname, toks in add.items():
                    builder.add_genome(gname, toks)
                addition = builder.finish()
            db = assoc_memory.apply_delta(base.db, add=addition,
                                          remove=tuple(remove))
            delta = {"added": sorted(add) if add else [],
                     "removed": sorted(remove)}
            snap = self._publish(entry, db, parent=base.version, delta=delta)
        self._notify(snap)
        return snap

    # -- reads --------------------------------------------------------------
    def databases(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._entries))

    def config(self, name: str) -> ProfilerConfig:
        """The build config pinned at ``create`` (content fields bind all
        later deltas; execution fields are just its defaults — the router
        overrides backend/batch per serving deployment)."""
        return self._entry(name).config

    def current(self, name: str) -> RefDBSnapshot:
        """The newest published snapshot of ``name``."""
        entry = self._entry(name)
        with self._lock:
            if entry.current_version == 0:
                raise KeyError(f"database {name!r} has no published version")
            return entry.snapshots[entry.current_version]

    def snapshot(self, name: str, version: int) -> RefDBSnapshot:
        """A specific retained version (every publish is retained)."""
        entry = self._entry(name)
        with self._lock:
            try:
                return entry.snapshots[version]
            except KeyError:
                raise KeyError(
                    f"database {name!r} has no version {version} "
                    f"(have {sorted(entry.snapshots)})") from None

    def versions(self, name: str) -> tuple[int, ...]:
        entry = self._entry(name)
        with self._lock:
            return tuple(sorted(entry.snapshots))

    # -- change notification (the router's auto-swap hook) ------------------
    def subscribe(self, fn: Callable[[RefDBSnapshot], None]
                  ) -> Callable[[RefDBSnapshot], None]:
        """Call ``fn(snapshot)`` after every publish; returns ``fn``.

        Called outside registry locks, after the new version is already
        current — a subscriber that re-reads ``current`` sees it.
        """
        with self._lock:
            self._subscribers.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[RefDBSnapshot], None]) -> None:
        with self._lock:
            if fn in self._subscribers:
                self._subscribers.remove(fn)

    # -- persistence --------------------------------------------------------
    @classmethod
    def open(cls, root: str | pathlib.Path) -> "RefDBRegistry":
        """Reopen a persisted registry: every database's CURRENT version.

        Only the current snapshot of each database is loaded into memory
        (older versions stay on disk for audit via their manifests); the
        version counter continues from the published chain.
        """
        root = pathlib.Path(root)
        reg = cls(root)
        for pointer in sorted(root.glob("*/CURRENT.json")):
            try:
                meta = json.loads(pointer.read_text())
            except (OSError, json.JSONDecodeError):
                continue                      # torn dir: skip, don't poison
            if meta.get("pointer_version") != _POINTER_VERSION:
                continue
            name = meta["database"]
            path = pointer.parent / meta["file"]
            db = refdb_store.load(path)
            if db is None:
                continue                      # defect reads as absent
            m = refdb_store.manifest(path) or {}
            entry = _Entry(name, ProfilerConfig.from_dict(meta["config"]))
            snap = RefDBSnapshot(
                database=name, version=int(meta["version"]), db=db,
                parent_version=m.get("parent_version"),
                delta=m.get("delta"), path=path)
            entry.snapshots[snap.version] = snap
            entry.current_version = snap.version
            reg._entries[name] = entry
        return reg

    # -- internals ----------------------------------------------------------
    def _entry(self, name: str) -> _Entry:
        with self._lock:
            try:
                return self._entries[name]
            except KeyError:
                raise KeyError(
                    f"unknown database {name!r}; registry has "
                    f"{list(sorted(self._entries))}") from None

    def _builder(self, entry: _Entry) -> RefDBBuilder:
        c = entry.config
        return RefDBBuilder(c.space, window=c.window,
                            stride=c.effective_stride,
                            batch_size=c.batch_size,
                            encode_fn=entry.encode_fn)

    def _publish(self, entry: _Entry, db: RefDB, *, parent: int | None,
                 delta: dict | None, genomes_digest: str = ""
                 ) -> RefDBSnapshot:
        """Write (optional) + swap the current pointer; runs under
        ``entry.mutate`` so versions are a gapless chain."""
        version = entry.current_version + 1
        path = None
        if self.root is not None:
            d = self.root / entry.name
            path = d / f"v{version:04d}.npz"
            c = entry.config
            refdb_store.save(
                path, db,
                refdb_fingerprint=c.refdb_fingerprint(),
                genomes_digest=genomes_digest,
                config_fields={"space": dataclasses.asdict(c.space),
                               "window": c.window,
                               "stride": c.effective_stride,
                               "database": entry.name},
                version=version, parent_version=parent, delta=delta)
            self._flip_pointer(d, entry, version, path.name)
        snap = RefDBSnapshot(database=entry.name, version=version, db=db,
                             parent_version=parent, delta=delta, path=path)
        with self._lock:
            entry.snapshots[version] = snap
            entry.current_version = version
        return snap

    def _flip_pointer(self, d: pathlib.Path, entry: _Entry, version: int,
                      filename: str) -> None:
        """Atomically repoint CURRENT.json at the new snapshot file."""
        meta = {
            "pointer_version": _POINTER_VERSION,
            "database": entry.name,
            "version": version,
            "file": filename,
            "config": entry.config.to_dict(),
        }
        fd, tmp = tempfile.mkstemp(dir=d, prefix="CURRENT.json.tmp-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(meta, f, sort_keys=True, indent=2)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, d / "CURRENT.json")
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _notify(self, snap: RefDBSnapshot) -> None:
        with self._lock:
            subs = list(self._subscribers)
        for fn in subs:
            fn(snap)
