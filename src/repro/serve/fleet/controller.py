"""`FleetController`: replication, routing, failover, fleet-wide swaps.

The multi-host front over a set of :class:`HostReplica` s.  One source
registry is the truth about database versions; the controller

* **replicates** published versions to every healthy host (pull-based
  :meth:`HostReplica.sync` — resumable after downtime, gaps allowed),
* **routes** each request by tenant affinity (a crc32 ring home, so the
  same tenant lands on the same host whenever load permits) broken by
  least-outstanding-reads load across healthy hosts,
* **fails over**: when a host dies mid-flight, every affected request is
  re-submitted on a surviving replica *before* the dead router's work is
  cancelled — re-submission is safe because reports are deterministic,
  and the rerouted report is bit-identical to a sequential run,
* **swaps fleet-wide** in two phases: *prepare* (every host opens + pins
  the new version; nothing serves it yet) then *flip* (every router
  repoints admissions); the old version's source pins are only released
  as each host reports drained (:meth:`poll_retire`) — generalizing the
  single-host pin/release refcounting across the fleet, so the source
  registry's ``gc`` cannot collect a version any host still serves.

Fleet observability: every replica records into its own registry;
:meth:`metrics_snapshot` folds them with
:meth:`~repro.obs.metrics.MetricsRegistry.merged` into one snapshot
whose every series carries a ``host`` label, plus the controller's own
fleet gauges (healthy hosts, per-host replication lag, per-host
outstanding reads) and counters (requests, reroutes, swaps).
"""

from __future__ import annotations

import itertools
import threading
import time
import zlib
from typing import Callable

from repro import obs
from repro.pipeline.report import ProfileReport
from repro.pipeline.source import IterableSource, as_source
from repro.serve.profiler_service import RequestState, ServiceOverloaded
from repro.serve.registry import RefDBRegistry
from repro.serve.router import RoutedHandle
from repro.serve.fleet.replica import HostDown, HostReplica, HostState


class NoHealthyHosts(RuntimeError):
    """Every replica is down or draining: nothing can take the request."""


class FleetHandle:
    """Caller view of a fleet request across host failovers.

    ``attempts`` records every (host, routed handle) the request ran on;
    a kill-triggered failover appends a new attempt *before* the dead
    host's copy is cancelled, so :meth:`result` never observes a gap.
    The final report is whatever the last attempt produced — bit-exact
    with a sequential run on :attr:`version` (the database version that
    admitted the final attempt).
    """

    def __init__(self, controller: "FleetController", request_id: str,
                 tenant: str, database: str, source, est_reads: int):
        self._controller = controller
        self.request_id = request_id
        self.tenant = tenant
        self.database = database
        self.source = source
        self.est_reads = est_reads
        self.rerouted = False
        self._attempts: list[tuple[str, RoutedHandle]] = []
        self._error: BaseException | None = None
        self._settled = False

    @property
    def attempts(self) -> tuple[tuple[str, str], ...]:
        """(host_id, routed request_id) per attempt, in order."""
        with self._controller._lock:
            return tuple((h, r.request_id) for h, r in self._attempts)

    @property
    def host(self) -> str:
        """The host serving (or having served) the latest attempt."""
        with self._controller._lock:
            return self._attempts[-1][0]

    @property
    def version(self) -> int:
        """Database version the latest attempt was admitted against."""
        with self._controller._lock:
            return self._attempts[-1][1].version

    @property
    def done(self) -> bool:
        with self._controller._lock:
            return self._error is not None or self._attempts[-1][1].done

    def result(self, timeout: float | None = None) -> ProfileReport:
        """Block until the request (any attempt) is terminal.

        Raises :class:`HostDown` / :class:`NoHealthyHosts` when failover
        was impossible, or the request's own error, like the single-host
        handle."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._controller._lock:
                if self._error is not None:
                    self._settle_locked()
                    raise self._error
                host, routed = self._attempts[-1]
            left = 0.25
            if deadline is not None:
                left = min(left, max(0.0, deadline - time.monotonic()))
            try:
                # timeout=0 still succeeds on an already-terminal attempt
                report = routed.result(timeout=left)
            except TimeoutError:
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"fleet request {self.request_id} still "
                        f"{routed.state.value} after {timeout}s") from None
                continue
            except BaseException:
                with self._controller._lock:
                    # A kill_host failover appends the replacement
                    # attempt BEFORE cancelling the dead host's copy, so
                    # a cancellation observed here with a newer attempt
                    # (or an error verdict) just means "look again".
                    if (self._attempts[-1][1] is not routed
                            or self._error is not None):
                        continue
                    self._settle_locked()
                raise
            with self._controller._lock:
                if self._attempts[-1][1] is not routed:
                    continue            # superseded mid-completion
                self._settle_locked()
            return report

    def cancel(self) -> bool:
        with self._controller._lock:
            return self._attempts[-1][1].cancel()

    def _settle_locked(self) -> None:
        """One-shot terminal accounting (outstanding reads, live list);
        runs under the controller lock."""
        if self._settled:
            return
        self._settled = True
        host = self._attempts[-1][0]
        c = self._controller
        c._outstanding[host] = max(0, c._outstanding.get(host, 0)
                                   - self.est_reads)
        if self in c._live:
            c._live.remove(self)


class FleetController:
    """Multi-host serving: replication + routing + failover + swaps."""

    def __init__(self, source: RefDBRegistry, hosts: int = 3, *,
                 backend: str | None = None, batch_size: int | None = None,
                 backend_options: dict | None = None,
                 workers_per_host: int = 1, service_active: int = 8,
                 service_queue: int = 256, buckets=None,
                 metrics: obs.MetricsRegistry | None = None):
        """Args:
          source: the source-of-truth registry (builds/deltas publish
            here; hosts mirror it).
          hosts: number of :class:`HostReplica` s to spin up (named
            ``host0..host{N-1}``).
          backend / batch_size / backend_options / workers_per_host /
            service_active / service_queue / buckets: forwarded to every
            replica's router.
          metrics: the controller's own fleet-level registry (default: a
            fresh real one; it is merged into every
            :meth:`metrics_snapshot`).
        """
        if hosts < 1:
            raise ValueError("need at least one host")
        self.source = source
        self._metrics = metrics if metrics is not None \
            else obs.MetricsRegistry()
        self._replicas: dict[str, HostReplica] = {}
        self._order: list[str] = []
        for i in range(hosts):
            hid = f"host{i}"
            self._replicas[hid] = HostReplica(
                hid, source, backend=backend, batch_size=batch_size,
                backend_options=backend_options, workers=workers_per_host,
                service_active=service_active, service_queue=service_queue,
                buckets=buckets)
            self._order.append(hid)
        self._lock = threading.RLock()
        self._tenants: dict[str, dict] = {}    # tenant -> spec kwargs
        self._targets: dict[str, int] = {}     # db -> fleet serving version
        # (db, version) -> host ids holding a source pin for it: one pin
        # per host that serves (or drains) the version, released as each
        # host drains — the fleet-wide generalization of the router's
        # pin/release refcounting.
        self._src_pins: dict[tuple[str, int], set[str]] = {}
        self._outstanding: dict[str, int] = {h: 0 for h in self._order}
        self._live: list[FleetHandle] = []
        self._ids = itertools.count()
        self.swap_log: list[tuple[str, str, int]] = []  # (phase, host, v)
        self._m_requests = self._metrics.counter(
            "fleet_requests_total", "Requests routed, by tenant and host.")
        self._m_reroutes = self._metrics.counter(
            "fleet_reroutes_total",
            "Requests re-submitted on a surviving host after their host "
            "died mid-flight.")
        self._m_swaps = self._metrics.counter(
            "fleet_swaps_total", "Fleet-wide two-phase hot-swaps completed.")
        self._m_healthy = self._metrics.gauge(
            "fleet_healthy_hosts", "Replicas currently accepting routes.")
        self._m_lag = self._metrics.gauge(
            "fleet_replication_lag_versions",
            "Versions a host's mirror trails the source, by host and "
            "database.")
        self._m_outstanding = self._metrics.gauge(
            "fleet_outstanding_reads",
            "Reads admitted to a host and not yet completed, by host.")

    # -- topology ------------------------------------------------------------
    def hosts(self) -> tuple[HostReplica, ...]:
        return tuple(self._replicas[h] for h in self._order)

    def host(self, host_id: str) -> HostReplica:
        try:
            return self._replicas[host_id]
        except KeyError:
            raise KeyError(f"unknown host {host_id!r}; fleet has "
                           f"{self._order}") from None

    def healthy_hosts(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(h for h in self._order
                         if self._replicas[h].state is HostState.HEALTHY)

    def add_tenant(self, tenant: str, database: str, *,
                   max_active: int = 4, max_queue: int = 16) -> None:
        """Register a tenant on every non-down host (replicating the
        database first); quotas apply per host."""
        with self._lock:
            if tenant in self._tenants:
                raise ValueError(f"tenant {tenant!r} already registered")
            self._tenants[tenant] = {
                "database": database, "max_active": max_active,
                "max_queue": max_queue}
        for hid in self._order:
            replica = self._replicas[hid]
            if replica.state is HostState.DOWN:
                continue
            v = replica.add_tenant(tenant, database,
                                   max_active=max_active,
                                   max_queue=max_queue)
            with self._lock:
                self._targets.setdefault(database, v)
                self._pin_source_locked(database, v, hid)

    # -- routing -------------------------------------------------------------
    def submit(self, reads, *, tenant: str,
               request_id: str | None = None) -> FleetHandle:
        """Route one request: tenant-affinity home, least-outstanding
        load tiebreak, next-best host on per-host quota overflow."""
        src = as_source(reads)
        with self._lock:
            try:
                database = self._tenants[tenant]["database"]
            except KeyError:
                raise KeyError(
                    f"unknown tenant {tenant!r}; registered: "
                    f"{sorted(self._tenants)}") from None
            candidates = self._route_order_locked(tenant)
            if not candidates:
                raise NoHealthyHosts(
                    f"no healthy host to route tenant {tenant!r}")
            est = self._est_reads(src)
            rid = request_id or f"{tenant}-f{next(self._ids)}"
            last_err: BaseException | None = None
            for hid in candidates:
                try:
                    routed = self._replicas[hid].submit(
                        src, tenant=tenant, request_id=rid)
                except ServiceOverloaded as e:
                    last_err = e          # quota full HERE; try the next
                    continue
                fh = FleetHandle(self, rid, tenant, database, src, est)
                fh._attempts.append((hid, routed))
                self._outstanding[hid] += est
                self._live.append(fh)
                self._m_requests.inc(1, tenant=tenant, host=hid)
                return fh
            raise last_err if last_err is not None else NoHealthyHosts(
                f"no healthy host accepted tenant {tenant!r}")

    def _route_order_locked(self, tenant: str) -> list[str]:
        """Healthy hosts, best first: least outstanding reads, ring
        distance from the tenant's crc32 affinity home as tiebreak."""
        healthy = [h for h in self._order
                   if self._replicas[h].state is HostState.HEALTHY]
        if not healthy:
            return []
        n = len(self._order)
        home = zlib.crc32(tenant.encode()) % n
        index = {h: i for i, h in enumerate(self._order)}

        def key(hid: str):
            return (self._outstanding.get(hid, 0),
                    (index[hid] - home) % n)

        return sorted(healthy, key=key)

    @staticmethod
    def _est_reads(src) -> int:
        try:
            return max(1, len(src))
        except TypeError:
            return 1

    # -- failover ------------------------------------------------------------
    def kill_host(self, host_id: str) -> list[str]:
        """Simulate a host death; returns the rerouted request ids.

        Order matters: every live request on the dying host is
        re-submitted on a surviving replica *first* (under the
        controller lock, so :meth:`FleetHandle.result` waiters always
        find the replacement attempt), then the dead router is stopped,
        cancelling its copies.  Non-replayable sources cannot be
        re-submitted: their handles fail with :class:`HostDown`.
        """
        replica = self.host(host_id)
        rerouted: list[str] = []
        with self._lock:
            if replica.state is HostState.DOWN:
                return rerouted
            replica.state = HostState.DOWN
            for fh in list(self._live):
                hid, routed = fh._attempts[-1]
                if hid != host_id:
                    continue
                if routed.state is RequestState.DONE:
                    continue              # report already complete
                self._outstanding[hid] = max(
                    0, self._outstanding[hid] - fh.est_reads)
                if isinstance(fh.source, IterableSource):
                    fh._error = HostDown(
                        f"host {host_id} died mid-flight and request "
                        f"{fh.request_id}'s source is not replayable")
                    continue
                targets = self._route_order_locked(fh.tenant)
                placed = False
                for nhid in targets:
                    try:
                        nr = self._replicas[nhid].submit(
                            fh.source, tenant=fh.tenant,
                            request_id=(f"{fh.request_id}"
                                        f"-r{len(fh._attempts)}"))
                    except ServiceOverloaded:
                        continue
                    fh._attempts.append((nhid, nr))
                    fh.rerouted = True
                    self._outstanding[nhid] += fh.est_reads
                    self._m_reroutes.inc(1, **{"from": host_id, "to": nhid})
                    rerouted.append(fh.request_id)
                    placed = True
                    break
                if not placed:
                    fh._error = NoHealthyHosts(
                        f"host {host_id} died and no healthy replica "
                        f"could take request {fh.request_id}")
            # The dead host's serving pins on the source are released:
            # its in-flight work is gone, nothing there drains.
            for (db, v), holders in list(self._src_pins.items()):
                self._release_source_locked(db, v, host_id)
        replica.kill()
        return rerouted

    def revive_host(self, host_id: str) -> None:
        """Bring a DOWN host back into rotation: restart its pump,
        resync every database (resumable — versions the source gc'd
        while it was down are simply skipped), and flip it to the
        fleet's serving version."""
        replica = self.host(host_id)
        if replica.state is not HostState.DOWN:
            return
        replica.revive()
        with self._lock:
            targets = dict(self._targets)
            tenants = dict(self._tenants)
        for tenant, spec in tenants.items():
            if tenant not in {s.tenant for s in replica.router.tenants()}:
                replica.add_tenant(tenant, spec["database"],
                                   max_active=spec["max_active"],
                                   max_queue=spec["max_queue"])
        for db, target in targets.items():
            replica.sync(db)
            if replica.router.serving_version(db) != target:
                replica.prepare(db, target)
                replica.flip(db, target)
            with self._lock:
                self._pin_source_locked(db, target, host_id)

    # -- the fleet-wide two-phase swap ---------------------------------------
    def fleet_swap(self, database: str, *, version: int | None = None,
                   on_phase: Callable[[str], None] | None = None) -> int:
        """Swap every host to ``version`` (default: source current).

        Phase 1 *prepare*: every non-down host installs + pins the new
        version locally; no admissions see it yet — the invariant tests
        assert through ``on_phase("prepared")``.  Phase 2 *flip*: every
        router repoints atomically.  Retire is asynchronous: each host's
        source pin on the old version is released by
        :meth:`poll_retire` once that host reports drained, and only
        when every host has does the old version become gc-eligible at
        the source."""
        snap = (self.source.current(database) if version is None
                else self.source.snapshot(database, version))
        new_v = snap.version
        with self._lock:
            hosts = [h for h in self._order
                     if self._replicas[h].state is not HostState.DOWN]
        for hid in hosts:                             # phase 1: prepare
            self._replicas[hid].prepare(database, new_v)
            with self._lock:
                self._pin_source_locked(database, new_v, hid)
                self.swap_log.append(("prepare", hid, new_v))
        if on_phase is not None:
            on_phase("prepared")
        for hid in hosts:                             # phase 2: flip
            self._replicas[hid].flip(database, new_v)
            with self._lock:
                self.swap_log.append(("flip", hid, new_v))
        if on_phase is not None:
            on_phase("flipped")
        with self._lock:
            self._targets[database] = new_v
        self._m_swaps.inc(1, database=database)
        return new_v

    def poll_retire(self) -> list[tuple[str, int, str]]:
        """Release source pins for old versions hosts have drained;
        returns the (database, version, host) pins released.  When the
        last host's pin goes, the old version is gc-eligible at the
        source (subject to its own keep_last policy)."""
        released: list[tuple[str, int, str]] = []
        with self._lock:
            items = [(db, v, set(hs))
                     for (db, v), hs in self._src_pins.items()
                     if v != self._targets.get(db)]
        for db, v, holders in items:
            for hid in holders:
                if self._replicas[hid].drained(db, v):
                    with self._lock:
                        if self._release_source_locked(db, v, hid):
                            released.append((db, v, hid))
        return released

    def wait_retired(self, database: str, version: int,
                     timeout: float = 60.0) -> None:
        """Block until every host's pin on (database, version) is gone."""
        deadline = time.monotonic() + timeout
        while True:
            self.poll_retire()
            with self._lock:
                if (database, version) not in self._src_pins:
                    return
                holders = sorted(self._src_pins[(database, version)])
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"{database} v{version} still pinned by {holders} "
                    f"after {timeout}s")
            time.sleep(0.005)

    def _pin_source_locked(self, db: str, version: int, hid: str) -> None:
        holders = self._src_pins.setdefault((db, version), set())
        if hid not in holders:
            self.source.pin(db, version)
            holders.add(hid)

    def _release_source_locked(self, db: str, version: int,
                               hid: str) -> bool:
        holders = self._src_pins.get((db, version))
        if holders is None or hid not in holders:
            return False
        self.source.release(db, version)
        holders.discard(hid)
        if not holders:
            del self._src_pins[(db, version)]
        return True

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "FleetController":
        for replica in self.hosts():
            if replica.state is not HostState.DOWN:
                replica.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        for replica in self.hosts():
            if replica.state is not HostState.DOWN:
                replica.stop(drain=drain)

    def __enter__(self) -> "FleetController":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))

    def close(self) -> None:
        """Full teardown: stop every replica and release all the source
        pins this fleet holds (the fleet's versions become gc-eligible
        at the source).  A stopped-but-not-closed fleet can be
        restarted; a closed one is done."""
        self.stop(drain=True)
        with self._lock:
            for (db, v), holders in list(self._src_pins.items()):
                for hid in list(holders):
                    self._release_source_locked(db, v, hid)

    def run_until_idle(self, timeout: float = 600.0) -> None:
        """Block until every live fleet request reached a terminal
        attempt (the replicas' own workers do the pumping)."""
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                live = [fh for fh in self._live
                        if fh._error is None
                        and not fh._attempts[-1][1].done]
            if not live:
                return
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"{len(live)} fleet request(s) still live after "
                    f"{timeout}s")
            time.sleep(0.005)

    # -- fleet observability -------------------------------------------------
    def metrics_snapshot(self) -> obs.MetricsRegistry:
        """One merged registry: every replica's series labelled
        ``host=<id>``, plus the controller's fleet-level series."""
        with self._lock:
            healthy = sum(r.state is HostState.HEALTHY
                          for r in self._replicas.values())
            self._m_healthy.set(healthy)
            for hid in self._order:
                self._m_outstanding.set(self._outstanding.get(hid, 0),
                                        host=hid)
                replica = self._replicas[hid]
                for db in self.source.databases():
                    self._m_lag.set(replica.lag(db), host=hid, database=db)
        merged = obs.MetricsRegistry.merged(
            {hid: self._replicas[hid].metrics for hid in self._order})
        merged.merge_from(self._metrics)
        return merged
