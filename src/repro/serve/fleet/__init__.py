"""Multi-host fleet serving: replicated hosts behind one controller.

Each :class:`HostReplica` is a complete copy of the single-host stack
(mirror registry + tenant router + per-host metrics); the
:class:`FleetController` replicates database versions to every host,
routes by tenant affinity + least-outstanding-reads, fails requests
over to surviving replicas when a host dies mid-flight (bit-exactly —
reports are deterministic), and hot-swaps the whole fleet in two phases
with source-registry pins guaranteeing the old version only becomes
gc-eligible after every host drained.  See ``docs/FLEET.md``.
"""

from repro.serve.fleet.controller import (FleetController, FleetHandle,
                                          NoHealthyHosts)
from repro.serve.fleet.replica import HostDown, HostReplica, HostState

__all__ = [
    "FleetController", "FleetHandle", "NoHealthyHosts",
    "HostDown", "HostReplica", "HostState",
]
