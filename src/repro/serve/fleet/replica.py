"""`HostReplica`: one simulated host of the serving fleet.

A replica is a full copy of the single-host serving stack — its own
in-memory :class:`~repro.serve.registry.RefDBRegistry` mirror, its own
:class:`~repro.serve.router.TenantRouter` with ``auto_swap=False`` (the
fleet controller, not the source registry, decides when a host flips
versions — the two-phase swap invariant depends on it), and its own
:class:`~repro.obs.metrics.MetricsRegistry` so fleet observability can
fold per-host registries into one labelled snapshot.

Replication is **pull-based**: :meth:`sync` installs every version the
source registry retains that the mirror is missing, sharing the
immutable ``RefDB`` objects (no re-encode — see
:meth:`RefDBRegistry.install`).  A host that was down across publishes
simply resyncs on revive and the mirror chain skips the versions the
source has since garbage-collected — replication is resumable by
construction.

Health is a three-state machine the controller drives:

  HEALTHY   routed new requests; pumping.
  DRAINING  no new requests; pumping until in-flight work completes.
  DOWN      killed: pump stopped, in-flight requests cancelled (the
            controller reroutes them to surviving replicas).
"""

from __future__ import annotations

import enum
import threading

from repro import obs
from repro.serve.registry import RefDBRegistry
from repro.serve.router import RoutedHandle, TenantRouter


class HostState(enum.Enum):
    HEALTHY = "healthy"
    DRAINING = "draining"
    DOWN = "down"


class HostDown(RuntimeError):
    """The request's host died and the fleet could not recover it
    (non-replayable source, or no healthy replica left to retry on)."""


class HostReplica:
    """One fleet host: mirror registry + router + per-host metrics."""

    def __init__(self, host_id: str, source: RefDBRegistry, *,
                 backend: str | None = None, batch_size: int | None = None,
                 backend_options: dict | None = None, workers: int = 1,
                 service_active: int = 8, service_queue: int = 256,
                 buckets=None, metrics: obs.MetricsRegistry | None = None):
        """Args:
          host_id: stable fleet-unique name (becomes the ``host`` label
            on every metric this replica records).
          source: the source-of-truth registry versions are pulled from.
          backend / batch_size / backend_options: execution overrides
            for this host's router (content fields stay pinned by the
            source config, exactly as on a single host).
          workers: pump threads :meth:`start` launches.
          metrics: this host's metrics registry (default: a fresh real
            one — fleet snapshots are built by merging these).
        """
        self.host_id = host_id
        self.source = source
        self.metrics = metrics if metrics is not None \
            else obs.MetricsRegistry()
        self.registry = RefDBRegistry(root=None, metrics=self.metrics)
        self.router = TenantRouter(
            self.registry, backend=backend, batch_size=batch_size,
            backend_options=backend_options, buckets=buckets,
            service_active=service_active, service_queue=service_queue,
            auto_swap=False, metrics=self.metrics)
        self.state = HostState.HEALTHY
        self.workers = workers
        self._lock = threading.Lock()

    # -- replication ---------------------------------------------------------
    def sync(self, database: str) -> int:
        """Pull every missing retained version of ``database`` from the
        source into the mirror; returns how many were installed.

        Shares the source's immutable ``RefDB`` objects and keeps source
        version numbers, so "version 3" means the same thing on every
        host.  Safe to call repeatedly (installs are idempotent) and
        after any amount of downtime (gaps are fine)."""
        config = self.source.config(database)
        installed = 0
        have = set(self.registry.versions(database)) \
            if database in self.registry.databases() else set()
        for version in self.source.versions(database):
            if version in have:
                continue
            snap = self.source.snapshot(database, version)
            self.registry.install(database, snap, config=config)
            installed += 1
        return installed

    def lag(self, database: str) -> int:
        """Replication lag in versions behind the source's current."""
        src = self.source.current(database).version
        try:
            mine = self.registry.current(database).version
        except KeyError:
            mine = 0
        return max(0, src - mine)

    # -- serving -------------------------------------------------------------
    def add_tenant(self, tenant: str, database: str, *,
                   max_active: int = 4, max_queue: int = 16) -> int:
        """Register a tenant on this host (syncs the database first);
        returns the version this host now serves for it."""
        self.sync(database)
        self.router.add_tenant(tenant, database, max_active=max_active,
                               max_queue=max_queue)
        return self.router.serving_version(database)

    def submit(self, source, *, tenant: str,
               request_id: str | None = None) -> RoutedHandle:
        if self.state is not HostState.HEALTHY:
            raise HostDown(f"host {self.host_id} is {self.state.value}; "
                           f"not accepting new requests")
        return self.router.submit(source, tenant=tenant,
                                  request_id=request_id)

    # -- the two-phase swap, host side --------------------------------------
    def prepare(self, database: str, version: int) -> None:
        """Phase 1: open + pin ``version`` locally without serving it.

        After this returns the snapshot is resident in the mirror and
        pinned there, so nothing local can collect it before the flip —
        but admissions still route to the old version."""
        self.sync(database)
        self.registry.snapshot(database, version)    # loud if absent
        self.registry.pin(database, version)

    def flip(self, database: str, version: int) -> int:
        """Phase 2: atomically repoint new admissions at ``version``.

        The router takes its own serving pin; the prepare pin is
        released here so pin counts stay balanced."""
        served = self.router.hot_swap(database, version=version)
        self.registry.release(database, version)
        return served

    def drained(self, database: str, version: int) -> bool:
        """True once ``version`` neither serves nor drains here — the
        host-side signal the fleet retire phase waits for."""
        if self.state is HostState.DOWN:
            return True        # cancelled work never completes a drain
        return (self.router.serving_version(database) != version
                and version not in self.router.draining_versions(database))

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "HostReplica":
        with self._lock:
            self.state = HostState.HEALTHY
            if not self.router.running:
                self.router.start(self.workers)
        return self

    def stop(self, *, drain: bool = True) -> None:
        self.router.stop(drain=drain)

    def drain(self) -> None:
        """Stop receiving new routes; in-flight work keeps pumping."""
        with self._lock:
            if self.state is HostState.HEALTHY:
                self.state = HostState.DRAINING

    def kill(self) -> None:
        """Simulate host death: cancel in-flight work, stop the pump.

        The controller reroutes the cancelled requests to surviving
        replicas (safe because reports are deterministic).  Idempotent —
        the controller marks the state DOWN first (so routing excludes
        the host while reroutes are placed) and then calls this."""
        with self._lock:
            self.state = HostState.DOWN
        self.router.stop(drain=False)

    def revive(self) -> None:
        """Bring a DOWN host back: restart the pump (the controller
        resyncs databases and re-flips to the fleet's serving version)."""
        with self._lock:
            if self.state is not HostState.DOWN:
                return
            self.state = HostState.HEALTHY
        if not self.router.running:
            self.router.start(self.workers)

    def __repr__(self) -> str:
        return (f"HostReplica({self.host_id!r}, state={self.state.value}, "
                f"databases={list(self.registry.databases())})")
