"""Serving steps: prefill (last-token logits only) and decode, + sampling.

The prefill step intentionally returns only the last position's logits —
at 32k x 256k-vocab, full prefill logits would be ~0.5 TB; sampling needs
one row per sequence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers, lm


def make_prefill_step(cfg: ModelConfig, max_len: int, *,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      unroll: bool = False):
    """prefill(params, tokens, **frontend_kw) -> (last_logits (B,V), caches)."""

    def prefill_step(params, tokens, enc_embeds=None, prefix_embeds=None):
        kw = {}
        if enc_embeds is not None:
            kw["enc_embeds"] = enc_embeds
        if prefix_embeds is not None:
            kw["prefix_embeds"] = prefix_embeds
        h, _, seg_caches = lm.forward(
            params, tokens, cfg, return_caches=True, return_hidden=True,
            remat=False, unroll=unroll, q_chunk=q_chunk, kv_chunk=kv_chunk,
            **kw)
        b, s, _ = h.shape
        caches = []
        for (kind, _), cache in zip(lm.segments(cfg), seg_caches):
            caches.append(lm._assemble_cache(cache, cfg, kind, b, s, max_len))
        last = layers.lm_logits(params["embed"], h[:, -1:], cfg)[:, 0]
        return last, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, unroll: bool = False):
    """decode(params, token (B,), caches, cur_pos) -> (logits (B,V), caches)."""

    def decode(params, token, caches, cur_pos):
        return lm.decode_step(params, token, caches, cur_pos, cfg,
                              unroll=unroll)

    return decode


def sample(logits: jax.Array, key: jax.Array, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """Greedy (t=0) or temperature/top-k sampling. logits: (B, V)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[..., -1:], -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def generate(params, prompt: jax.Array, cfg: ModelConfig, *, steps: int,
             max_len: int, key: jax.Array | None = None,
             temperature: float = 0.0, q_chunk: int = 256,
             kv_chunk: int = 256, **frontend_kw) -> jax.Array:
    """Simple end-to-end generation loop (prefill + jit'd decode steps)."""
    key = key if key is not None else jax.random.key(0)
    prefill = jax.jit(make_prefill_step(cfg, max_len, q_chunk=q_chunk,
                                        kv_chunk=kv_chunk))
    decode = jax.jit(make_decode_step(cfg))
    logits, caches = prefill(params, prompt, **frontend_kw)
    pos0 = prompt.shape[1] + (
        cfg.vlm_prefix if frontend_kw.get("prefix_embeds") is not None else 0)
    toks = []
    tok = sample(logits, key, temperature)
    for i in range(steps):
        toks.append(tok)
        logits, caches = decode(params, tok, caches, jnp.int32(pos0 + i))
        key, sub = jax.random.split(key)
        tok = sample(logits, sub, temperature)
    toks.append(tok)
    return jnp.stack(toks, axis=1)
