"""Generic fixed-shape cohort scheduler: the admission core of serving.

Every serving path in this repo — the legacy LM decode loop
(:class:`repro.serve.batching.CohortScheduler`) and the profiler service
(:class:`repro.serve.profiler_service.ProfilingService`) — has the same
shape problem: jit compiles one executable per input shape, so admission
must quantize work into a *small, bounded* set of shapes.  This module
owns that policy once:

  * items are submitted FIFO with a ``size`` (prompt length, read length);
  * :meth:`FixedShapeScheduler.next_cohort` pops up to ``slots`` items and
    pads their variable dimension up to a *bucket* — the smallest
    configured padding length holding the cohort's largest item — so the
    jit cache sees at most ``len(buckets)`` shapes per slot count;
  * ``buckets=None`` degrades to exact-max padding (the legacy LM
    behavior: one shape per distinct cohort max).

The scheduler is deliberately compute-free: it never touches arrays, only
decides *who* runs together and *at what padded length*.  Callers own the
actual padding (left-pad prompts, right-pad reads) and the step function.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Generic, Sequence, TypeVar

T = TypeVar("T")


def pow2_buckets(lo: int, hi: int) -> tuple[int, ...]:
    """Power-of-two padding lengths covering ``[lo, hi]`` (both rounded up).

    The default bounded-shape policy: ``pow2_buckets(64, 400)`` ->
    ``(64, 128, 256, 512)``; at most ``log2(hi/lo)+1`` jit cache entries.
    """
    if lo < 1 or hi < lo:
        raise ValueError(f"need 1 <= lo <= hi, got lo={lo} hi={hi}")
    out = []
    b = 1
    while b < lo:
        b *= 2
    while True:
        out.append(b)
        if b >= hi:
            return tuple(out)
        b *= 2


@dataclasses.dataclass(frozen=True)
class Cohort(Generic[T]):
    """One admitted group: run these items together at ``length`` padding."""
    items: tuple[T, ...]
    length: int            # pad the variable dimension to this


class FixedShapeScheduler(Generic[T]):
    """FIFO admission into padding-bucketed, bounded-shape cohorts."""

    def __init__(self, *, slots: int, buckets: Sequence[int] | None = None):
        """Args:
          slots: maximum items per cohort (the fixed batch dimension).
          buckets: allowed padding lengths, ascending; an item longer than
            ``max(buckets)`` is rejected at submit.  ``None`` pads each
            cohort to its exact max size (unbounded shape set — only for
            callers that control sizes themselves).
        """
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.slots = slots
        self.buckets = tuple(sorted(buckets)) if buckets is not None else None
        if self.buckets is not None and not self.buckets:
            raise ValueError("buckets must be non-empty (or None)")
        self._queue: deque[tuple[T, int]] = deque()

    def bucket_for(self, size: int) -> int:
        """Smallest configured padding length >= ``size``."""
        if self.buckets is None:
            return size
        for b in self.buckets:
            if size <= b:
                return b
        raise ValueError(
            f"item size {size} exceeds the largest bucket "
            f"{self.buckets[-1]}; configure larger buckets")

    def submit(self, item: T, size: int) -> None:
        """Queue ``item`` whose variable dimension is ``size`` long."""
        if size < 0:
            raise ValueError(f"size must be >= 0, got {size}")
        self.bucket_for(max(size, 1))      # reject oversize at the door
        self._queue.append((item, size))

    def __len__(self) -> int:
        return len(self._queue)

    def next_cohort(self) -> Cohort[T] | None:
        """Pop the next FIFO cohort (<= ``slots`` items), or None if idle.

        The cohort's padding length is the bucket of its largest item;
        FIFO order is never reordered across cohorts, so a submitter's
        items come back in submission order — the property the profiler
        service's bit-exactness guarantee rests on.
        """
        if not self._queue:
            return None
        items, max_size = [], 1
        while self._queue and len(items) < self.slots:
            item, size = self._queue.popleft()
            items.append(item)
            max_size = max(max_size, size)
        return Cohort(items=tuple(items), length=self.bucket_for(max_size))

    def drain(self) -> list[Cohort[T]]:
        """Pop every remaining cohort (for batch-style callers)."""
        out = []
        while (c := self.next_cohort()) is not None:
            out.append(c)
        return out
