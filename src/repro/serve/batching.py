"""Cohort-based continuous batching for the LM decode loop (legacy path).

Fixed-shape serving: requests are admitted into a cohort of ``slots``
(jit caches one shape); each slot decodes in lockstep; finished slots
(EOS or budget) are refilled from the queue at cohort boundaries with
their own cache region reset.  Per-slot positions are tracked host-side;
the decode step itself uses per-slot cur_pos via the kpos masking already
built into the caches (a slot's stale entries carry kpos > its reset
point and are masked by ``kpos <= cur_pos`` only after overwrite —
freshly admitted slots therefore start from a zeroed kpos region).

Admission (FIFO grouping into ``slots``-sized cohorts, choice of padded
prompt length) is delegated to the generic
:class:`repro.serve.scheduler.FixedShapeScheduler`; this module keeps
only the LM-specific lockstep decode.  By default cohorts pad to their
exact prompt max (the historical behavior); pass ``buckets=`` to bound
the prefill shape set instead.

This is deliberately simple (cohort granularity, no paged attention);
the dry-run's decode_32k cell is one production cohort.  New serving
work targets the profiler service in
:mod:`repro.serve.profiler_service`, not this loop.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.serve.scheduler import FixedShapeScheduler


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class CohortScheduler:
    """Admit-from-queue, decode-in-lockstep, emit-on-finish."""

    def __init__(self, *, slots: int, max_len: int,
                 prefill_fn: Callable, decode_fn: Callable,
                 sample_fn: Callable, eos_id: int | None = None,
                 buckets: Sequence[int] | None = None):
        """``buckets`` bounds the prefill shape set, at a cost: prompts
        are LEFT-padded to the bucket, and padded positions physically
        occupy cache slots, so a cohort's decode budget becomes
        ``max_len - bucket`` rather than ``max_len - true_prompt_max``.
        Size ``max_len`` with the largest bucket in mind."""
        self.max_len = max_len
        self.prefill = prefill_fn
        self.decode = decode_fn
        self.sample = sample_fn
        self.eos_id = eos_id
        self._sched: FixedShapeScheduler[Request] = FixedShapeScheduler(
            slots=slots, buckets=buckets)
        self.finished: list[Request] = []

    @property
    def slots(self) -> int:
        return self._sched.slots

    def submit(self, req: Request) -> None:
        self._sched.submit(req, len(req.prompt))

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Serve until queue + cohort drain (cohort-granular admission)."""
        while (cohort := self._sched.next_cohort()) is not None:
            self._run_cohort(list(cohort.items), cohort.length, max_steps)
            self.finished.extend(cohort.items)
        return self.finished

    def _run_cohort(self, cohort: list[Request], plen: int,
                    max_steps: int) -> None:
        b = len(cohort)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(cohort):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        logits, caches = self.prefill(jnp.asarray(prompts))
        tok = self.sample(logits)
        active = np.ones(b, bool)
        for step in range(max_steps):
            for i, r in enumerate(cohort):
                if not active[i]:
                    continue
                t = int(np.asarray(tok)[i])
                r.out.append(t)
                if (self.eos_id is not None and t == self.eos_id) or \
                        len(r.out) >= r.max_new_tokens:
                    r.done = True
                    active[i] = False
            if not active.any() or plen + step + 1 >= self.max_len:
                break
            logits, caches = self.decode(tok, caches,
                                         jnp.int32(plen + step))
            tok = self.sample(logits)
