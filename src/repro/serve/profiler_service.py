"""`ProfilingService`: the profiler-first serving front door.

The paper frames Demeter as the engine of a real-time food monitoring
system: one expensive reference database, many cheap concurrent queries.
This module is that shape as an API.  A service owns **one** shared
RefDB + backend (a :class:`~repro.pipeline.session.ProfilingSession`) and
admits many concurrent :class:`ProfileRequest` s, each wrapping its own
:class:`~repro.pipeline.source.ReadSource`:

    service = ProfilingService(session)           # session has a RefDB
    with service:                                 # background worker
        h1 = service.submit(FastqSource("a.fastq"))
        h2 = service.submit(FastqSource("b.fastq"))
        partial = h1.snapshot()                   # streaming report
        report = h1.result(timeout=60)            # final ProfileReport

Requests' reads are interleaved into fixed-shape cohorts through the
generic :class:`~repro.serve.scheduler.FixedShapeScheduler` (rows =
``config.batch_size``, read length padded to a bounded bucket set), run
through the session's single hot-path primitive
:meth:`~repro.pipeline.session.ProfilingSession.classify_batch`, and the
resulting rows are demultiplexed into per-request streaming
:class:`~repro.pipeline.report.ProfileAccumulator` s.

**Bit-exactness contract**: a request's final report equals a sequential
``ProfilingSession.profile(source)`` run of the same reads, bit for bit,
on every backend.  This holds because (a) the scheduler never reorders a
submitter's items, (b) encode/agreement are row-independent and invariant
to length padding (the encoder masks by per-row ``lengths``), and (c)
``ProfileAccumulator.finalize`` is batch-grouping-independent.  The
parity test in ``tests/test_profiler_service.py`` enforces it.

Lifecycle & backpressure: requests move QUEUED -> RUNNING -> one of
DONE / CANCELLED / FAILED.  At most ``max_active`` requests interleave at
once; at most ``max_queue`` more wait in admission.  A ``submit`` beyond
that raises :class:`ServiceOverloaded` (or blocks when ``block=True``) —
the backpressure signal a fronting RPC layer turns into HTTP 429/503.

The service is synchronous at heart — :meth:`step` runs one cohort on the
calling thread — with an optional single background worker
(:meth:`start`/:meth:`stop`, or the context manager) so callers can
submit at their own rate.  All jax compute stays on whichever thread
pumps ``step``.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
import time
from typing import Iterator, Sequence

import numpy as np

from repro import obs
from repro.obs.trace import RequestTimeline
from repro.pipeline.report import ProfileAccumulator, ProfileReport
from repro.pipeline.session import ProfilingSession
from repro.pipeline.source import ReadSource, as_source
from repro.serve.scheduler import Cohort, FixedShapeScheduler, pow2_buckets


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    CANCELLED = "cancelled"
    FAILED = "failed"

    @property
    def terminal(self) -> bool:
        return self in (RequestState.DONE, RequestState.CANCELLED,
                        RequestState.FAILED)


class ServiceOverloaded(RuntimeError):
    """Admission queue full: shed load or retry later (HTTP 429 analogue)."""


@dataclasses.dataclass(frozen=True)
class ProfileRequest:
    """One profiling job: a read stream plus bookkeeping identity."""
    source: ReadSource
    request_id: str | None = None


@dataclasses.dataclass(frozen=True)
class _Read:
    """One admitted read row, tagged with its owning request."""
    handle: "ProfileHandle"
    tokens: np.ndarray      # (L_request,) int32
    length: int


class ProfileHandle:
    """Caller-side view of a submitted request (state, snapshots, result)."""

    def __init__(self, service: "ProfilingService", request: ProfileRequest,
                 request_id: str):
        self._service = service
        self.request = request
        self.request_id = request_id
        self.state = RequestState.QUEUED
        self.error: BaseException | None = None
        # The one request clock: every latency figure (here and on the
        # router's RoutedHandle) derives from these phase marks, and the
        # same marks assemble into the request's trace.
        self.timeline = RequestTimeline()
        self.timeline.mark("submitted")
        self.reads_admitted = 0
        self.reads_classified = 0
        self._acc: ProfileAccumulator | None = None
        self._reads: Iterator[tuple[np.ndarray, int]] | None = None
        self._exhausted = False
        self._final: ProfileReport | None = None
        self._terminal = threading.Event()

    # -- caller API ---------------------------------------------------------
    def snapshot(self) -> ProfileReport:
        """Incremental report over the reads classified *so far*.

        Valid in any state (zero-read report while queued); once the
        request is DONE this is the final report.
        """
        with self._service._lock:
            if self._final is not None:
                return self._final
            return self._service._finalize_locked(self)

    def result(self, timeout: float | None = None) -> ProfileReport:
        """Block until terminal; return the final report.

        Raises TimeoutError on timeout, the request's own error if it
        FAILED, and RuntimeError if it was CANCELLED.
        """
        if not self._terminal.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} still {self.state.value} "
                f"after {timeout}s")
        if self.state is RequestState.FAILED:
            raise self.error  # type: ignore[misc]
        if self.state is RequestState.CANCELLED:
            raise RuntimeError(f"request {self.request_id} was cancelled")
        assert self._final is not None
        return self._final

    def cancel(self) -> bool:
        """Cancel the request; True if it was still live.

        Already-classified reads are discarded with the rest: a cancelled
        request produces no report (``result`` raises).
        """
        return self._service._cancel(self)

    @property
    def done(self) -> bool:
        return self.state.terminal

    # -- the unified latency clock (all timeline-derived) -------------------
    @property
    def submitted_at(self) -> float | None:
        return self.timeline.at("submitted")

    @property
    def started_at(self) -> float | None:
        return self.timeline.at("started")

    @property
    def finished_at(self) -> float | None:
        return self.timeline.at("finished")

    @property
    def latency_s(self) -> float | None:
        """Submit-to-terminal wall time, once terminal."""
        return self.timeline.latency_s

    @property
    def queue_wait_s(self) -> float | None:
        """Admission wait: submit until the request went RUNNING."""
        return self.timeline.queue_wait_s

    @property
    def service_s(self) -> float | None:
        """Active service time: RUNNING until terminal."""
        return self.timeline.service_s


class ProfilingService:
    """Multi-tenant profiling over one shared RefDB + backend.

    The shared database may itself be sharded: when the session's backend
    is ``sharded``, ``build_or_load_refdb`` has already padded the
    prototype axis and distributed it across the device mesh, and every
    cohort the service pumps through ``classify_batch`` runs the
    shard_map'd AM search — many tenants, one multi-device database, no
    service-level changes (requests stay bit-identical to sequential
    runs; ``tests/test_sharded.py`` pins this on an 8-way mesh).
    """

    def __init__(self, session: ProfilingSession, *, max_active: int = 8,
                 max_queue: int = 64,
                 buckets: Sequence[int] | None = None,
                 metrics: obs.MetricsRegistry | None = None,
                 tracer: obs.TraceRecorder | None = None,
                 obs_labels: dict[str, str] | None = None):
        """Args:
          session: a session whose RefDB is already built/loaded (the one
            expensive shared structure; requests only read it — for the
            ``sharded`` backend it is already device-placed, one shard
            per device).
          max_active: how many requests interleave reads at once.
          max_queue: bound on requests waiting behind the active set.
          buckets: allowed read-length paddings for cohort shapes
            (default: powers of two up to 4096 — a bounded jit cache).
          metrics: explicit metrics registry (default: the process
            global, a no-op unless ``obs.enable_metrics()`` ran).
          tracer: explicit trace recorder (same default convention).
          obs_labels: constant labels stamped on every sample this
            service records (the tenant router sets ``tenant=...``).
        """
        if session.refdb is None:
            raise ValueError(
                "session has no RefDB; call build_or_load_refdb() before "
                "constructing the service (requests share one database)")
        if max_active < 1 or max_queue < 0:
            raise ValueError("need max_active >= 1 and max_queue >= 0")
        self.session = session
        self.max_active = max_active
        self.max_queue = max_queue
        self._sched: FixedShapeScheduler[_Read] = FixedShapeScheduler(
            slots=session.config.batch_size,
            buckets=buckets if buckets is not None else pow2_buckets(16, 4096))
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._queued: list[ProfileHandle] = []
        self._active: list[ProfileHandle] = []
        self._ids = itertools.count()
        self._worker: threading.Thread | None = None
        self._stopping = False
        self.error: BaseException | None = None
        self.cohorts_run = 0
        self.reads_classified = 0
        self._obs = obs.resolve_metrics(metrics)
        self._tracer = obs.resolve_tracer(tracer)
        self._labels = dict(obs_labels or {})
        self._m_admission_wait = self._obs.histogram(
            "serve_admission_wait_seconds",
            "Queue wait from submit until the request went RUNNING.",
            unit="s")
        self._m_batch_time = self._obs.histogram(
            "serve_batch_seconds",
            "Wall time of one cohort classify_batch, demux included.",
            unit="s")
        self._m_fill_ratio = self._obs.histogram(
            "serve_cohort_fill_ratio",
            "Live rows over total slots per executed cohort.",
            buckets=obs.RATIO_BUCKETS)
        self._m_padding_rows = self._obs.counter(
            "serve_cohort_padding_rows_total",
            "Wasted (padding) rows across executed cohorts.")
        self._m_reads = self._obs.counter(
            "serve_reads_classified_total",
            "Reads classified and demuxed into request accumulators.")
        self._m_requests = self._obs.counter(
            "serve_requests_total",
            "Requests reaching a terminal state, by outcome.")
        self._m_queue_depth = self._obs.gauge(
            "serve_queue_depth", "Requests waiting in admission right now.")
        self._m_active = self._obs.gauge(
            "serve_active_requests", "Requests currently interleaving reads.")

    # -- admission ----------------------------------------------------------
    def submit(self, request: ProfileRequest | ReadSource | object, *,
               request_id: str | None = None, block: bool = False,
               timeout: float | None = None) -> ProfileHandle:
        """Admit one profiling request; returns its :class:`ProfileHandle`.

        Accepts a :class:`ProfileRequest`, a :class:`ReadSource`, or
        anything :func:`~repro.pipeline.source.as_source` coerces.  The
        id precedence is ``request.request_id``, then ``request_id=``,
        then a generated ``req-N``.  When the admission queue is full,
        raises :class:`ServiceOverloaded` (``block=False``) or waits up
        to ``timeout`` for space.
        """
        if not isinstance(request, ProfileRequest):
            request = ProfileRequest(source=as_source(request),
                                     request_id=request_id)
        with self._work:
            if self.error is not None:
                raise RuntimeError(
                    "service worker died on an unrecoverable error"
                ) from self.error
            deadline = None if timeout is None else time.monotonic() + timeout
            # The service holds at most max_active + max_queue live
            # requests; past that, admission is the backpressure point.
            while len(self._queued) + len(self._active) \
                    >= self.max_active + self.max_queue:
                if not block:
                    raise ServiceOverloaded(
                        f"admission queue full ({self.max_queue} queued, "
                        f"{self.max_active} active)")
                left = None if deadline is None \
                    else deadline - time.monotonic()
                if left is not None and left <= 0:
                    raise TimeoutError("timed out waiting for admission")
                self._work.wait(left)
            rid = request.request_id or request_id \
                or f"req-{next(self._ids)}"
            handle = ProfileHandle(self, request, rid)
            self._queued.append(handle)
            if self._obs.enabled:
                self._m_queue_depth.set(len(self._queued), **self._labels)
            self._work.notify_all()
            return handle

    # -- the pump -----------------------------------------------------------
    def step(self) -> bool:
        """Run one cohort (admit -> classify -> demux); False when idle.

        This is the whole serving hot loop at its smallest granularity;
        ``run_until_idle`` and the background worker just call it.
        """
        with self._lock:
            self._activate_locked()
            active = list(self._active)
            want = self._sched.slots - len(self._sched)
        # Source iteration (file IO) happens outside the lock — only the
        # pumping thread touches the iterators, so submissions and
        # snapshots stay responsive while a slow FASTQ parses.
        events = self._pull_reads(active, want)
        with self._lock:
            self._apply_admission_locked(events)
            self._finish_exhausted_locked()
            cohort = self._sched.next_cohort()
            if cohort is None:
                return False
        # Classify outside the lock too: the service stays responsive
        # while the backend crunches the batch.
        tokens, lengths, live = self._assemble(cohort)
        recording = self._obs.enabled
        t_exec = time.perf_counter() if recording or self._tracer.enabled \
            else 0.0
        res = self.session.classify_batch(tokens, lengths,
                                          num_valid=len(live))
        hits = np.asarray(res.classification.hits)
        cat = np.asarray(res.classification.category)
        t_demux = time.perf_counter() if recording or self._tracer.enabled \
            else 0.0
        with self._work:
            if recording:
                slots = self._sched.slots
                self._m_batch_time.observe(
                    t_demux - t_exec, backend=self.session.config.backend,
                    **self._labels)
                self._m_fill_ratio.observe(len(live) / slots, **self._labels)
                self._m_padding_rows.inc(slots - len(live), **self._labels)
                self._m_reads.inc(len(live), **self._labels)
            # hits + category: two device->host pulls per cohort (the
            # session guards on its own registry's enabled flag).
            self.session.note_host_transfers(2)
            if recording or self._tracer.enabled:
                for h in {r.handle for r in live}:
                    h.timeline.mark("first_execute", at=t_exec)
                    h.timeline.mark("accumulate", at=t_demux)
            self._demux_locked(live, hits, cat)
            self.cohorts_run += 1
            self._finish_exhausted_locked()
            self._work.notify_all()
        return True

    @property
    def idle(self) -> bool:
        """True when nothing is queued, active, or buffered in cohorts.

        The drain condition: an idle service has every admitted request
        terminal.  The tenant router retires an old RefDB version's
        service the moment it reports idle.
        """
        with self._lock:
            return not (self._queued or self._active or len(self._sched))

    def run_until_idle(self) -> None:
        """Pump cohorts on the calling thread until no work remains."""
        while True:
            if self.step():
                continue
            if self.idle:
                return

    # -- background worker --------------------------------------------------
    def start(self) -> "ProfilingService":
        """Start the single background worker pumping :meth:`step`."""
        with self._lock:
            if self._worker is not None:
                raise RuntimeError("service already started")
            self._stopping = False
            self._worker = threading.Thread(target=self._pump, daemon=True,
                                            name="profiling-service")
            self._worker.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float | None = None
             ) -> None:
        """Stop the worker; ``drain=True`` finishes in-flight work first.

        If the worker died on an unrecoverable error, ``service.error``
        holds it (every live request was FAILED with the same error).
        """
        if not drain:
            self.cancel_all()
        with self._work:
            if self._worker is None:
                return
            self._stopping = True
            self._work.notify_all()
        self._worker.join(timeout)
        self._worker = None

    def cancel_all(self) -> int:
        """Best-effort cancel of every queued/active request; returns the
        number actually cancelled (requests mid-cohort may complete)."""
        with self._work:
            n = 0
            for h in list(self._queued) + list(self._active):
                n += bool(self._cancel_locked(h))
            self._work.notify_all()
            return n

    def __enter__(self) -> "ProfilingService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))

    def fail_all(self, error: BaseException) -> None:
        """Record a service-fatal error and fail every live request.

        The containment of last resort when per-request isolation could
        not hold (the backend itself died mid-cohort): the service
        refuses new work, and every ``result()``/blocking ``submit()``
        caller wakes immediately with ``error``.  Used by the internal
        worker and by any external pump (the tenant router) driving
        :meth:`step` itself.
        """
        with self._work:
            self.error = error
            for h in list(self._active) + list(self._queued):
                self._fail_locked(h, error)
            self._work.notify_all()

    def _pump(self) -> None:
        while True:
            try:
                did = self.step()
            except BaseException as e:
                # A failure the per-request isolation could not contain
                # (e.g. the backend itself died mid-cohort).  Don't die
                # silently — see fail_all.
                self.fail_all(e)
                return
            with self._work:
                if not did:
                    if self._stopping:
                        return
                    self._work.wait(0.05)

    # -- internals (all *_locked run under self._lock) ----------------------
    def _activate_locked(self) -> None:
        while self._queued and len(self._active) < self.max_active:
            h = self._queued.pop(0)
            if h.state is not RequestState.QUEUED:
                continue                       # cancelled while waiting
            h.state = RequestState.RUNNING
            h.timeline.mark("started")
            if self._obs.enabled:
                self._m_admission_wait.observe(
                    h.queue_wait_s or 0.0, **self._labels)
                self._m_queue_depth.set(len(self._queued), **self._labels)
                self._m_active.set(len(self._active) + 1, **self._labels)
            h._acc = ProfileAccumulator(self.session.refdb.num_species)
            h._reads = _iter_reads(h.request.source,
                                   self.session.config.batch_size)
            self._active.append(h)
            self._work.notify_all()

    def _pull_reads(self, active: list[ProfileHandle], want: int
                    ) -> list[tuple[str, ProfileHandle, object]]:
        """Round-robin up to ``want`` reads from the active streams.

        Runs WITHOUT the lock (the pump thread owns the iterators); the
        returned event list is applied under the lock.  A stream that
        ends, raises, or yields a read longer than the largest bucket
        produces an event for *its own request only* — failure isolation
        lives here.
        """
        events: list[tuple[str, ProfileHandle, object]] = []
        live = [h for h in active
                if not h._exhausted and h.state is RequestState.RUNNING]
        while want > 0 and live:
            for h in list(live):
                try:
                    tokens, length = next(h._reads)
                except StopIteration:
                    events.append(("end", h, None))
                    live.remove(h)
                    continue
                except BaseException as e:
                    events.append(("fail", h, e))
                    live.remove(h)
                    continue
                length = int(length)
                try:
                    self._sched.bucket_for(max(length, 1))
                except ValueError as e:        # oversize read: fail the
                    events.append(("fail", h, e))    # one request, not
                    live.remove(h)                   # the service
                    continue
                # Trim to the true length: the row re-pads to the cohort
                # bucket in _assemble, which may be shorter than the
                # request's own padded width.
                row = np.asarray(tokens, np.int32)[:length]
                events.append(("read", h, (row, length)))
                want -= 1
                if want <= 0:
                    break
        return events

    def _apply_admission_locked(
            self, events: list[tuple[str, ProfileHandle, object]]) -> None:
        for kind, h, payload in events:
            if kind == "end":
                h._exhausted = True
            elif kind == "fail" and not h.state.terminal:
                self._fail_locked(h, payload)
            elif kind == "read" and h.state is RequestState.RUNNING:
                row, length = payload
                h.reads_admitted += 1
                self._sched.submit(_Read(h, row, length), length)

    def _assemble(self, cohort: Cohort[_Read]
                  ) -> tuple[np.ndarray, np.ndarray, list[_Read]]:
        """Pad cohort rows to the fixed ``(batch_size, bucket)`` shape,
        dropping rows whose request died after admission."""
        live = [r for r in cohort.items
                if r.handle.state is RequestState.RUNNING]
        b, length = self._sched.slots, cohort.length
        tokens = np.zeros((b, length), np.int32)
        lengths = np.zeros(b, np.int32)
        for i, r in enumerate(live):
            tokens[i, :len(r.tokens)] = r.tokens
            lengths[i] = r.length
        return tokens, lengths, live

    def _demux_locked(self, live: list[_Read], hits: np.ndarray,
                      cat: np.ndarray) -> None:
        """Split cohort rows back into per-request accumulators, in order."""
        per: dict[ProfileHandle, list[int]] = {}
        for i, r in enumerate(live):
            if r.handle.state is RequestState.RUNNING:
                per.setdefault(r.handle, []).append(i)
        for h, idx in per.items():
            h._acc.add(hits[idx], cat[idx])
            h.reads_classified += len(idx)
            self.reads_classified += len(idx)

    def _finish_exhausted_locked(self) -> None:
        # classified == admitted implies nothing of this request's is
        # still buffered in the scheduler (rows only classify after
        # passing through a cohort, and RUNNING rows are never dropped).
        for h in list(self._active):
            if h.state is RequestState.RUNNING and h._exhausted \
                    and h.reads_classified == h.reads_admitted:
                h.timeline.mark("finalize")
                h._final = self._finalize_locked(h)
                self._terminate_locked(h, RequestState.DONE)

    def _finalize_locked(self, h: ProfileHandle) -> ProfileReport:
        db = self.session.refdb
        acc = h._acc or ProfileAccumulator(db.num_species)
        return acc.finalize(np.asarray(db.genome_lengths), db.species_names)

    def _cancel(self, h: ProfileHandle) -> bool:
        with self._work:
            out = self._cancel_locked(h)
            self._work.notify_all()
            return out

    def _cancel_locked(self, h: ProfileHandle) -> bool:
        if h.state.terminal:
            return False
        self._terminate_locked(h, RequestState.CANCELLED)
        return True

    def _fail_locked(self, h: ProfileHandle, err: BaseException) -> None:
        h.error = err
        self._terminate_locked(h, RequestState.FAILED)

    def _terminate_locked(self, h: ProfileHandle, state: RequestState
                          ) -> None:
        h.state = state
        h.timeline.mark("finished")
        if h in self._active:
            self._active.remove(h)
        if h in self._queued:
            self._queued.remove(h)
        if self._obs.enabled:
            self._m_requests.inc(1, state=state.value, **self._labels)
            self._m_queue_depth.set(len(self._queued), **self._labels)
            self._m_active.set(len(self._active), **self._labels)
        if self._tracer.enabled:
            self._tracer.record(h.request_id, h.timeline, state.value)
        close = getattr(h._reads, "close", None)
        if close is not None:
            close()
        h._terminal.set()
        self._work.notify_all()    # wake blocked submitters: a slot freed


def _iter_reads(source: ReadSource, batch_size: int
                ) -> Iterator[tuple[np.ndarray, int]]:
    """Flatten a source into single reads, in stream order.

    Iterating ``batches(batch_size)`` with the *session's* batch size
    means the service sees exactly the rows a sequential
    ``session.profile(source)`` would — only regrouped into cohorts.
    """
    for batch in source.batches(batch_size):
        for j in range(batch.num_valid):
            yield batch.tokens[j], int(batch.lengths[j])
