"""`TenantRouter`: many tenants, many live databases, one serving front.

:class:`~repro.serve.profiler_service.ProfilingService` is the data
plane: many concurrent requests over **one** RefDB + backend, bit-exact
with sequential runs.  The router is the control plane above it, built
for the production shape of food monitoring — several reference
databases (food, clinical, environmental) served at once, each updated
live through the :class:`~repro.serve.registry.RefDBRegistry`:

    registry.create("food", food_genomes, config)
    registry.create("clinical", clinical_genomes, config)
    router = TenantRouter(registry, backend="pallas_fused")
    router.add_tenant("acme", database="food", max_active=4, max_queue=16)
    router.add_tenant("cdc", database="clinical", max_active=8)
    with router:                                   # pump worker(s)
        h = router.submit(source, tenant="acme")   # routed by tenant
        registry.apply_delta("food", add={"listeria": toks})  # auto-swap
        report = h.result(timeout=60)              # old version, bit-exact

**Routing.**  Each tenant names a database; ``submit`` maps the request
to that database's *current* serving version.  Per-tenant admission
quotas (``max_active`` + ``max_queue`` live requests) are enforced at
the router door with the same backpressure contract as the service:
overflow raises :class:`ServiceOverloaded` for that tenant only — other
tenants, including ones sharing the database, are untouched.

**Zero-downtime hot-swap.**  Every served database version gets its own
``(ProfilingSession, ProfilingService)`` pair; all of them share one
resolved backend per database, so a swap never recompiles the query
path.  A swap (explicit :meth:`hot_swap`, or automatic on registry
publish) atomically repoints new admissions at version N+1 while the
version-N service keeps draining its in-flight requests to completion.
Because cohorts are formed *inside* one service, no cohort can ever mix
versions, and a request admitted against N is classified against N's
database from first read to final report — bit-identical to a
sequential run on N (the service's existing contract, now per version).
A drained service is retired on the next pump step.

**Fleet pumping.**  ``step()`` round-robins one cohort attempt across
every live service (current + draining, all databases);
``start(workers=n)`` runs n pump threads — services are claimed with a
per-service try-lock, so distinct services execute concurrently while
one service is never pumped from two threads at once (the service's
read-iterator contract).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time

from repro import obs
from repro.pipeline.backend import Backend, resolve_backend
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.report import ProfileReport
from repro.pipeline.session import ProfilingSession
from repro.serve.profiler_service import (ProfileHandle, ProfilingService,
                                          RequestState, ServiceOverloaded)
from repro.serve.registry import RefDBRegistry, RefDBSnapshot

#: Execution-only config fields the router may override per deployment;
#: content fields (space/window/stride) stay pinned by the registry.
_EXEC_FIELDS = ("backend", "backend_options", "batch_size")


class RouterClosed(RuntimeError):
    """The router is stopping or stopped: no new admissions.

    The :meth:`TenantRouter.stop` / :meth:`TenantRouter.submit` race
    contract: a submit that wins the race is admitted and — with
    ``drain=True`` — pumped to completion before the workers exit; a
    submit that loses raises this, immediately.  A handle is never left
    hanging with no pump behind it.  :meth:`TenantRouter.start` reopens
    admissions.
    """


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's routing + admission-quota contract."""

    tenant: str
    database: str
    max_active: int = 4     # requests in flight at once
    max_queue: int = 16     # further requests waiting in admission

    def __post_init__(self) -> None:
        if self.max_active < 1 or self.max_queue < 0:
            raise ValueError("need max_active >= 1 and max_queue >= 0")


class RoutedHandle:
    """Caller view of a routed request: the service handle + routing facts.

    ``version`` records which database version admitted the request —
    the version its report is bit-exact against, whatever swaps happen
    while it runs.
    """

    def __init__(self, handle: ProfileHandle, tenant: str, database: str,
                 version: int):
        self.handle = handle
        self.tenant = tenant
        self.database = database
        self.version = version

    # Delegation, not inheritance: the service owns the handle lifecycle.
    @property
    def request_id(self) -> str:
        return self.handle.request_id

    @property
    def state(self) -> RequestState:
        return self.handle.state

    @property
    def done(self) -> bool:
        return self.handle.done

    @property
    def latency_s(self) -> float | None:
        return self.handle.latency_s

    @property
    def queue_wait_s(self) -> float | None:
        return self.handle.queue_wait_s

    @property
    def service_s(self) -> float | None:
        return self.handle.service_s

    @property
    def timeline(self):
        """The request's phase clock (shared with the service handle)."""
        return self.handle.timeline

    def snapshot(self) -> ProfileReport:
        return self.handle.snapshot()

    def result(self, timeout: float | None = None) -> ProfileReport:
        return self.handle.result(timeout)

    def cancel(self) -> bool:
        return self.handle.cancel()


class _VersionedService:
    """One database version being served: session + service + pump claim."""

    def __init__(self, version: int, session: ProfilingSession,
                 service: ProfilingService):
        self.version = version
        self.session = session
        self.service = service
        self.drain_started: float | None = None   # set at hot-swap time
        # Claimed by at most one pump thread at a time (the service's
        # source iterators are single-pumper by contract); distinct
        # services pump concurrently across worker threads.
        self.pump_claim = threading.Lock()


class _Database:
    """Router-internal serving state of one named database."""

    def __init__(self, name: str, config: ProfilerConfig, backend: Backend,
                 current: _VersionedService):
        self.name = name
        self.config = config
        self.backend = backend
        self.current = current
        self.draining: list[_VersionedService] = []


class TenantRouter:
    """Multi-tenant, multi-database serving with zero-downtime swaps."""

    def __init__(self, registry: RefDBRegistry, *,
                 backend: str | None = None, batch_size: int | None = None,
                 backend_options: dict | None = None,
                 buckets=None, service_active: int = 8,
                 service_queue: int = 256, auto_swap: bool = True,
                 metrics: obs.MetricsRegistry | None = None,
                 tracer: obs.TraceRecorder | None = None):
        """Args:
          registry: source of truth for databases and their versions.
          backend / batch_size / backend_options: execution overrides
            applied over each database's registry config (content fields
            are never overridable — they pin what the prototypes mean).
            None keeps the registry config's value.
          buckets: cohort read-length buckets, forwarded to each service.
          service_active/service_queue: per-version service capacity.
            Tenant quotas are the binding admission limits; these bound
            the cohort-interleaving width and total buffering per
            database version.
          auto_swap: subscribe to the registry so every publish of a
            served database hot-swaps it immediately.
          metrics / tracer: explicit observability sinks (default: the
            process globals — no-ops unless ``obs.enable_*()`` ran).
            Forwarded to every per-version service the router spins up.
        """
        self.registry = registry
        self._overrides = {"backend": backend, "batch_size": batch_size,
                           "backend_options": backend_options}
        self._buckets = buckets
        self._service_active = service_active
        self._service_queue = service_queue
        self._lock = threading.RLock()
        self._dbs: dict[str, _Database] = {}
        self._tenants: dict[str, TenantSpec] = {}
        self._live: dict[str, list[RoutedHandle]] = {}
        self._ids = itertools.count()
        self._workers: list[threading.Thread] = []
        self._stopping = False
        self._closed = False
        self._wake = threading.Condition(self._lock)
        self.swaps = 0
        self.retired: list[tuple[str, int]] = []    # (database, version)
        self._obs = obs.resolve_metrics(metrics)
        self._tracer = obs.resolve_tracer(tracer)
        self._m_requests = self._obs.counter(
            "router_requests_total", "Requests admitted, by tenant.")
        self._m_rejections = self._obs.counter(
            "router_quota_rejections_total",
            "Submissions rejected at a tenant's admission quota.")
        self._m_reads_done = self._obs.counter(
            "router_reads_completed_total",
            "Reads classified in requests that reached DONE, by tenant.")
        self._m_swap_time = self._obs.histogram(
            "router_hot_swap_seconds",
            "Publish-to-serving wall time of a hot swap (spin-up "
            "included).", unit="s")
        self._m_drain_time = self._obs.histogram(
            "router_drain_seconds",
            "Swap-to-retire wall time of a superseded version's drain.",
            unit="s")
        self._m_live_version = self._obs.gauge(
            "router_serving_version",
            "Database version new admissions currently route to.")
        self._subscription = (registry.subscribe(self._on_publish)
                              if auto_swap else None)

    # -- topology -----------------------------------------------------------
    def serve_database(self, name: str) -> int:
        """Attach a registry database to the router; returns the version
        now serving.  Implied by :meth:`add_tenant`; idempotent."""
        with self._lock:
            if name in self._dbs:
                return self._dbs[name].current.version
        snap = self.registry.current(name)
        config = self._config_for(name)
        backend = resolve_backend(config.backend, config)
        vs = self._spin_up(snap, config, backend)
        with self._lock:
            if name in self._dbs:                   # lost a benign race
                return self._dbs[name].current.version
            self._dbs[name] = _Database(name, config, backend, vs)
            self.registry.pin(name, vs.version)
            if self._obs.enabled:
                self._m_live_version.set(vs.version, database=name)
            return vs.version

    def add_tenant(self, tenant: str, database: str, *,
                   max_active: int = 4, max_queue: int = 16) -> TenantSpec:
        """Register a tenant: route its requests to ``database`` under an
        admission quota of ``max_active`` running + ``max_queue`` waiting."""
        spec = TenantSpec(tenant, database, max_active, max_queue)
        self.serve_database(database)
        with self._lock:
            if tenant in self._tenants:
                raise ValueError(f"tenant {tenant!r} already registered "
                                 f"for {self._tenants[tenant].database!r}")
            self._tenants[tenant] = spec
            self._live[tenant] = []
        return spec

    def tenants(self) -> tuple[TenantSpec, ...]:
        with self._lock:
            return tuple(self._tenants[t] for t in sorted(self._tenants))

    def serving_version(self, database: str) -> int:
        """The version new admissions of ``database`` currently see."""
        with self._lock:
            return self._db(database).current.version

    def draining_versions(self, database: str) -> tuple[int, ...]:
        with self._lock:
            return tuple(vs.version for vs in self._db(database).draining)

    # -- admission ----------------------------------------------------------
    def submit(self, source, *, tenant: str, request_id: str | None = None,
               block: bool = False, timeout: float | None = None
               ) -> RoutedHandle:
        """Admit one request for ``tenant``, routed to its database's
        current version.

        Quota: a tenant may hold ``max_active + max_queue`` live
        (non-terminal) requests; past that, ``submit`` raises
        :class:`ServiceOverloaded` — or, with ``block=True``, waits up to
        ``timeout`` for one of the tenant's own requests to finish.
        Other tenants are unaffected either way.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._wake:
            try:
                spec = self._tenants[tenant]
            except KeyError:
                raise KeyError(
                    f"unknown tenant {tenant!r}; registered: "
                    f"{sorted(self._tenants)}") from None
            while True:
                # Checked on entry AND after every quota-wait wakeup: a
                # stop() racing this submit closes admissions under the
                # same lock, so the submit either got in before (and
                # will be drained) or raises here — it can never slip a
                # request behind the exiting pump workers.
                if self._closed:
                    raise RouterClosed(
                        f"router is stopped; submit for tenant {tenant!r} "
                        f"rejected (start() reopens admissions)")
                live = self._prune_locked(tenant)
                if len(live) < spec.max_active + spec.max_queue:
                    break
                if not block:
                    if self._obs.enabled:
                        self._m_rejections.inc(1, tenant=tenant)
                    raise ServiceOverloaded(
                        f"tenant {tenant!r} quota full "
                        f"({spec.max_active} active + {spec.max_queue} "
                        f"queued live requests)")
                left = None if deadline is None \
                    else deadline - time.monotonic()
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"timed out waiting for tenant {tenant!r} quota")
                self._wake.wait(left if left is None else min(left, 0.05))
            db = self._db(spec.database)
            vs = db.current
            rid = request_id or f"{tenant}-{next(self._ids)}"
            handle = vs.service.submit(source, request_id=rid)
            routed = RoutedHandle(handle, tenant, spec.database, vs.version)
            live.append(routed)
            if self._obs.enabled:
                self._m_requests.inc(1, tenant=tenant)
            return routed

    # -- the swap -----------------------------------------------------------
    def hot_swap(self, database: str, *, version: int | None = None) -> int:
        """Serve ``version`` (default: registry current) for new
        admissions; in-flight requests drain on their own version.

        The swap is atomic under the router lock: an admission observes
        either the old service or the new one, and each service's
        cohorts contain only its own version's requests.  The old
        service keeps being pumped until idle, then retires.  No-op if
        the requested version is already serving.
        """
        t0 = time.perf_counter()
        snap = (self.registry.current(database) if version is None
                else self.registry.snapshot(database, version))
        with self._lock:
            db = self._db(database)
            if db.current.version == snap.version:
                return snap.version
        # Build the new version's serving pair outside the lock: device
        # placement can be slow, and admissions must stay live on the old
        # version until the instant of the swap.
        vs = self._spin_up(snap, db.config, db.backend)
        with self._wake:
            if db.current.version == snap.version:  # benign publish race
                return snap.version
            self.registry.pin(database, vs.version)
            db.current.drain_started = time.perf_counter()
            db.draining.append(db.current)
            db.current = vs
            self.swaps += 1
            if self._obs.enabled:
                self._m_swap_time.observe(time.perf_counter() - t0,
                                          database=database)
                self._m_live_version.set(vs.version, database=database)
            self._wake.notify_all()
        return snap.version

    def _on_publish(self, snap: RefDBSnapshot) -> None:
        """Registry subscriber: auto-swap databases this router serves.

        Forward-only: a late notification for an already-superseded
        version never rolls serving back (explicit :meth:`hot_swap` with
        ``version=`` is the rollback path).
        """
        with self._lock:
            db = self._dbs.get(snap.database)
            if db is None or snap.version <= db.current.version:
                return
        self.hot_swap(snap.database, version=snap.version)

    # -- the pump -----------------------------------------------------------
    def step(self) -> bool:
        """One round-robin pass: pump every claimable service one cohort.

        Returns True if any service did work.  Safe to call from many
        threads — each service is claimed by at most one pumper at a
        time, and a claim conflict just skips (the other thread is
        already pumping it).
        """
        did = False
        for vs in self._services():
            if not vs.pump_claim.acquire(blocking=False):
                continue
            try:
                try:
                    did = vs.service.step() or did
                except BaseException as e:
                    # Same containment as the service's own worker: the
                    # failure poisons that one service (and version), not
                    # the router — other databases/versions keep serving.
                    vs.service.fail_all(e)
            finally:
                vs.pump_claim.release()
        if self._retire_drained():
            did = True
        with self._wake:
            # Sweep terminal handles out of every tenant's quota list —
            # keeps quota headroom fresh between submits and is where
            # per-tenant completed-read accounting happens.
            for t in self._tenants:
                self._prune_locked(t)
            self._wake.notify_all()
        return did

    def run_until_idle(self) -> None:
        """Pump on the calling thread until every service is idle."""
        while True:
            if self.step():
                continue
            if self.idle:
                return

    @property
    def idle(self) -> bool:
        return all(vs.service.idle for vs in self._services())

    # -- workers ------------------------------------------------------------
    @property
    def running(self) -> bool:
        """True while pump workers are live (start'ed, not yet stop'ed)."""
        with self._lock:
            return bool(self._workers)

    def start(self, workers: int = 1) -> "TenantRouter":
        """Start ``workers`` pump threads (distinct services in parallel)."""
        if workers < 1:
            raise ValueError("workers must be >= 1")
        with self._lock:
            if self._workers:
                raise RuntimeError("router already started")
            self._stopping = False
            self._closed = False
            self._workers = [
                threading.Thread(target=self._pump, daemon=True,
                                 name=f"tenant-router-{i}")
                for i in range(workers)]
        for t in self._workers:
            t.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float | None = None
             ) -> None:
        """Stop the pump threads; ``drain=True`` finishes in-flight work.

        Closes admissions first (under the router lock), so a submit
        racing this call either completed before the close — and with
        ``drain=True`` its request is pumped to a terminal state before
        the workers exit — or raises :class:`RouterClosed`.  Either way
        no handle is left queued with nothing pumping it.
        """
        with self._wake:
            self._closed = True
            if not drain:
                for vs in self._services():
                    vs.service.cancel_all()
            if not self._workers:
                return
            self._stopping = True
            self._wake.notify_all()
        for t in self._workers:
            t.join(timeout)
        self._workers = []

    def close(self) -> None:
        """Detach from the registry (stop receiving auto-swap publishes)."""
        if self._subscription is not None:
            self.registry.unsubscribe(self._subscription)
            self._subscription = None

    def __enter__(self) -> "TenantRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))
        self.close()

    def _pump(self) -> None:
        while True:
            did = self.step()
            with self._wake:
                if not did:
                    # Exit only when stopping AND truly idle: a submit
                    # that won the stop race may have landed between the
                    # step above and this check — its request still gets
                    # drained before the worker leaves.
                    if self._stopping and self.idle:
                        return
                    self._wake.wait(0.02)

    # -- internals ----------------------------------------------------------
    def _config_for(self, name: str) -> ProfilerConfig:
        config = self.registry.config(name)
        overrides = {k: v for k, v in self._overrides.items()
                     if v is not None}
        assert set(overrides) <= set(_EXEC_FIELDS)
        return dataclasses.replace(config, **overrides) \
            if overrides else config

    def _spin_up(self, snap: RefDBSnapshot, config: ProfilerConfig,
                 backend: Backend) -> _VersionedService:
        """Session + service for one snapshot: adopt (re-place) the
        database on the shared backend, ready to admit."""
        session = ProfilingSession(config, backend=backend,
                                   metrics=self._obs)
        session.adopt_refdb(snap.db)
        service = ProfilingService(session,
                                   max_active=self._service_active,
                                   max_queue=self._service_queue,
                                   buckets=self._buckets,
                                   metrics=self._obs,
                                   tracer=self._tracer,
                                   obs_labels={"database": snap.database})
        return _VersionedService(snap.version, session, service)

    def _db(self, name: str) -> _Database:
        try:
            return self._dbs[name]
        except KeyError:
            raise KeyError(
                f"database {name!r} not served by this router; serving "
                f"{sorted(self._dbs)}") from None

    def _services(self) -> list[_VersionedService]:
        with self._lock:
            out = []
            for db in self._dbs.values():
                out.append(db.current)
                out.extend(db.draining)
            return out

    def _retire_drained(self) -> bool:
        """Drop drained old-version services (and their registry pins);
        True if any retired."""
        with self._lock:
            retired = False
            for db in self._dbs.values():
                keep = []
                for vs in db.draining:
                    if vs.service.idle:
                        self.retired.append((db.name, vs.version))
                        self.registry.release(db.name, vs.version)
                        if self._obs.enabled \
                                and vs.drain_started is not None:
                            self._m_drain_time.observe(
                                time.perf_counter() - vs.drain_started,
                                database=db.name)
                        retired = True
                    else:
                        keep.append(vs)
                db.draining = keep
            return retired

    def _prune_locked(self, tenant: str) -> list[RoutedHandle]:
        """Drop terminal handles from the tenant's live list (quota
        accounting); runs under the router lock."""
        live = []
        for h in self._live[tenant]:
            if not h.done:
                live.append(h)
            elif self._obs.enabled and h.state is RequestState.DONE:
                self._m_reads_done.inc(h.handle.reads_classified,
                                       tenant=tenant)
        self._live[tenant] = live
        return live
