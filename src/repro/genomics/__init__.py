"""Genomics data substrate: alphabet, synthetic communities, IO, k-mers."""

from repro.genomics import alphabet, kmers, synth

__all__ = ["alphabet", "kmers", "synth"]
