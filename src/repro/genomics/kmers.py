"""Shared k-mer machinery for the baseline profilers (numpy, host-side)."""

from __future__ import annotations

import numpy as np


def pack_kmers(tokens: np.ndarray, k: int) -> np.ndarray:
    """All k-mers of a token sequence packed base-4 into uint64 (k <= 31)."""
    if k > 31:
        raise ValueError("k must be <= 31 to fit uint64")
    t = np.asarray(tokens, np.uint64)
    if len(t) < k:
        return np.empty(0, np.uint64)
    win = np.lib.stride_tricks.sliding_window_view(t, k)
    weights = (np.uint64(4) ** np.arange(k, dtype=np.uint64))
    return (win * weights[None, :]).sum(axis=1, dtype=np.uint64)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mix (hash) of packed k-mers."""
    x = np.asarray(x, np.uint64).copy()
    x += np.uint64(0x9E3779B97F4A7C15)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def read_kmer_hashes(tokens: np.ndarray, length: int, k: int) -> np.ndarray:
    """Hashes of the k-mers of one (possibly padded) read."""
    return splitmix64(pack_kmers(tokens[:length], k))
