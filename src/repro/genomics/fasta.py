"""Minimal FASTA/FASTQ IO (plain text, no external deps)."""

from __future__ import annotations

import pathlib
from typing import Iterator

import numpy as np

from repro.genomics import alphabet


def read_fasta(path: str | pathlib.Path) -> dict[str, np.ndarray]:
    """FASTA file -> {name: int32 tokens}."""
    genomes: dict[str, np.ndarray] = {}
    name, chunks = None, []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if line.startswith(">"):
                if name is not None:
                    genomes[name] = alphabet.seq_to_tokens("".join(chunks))
                name, chunks = line[1:].split()[0], []
            else:
                chunks.append(line)
    if name is not None:
        genomes[name] = alphabet.seq_to_tokens("".join(chunks))
    return genomes


def write_fasta(path: str | pathlib.Path, genomes: dict[str, np.ndarray],
                width: int = 80) -> None:
    with open(path, "w") as f:
        for name, toks in genomes.items():
            f.write(f">{name}\n")
            seq = alphabet.tokens_to_seq(toks)
            for i in range(0, len(seq), width):
                f.write(seq[i:i + width] + "\n")


def iter_fastq(path: str | pathlib.Path, read_len: int
               ) -> "Iterator[tuple[np.ndarray, int]]":
    """Lazily yield FASTQ records as (tokens (read_len,), length).

    Sequences are truncated/zero-padded to ``read_len``.  The single
    FASTQ-parsing loop: both the eager :func:`read_fastq` and the
    streaming ``repro.pipeline.FastqSource`` consume it.
    """
    with open(path) as f:
        while True:
            header = f.readline()
            if not header:
                return
            if not header.strip():
                continue    # blank line (e.g. trailing newline), not a record
            seq = f.readline().strip()
            f.readline()  # '+'
            f.readline()  # quals
            t = alphabet.seq_to_tokens(seq)[:read_len]
            row = np.zeros(read_len, np.int32)
            row[:len(t)] = t
            yield row, len(t)


def read_fastq(path: str | pathlib.Path, read_len: int
               ) -> tuple[np.ndarray, np.ndarray]:
    """FASTQ -> (tokens (R, read_len) padded/truncated, lengths (R,))."""
    toks, lens = [], []
    for row, n in iter_fastq(path, read_len):
        toks.append(row)
        lens.append(n)
    return (np.stack(toks) if toks else np.empty((0, read_len), np.int32),
            np.asarray(lens, np.int32))


def write_fastq(path: str | pathlib.Path, tokens: np.ndarray,
                lengths: np.ndarray) -> None:
    with open(path, "w") as f:
        for i, (t, l) in enumerate(zip(tokens, lengths)):
            seq = alphabet.tokens_to_seq(t[:l])
            f.write(f"@read_{i}\n{seq}\n+\n{'I' * int(l)}\n")
