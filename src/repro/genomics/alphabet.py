"""DNA alphabet utilities: sequences <-> int32 token arrays."""

from __future__ import annotations

import numpy as np

BASES = "ACGT"
A, C, G, T = 0, 1, 2, 3
ALPHABET_SIZE = 4

_LUT = np.full(256, 0, np.int32)
for i, b in enumerate(BASES):
    _LUT[ord(b)] = i
    _LUT[ord(b.lower())] = i
# Ambiguity code 'N' (and anything unknown) deterministically maps to A;
# the HDC encoder is robust to the induced noise (paper §2.3 robustness).

_COMP = np.array([T, G, C, A], np.int32)


def seq_to_tokens(seq: str) -> np.ndarray:
    """ASCII DNA string -> int32 tokens in [0, 4)."""
    raw = np.frombuffer(seq.encode("ascii"), np.uint8)
    return _LUT[raw]


def tokens_to_seq(tokens: np.ndarray) -> str:
    return "".join(BASES[t] for t in np.asarray(tokens))


def reverse_complement(tokens: np.ndarray) -> np.ndarray:
    return _COMP[np.asarray(tokens)[::-1]]
