"""Synthetic food-community generator (reference genomes + sample reads).

Stands in for the AFS20/AFS31 reference databases and the PRJEB34001 /
PRJNA271645 calibrator-sausage samples used by the paper, which are not
available offline.  The generator reproduces the properties that matter
for profiling difficulty:

* a set of reference genomes, optionally with *homologous* shared regions
  between related species (drives multi-mapped reads, the case that
  distinguishes Demeter's step 4/5 from winner-take-all HDC);
* strain-level divergence (SNP rate vs the reference) between the sampled
  organism and its reference genome;
* Illumina-style short reads with a per-base error rate and a ground-truth
  abundance profile.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CommunitySpec:
    """Knobs for the synthetic community."""
    num_species: int = 8
    genome_len: int = 100_000
    homology_fraction: float = 0.05   # fraction of genome shared with a sibling
    strain_snp_rate: float = 0.002    # divergence sample-vs-reference
    read_len: int = 150
    read_error_rate: float = 0.002    # sequencing error per base
    seed: int = 7


def make_reference_genomes(spec: CommunitySpec) -> dict[str, np.ndarray]:
    """Generate the reference database (the AFS analogue)."""
    rng = np.random.default_rng(spec.seed)
    genomes: dict[str, np.ndarray] = {}
    prev: np.ndarray | None = None
    for s in range(spec.num_species):
        g = rng.integers(0, 4, spec.genome_len, dtype=np.int32)
        if prev is not None and spec.homology_fraction > 0:
            # Splice a shared block from the previous species (homology).
            h = int(spec.genome_len * spec.homology_fraction)
            if h > 0:
                src = rng.integers(0, spec.genome_len - h + 1)
                dst = rng.integers(0, spec.genome_len - h + 1)
                g[dst:dst + h] = prev[src:src + h]
        genomes[f"species_{s:02d}"] = g
        prev = g
    return genomes


def mutate(genome: np.ndarray, snp_rate: float, rng: np.random.Generator
           ) -> np.ndarray:
    """Apply i.i.d. substitutions (strain divergence / sequencing error)."""
    if snp_rate <= 0:
        return genome
    g = genome.copy()
    n_mut = rng.binomial(len(g), snp_rate)
    pos = rng.choice(len(g), size=n_mut, replace=False)
    g[pos] = (g[pos] + rng.integers(1, 4, n_mut)) % 4
    return g


def sample_reads(genomes: dict[str, np.ndarray], abundance: np.ndarray,
                 num_reads: int, spec: CommunitySpec,
                 rng: np.random.Generator | None = None
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Draw reads from the community with the given abundance profile.

    Returns:
      tokens:  (num_reads, read_len) int32
      lengths: (num_reads,) int32 (all == read_len)
      truth:   (num_reads,) int32 ground-truth species index
    """
    rng = rng or np.random.default_rng(spec.seed + 1)
    names = list(genomes.keys())
    abundance = np.asarray(abundance, np.float64)
    abundance = abundance / abundance.sum()
    strains = {n: mutate(genomes[n], spec.strain_snp_rate, rng) for n in names}

    truth = rng.choice(len(names), size=num_reads, p=abundance).astype(np.int32)
    tokens = np.empty((num_reads, spec.read_len), np.int32)
    for i, s in enumerate(truth):
        g = strains[names[s]]
        start = rng.integers(0, len(g) - spec.read_len + 1)
        read = g[start:start + spec.read_len]
        tokens[i] = mutate(read, spec.read_error_rate, rng)
    lengths = np.full(num_reads, spec.read_len, np.int32)
    return tokens, lengths, truth


def make_sample(spec: CommunitySpec, num_reads: int,
                present: list[int] | None = None,
                ) -> tuple[dict[str, np.ndarray], np.ndarray, np.ndarray,
                           np.ndarray, np.ndarray]:
    """Convenience: genomes + a food sample where only ``present`` species occur.

    Returns (genomes, tokens, lengths, truth, true_abundance). Absent
    species have zero abundance — the profiler must not report them
    (precision) and must find every present one (recall).
    """
    rng = np.random.default_rng(spec.seed + 2)
    genomes = make_reference_genomes(spec)
    s = spec.num_species
    present = present if present is not None else list(range(0, s, 2))
    ab = np.zeros(s)
    ab[present] = rng.dirichlet(np.ones(len(present))) + 0.05
    ab = ab / ab.sum()
    tokens, lengths, truth = sample_reads(genomes, ab, num_reads, spec, rng)
    return genomes, tokens, lengths, truth, ab
