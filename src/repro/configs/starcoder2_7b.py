"""starcoder2-7b [dense]: 32L d4608 36H (GQA kv=4) d_ff=18432 vocab 49152,
GQA + RoPE, gelu non-GLU MLP. [arXiv:2402.19173]
"""

from repro.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    d_ff=18432,
    vocab=49152,
    attn=AttnConfig(num_heads=36, num_kv_heads=4, head_dim=128,
                    rope_theta=100_000.0),
    act="gelu",
    glu=False,
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=256,
    vocab=256,
    attn=AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16),
    act="gelu",
    glu=False,
    norm="layernorm",
)
