"""paligemma-3b [vlm]: 18L d2048 8H (MQA kv=1, head_dim 256) d_ff=16384
vocab 257216; SigLIP tower stubbed -> 256 patch embeddings prefix with
prefix-LM masking. [arXiv:2407.07726]
"""

from repro.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab=257216,
    attn=AttnConfig(num_heads=8, num_kv_heads=1, head_dim=256),
    act="gelu",
    glu=True,
    tie_embeddings=True,
    vlm_prefix=256,
)

SMOKE = ModelConfig(
    name="paligemma-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab=256,
    attn=AttnConfig(num_heads=4, num_kv_heads=1, head_dim=16),
    act="gelu",
    glu=True,
    tie_embeddings=True,
    vlm_prefix=8,
)
