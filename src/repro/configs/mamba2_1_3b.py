"""mamba2-1.3b [ssm]: 48L d2048 attn-free, SSD with d_state=128,
expand=2, head_dim=64, vocab 50280. [arXiv:2405.21060]
"""

from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    d_ff=0,
    vocab=256,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, chunk=16),
    tie_embeddings=True,
)
