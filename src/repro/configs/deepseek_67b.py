"""deepseek-67b [dense]: 95L d8192 64H (GQA kv=8) d_ff=22016 vocab 102400,
llama architecture (silu GLU, RMSNorm, RoPE). [arXiv:2401.02954]
"""

from repro.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    d_ff=22016,
    vocab=102400,
    attn=AttnConfig(num_heads=64, num_kv_heads=8, head_dim=128),
    act="silu",
    glu=True,
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    d_ff=192,
    vocab=256,
    attn=AttnConfig(num_heads=8, num_kv_heads=2, head_dim=8),
)
