"""whisper-tiny [audio]: 4L enc + 4L dec, d384 6H d_ff=1536 vocab 51865,
enc-dec with stub conv frontend (input_specs provides frame embeddings).
[arXiv:2212.04356; unverified]
"""

from repro.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    n_enc_layers=4,
    d_model=384,
    d_ff=1536,
    vocab=51865,
    attn=AttnConfig(num_heads=6, num_kv_heads=6, head_dim=64),
    act="gelu",
    glu=False,
    norm="layernorm",
    pos="sinusoidal",
    dec_len_train=512,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    d_ff=128,
    vocab=256,
    attn=AttnConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    act="gelu",
    glu=False,
    norm="layernorm",
    pos="sinusoidal",
    dec_len_train=16,
)
