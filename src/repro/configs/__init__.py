"""Architecture registry: --arch <id> -> ModelConfig (+ demeter_hdc).

Each module defines ``CONFIG`` (full assigned config) and ``SMOKE``
(reduced same-family config for CPU tests).
"""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "deepseek_v2_lite",
    "phi35_moe",
    "starcoder2_7b",
    "deepseek_67b",
    "nemotron4_15b",
    "stablelm_3b",
    "whisper_tiny",
    "hymba_1_5b",
    "mamba2_1_3b",
    "paligemma_3b",
)

# External ids (assignment spelling) -> module names.
ALIASES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "starcoder2-7b": "starcoder2_7b",
    "deepseek-67b": "deepseek_67b",
    "nemotron-4-15b": "nemotron4_15b",
    "stablelm-3b": "stablelm_3b",
    "whisper-tiny": "whisper_tiny",
    "hymba-1.5b": "hymba_1_5b",
    "mamba2-1.3b": "mamba2_1_3b",
    "paligemma-3b": "paligemma_3b",
}


def get_config(arch: str, smoke: bool = False):
    name = ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_archs() -> tuple[str, ...]:
    return ARCH_IDS
