"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Four LM shapes per the assignment; ``input_specs`` builds allocation-free
stand-ins for every model input of the step function being lowered:

  train_4k     seq 4,096  x batch 256   -> train_step
  prefill_32k  seq 32,768 x batch 32    -> serve prefill (forward)
  decode_32k   seq 32,768 x batch 128   -> serve decode_step (1 new token)
  long_500k    seq 524,288 x batch 1    -> decode; sub-quadratic archs only

[audio]: seq_len applies to the encoder (stub frame embeddings); decoder
takes dec_len_train tokens for train/prefill shapes.
[vlm]: vlm_prefix stub patch embeddings are part of the sequence budget.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped). Skips recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and cfg.quadratic_attention:
        return False, "pure full-attention arch; 500k decode cache is " \
                      "O(L) per layer for every layer (DESIGN.md skip table)"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's data arguments.

    train  -> {tokens, labels[, enc_embeds | prefix_embeds]}
    prefill-> {tokens[, enc_embeds | prefix_embeds]}
    decode -> {token, cur_pos}  (caches come from cache_specs())
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.param_dtype)
    if shape.kind == "decode":
        return {"token": _sds((b,), jnp.int32),
                "cur_pos": _sds((), jnp.int32)}
    if cfg.family == "audio":
        d = cfg.dec_len_train
        spec = {"enc_embeds": _sds((b, s, cfg.d_model), dt),
                "tokens": _sds((b, d), jnp.int32)}
        if shape.kind == "train":
            spec["labels"] = _sds((b, d), jnp.int32)
        return spec
    if cfg.family == "vlm":
        text = s - cfg.vlm_prefix
        spec = {"prefix_embeds": _sds((b, cfg.vlm_prefix, cfg.d_model), dt),
                "tokens": _sds((b, text), jnp.int32)}
        if shape.kind == "train":
            spec["labels"] = _sds((b, text), jnp.int32)
        return spec
    spec = {"tokens": _sds((b, s), jnp.int32)}
    if shape.kind == "train":
        spec["labels"] = _sds((b, s), jnp.int32)
    return spec


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> list:
    """Decode-cache ShapeDtypeStructs (no allocation) for decode shapes."""
    assert shape.kind == "decode"
    enc_len = shape.seq_len if cfg.family == "audio" else 0
    return jax.eval_shape(
        functools.partial(lm.init_cache, cfg, shape.global_batch,
                          shape.seq_len, enc_len=enc_len))


def param_specs(cfg: ModelConfig, seed: int = 0):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda: lm.init_lm(jax.random.key(seed), cfg))
