"""deepseek-v2-lite-16b [moe]: 27L d2048, MLA kv_lora=512, 64 routed top-6
+ 2 shared experts, d_expert=1408, first layer dense (d_ff=10944).
[arXiv:2405.04434; hf]  (Assignment note "160 routed" belongs to full V2 —
see DESIGN.md §Config discrepancy.)
"""

from repro.config import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    d_ff=1408,
    vocab=102400,
    attn=AttnConfig(kind="mla", num_heads=16, num_kv_heads=16, head_dim=128,
                    kv_lora=512, rope_head_dim=64, v_head_dim=128,
                    rope_theta=10_000.0),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2),
    n_dense_layers=1,
    dense_d_ff=10944,
    act="silu",
    glu=True,
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    d_ff=48,
    vocab=256,
    attn=AttnConfig(kind="mla", num_heads=4, num_kv_heads=4, head_dim=16,
                    kv_lora=32, rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=48, num_shared=1,
                  group_size=64, capacity_factor=4.0),
    n_dense_layers=1,
    dense_d_ff=128,
)
