"""hymba-1.5b [hybrid]: 32L d1600 25H (GQA kv=5) d_ff=5504, parallel
attention+mamba heads, SWA everywhere except 3 global layers,
ssm_state=16. [arXiv:2411.13676]
"""

from repro.config import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab=32001,
    attn=AttnConfig(num_heads=25, num_kv_heads=5, head_dim=64, window=1024),
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64),
    act="silu",
    glu=True,
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    d_ff=128,
    vocab=256,
    attn=AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16, window=8),
    ssm=SSMConfig(d_state=8, expand=2, head_dim=16, chunk=16),
)
