"""nemotron-4-15b [dense]: 32L d6144 48H (GQA kv=8) d_ff=24576
vocab 256000, squared-ReLU MLP (no GLU), LayerNorm, RoPE.
[arXiv:2402.16819; unverified]
"""

from repro.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    d_ff=24576,
    vocab=256000,
    attn=AttnConfig(num_heads=48, num_kv_heads=8, head_dim=128),
    act="relu2",
    glu=False,
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="nemotron-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=256,
    vocab=512,
    attn=AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16),
    act="relu2",
    glu=False,
    norm="layernorm",
)
