"""phi3.5-moe-42b-a6.6b [moe]: 32L d4096 32H (GQA kv=8) d_ff(expert)=6400,
16 experts top-2, vocab 32064. [hf:microsoft/Phi-3.5-MoE-instruct]
"""

from repro.config import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab=32064,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400),
    act="silu",
    glu=True,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    d_ff=96,
    vocab=256,
    attn=AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16),
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=96, group_size=64,
                  capacity_factor=2.0),
)
