"""stablelm-3b [dense]: 32L d2560 32H (kv=32 -> MHA) d_ff=6912 vocab 50304,
partial RoPE (25%). [hf:stabilityai/stablelm-2; unverified]
"""

from repro.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    d_ff=6912,
    vocab=50304,
    attn=AttnConfig(num_heads=32, num_kv_heads=32, head_dim=80,
                    rope_fraction=0.25),
    act="silu",
    glu=True,
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="stablelm-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab=256,
    attn=AttnConfig(num_heads=4, num_kv_heads=4, head_dim=16,
                    rope_fraction=0.25),
    norm="layernorm",
)
