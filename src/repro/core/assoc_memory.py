"""Associative memory (AM): the HD reference database (HD-RefDB).

Demeter step 2 builds one (or a few) *prototype* HD vectors per reference
genome; we use windowed prototypes (one per genome window) because
bundling signal decays as 1/sqrt(#grams) — a handful of window prototypes
per species keeps read/prototype correlation detectable on real genome
sizes while keeping the AM tiny (paper §3.2 "one (or few) prototype HD
vector(s)").

The AM is immutable after build (PCM write-once discipline, paper §5.4);
``RefDB`` is a pytree so the query path jits/shards cleanly.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops, encoder, item_memory
from repro.core.hd_space import HDSpace


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RefDB:
    """HD reference database (the content of Acc-Demeter's AM unit).

    prototypes: ``(S, W)`` packed prototype HD vectors (S = total windows).
    proto_species: ``(S,)`` int32 species index of each prototype.
    genome_lengths: ``(num_species,)`` int32 reference lengths (abundance).
    """
    prototypes: jax.Array
    proto_species: jax.Array
    genome_lengths: jax.Array
    num_species: int = dataclasses.field(metadata=dict(static=True))
    species_names: tuple[str, ...] = dataclasses.field(metadata=dict(static=True))

    @property
    def num_prototypes(self) -> int:
        return self.prototypes.shape[0]

    def memory_bytes(self) -> int:
        """Size of the working data structure (paper Fig. 6 comparison)."""
        return (self.prototypes.size * 4 + self.proto_species.size * 4
                + self.genome_lengths.size * 4)


def window_tokens(tokens: np.ndarray, window: int, stride: int) -> np.ndarray:
    """Slice a genome token array into ``(num_windows, window)`` (padded)."""
    length = len(tokens)
    if length <= window:
        out = np.zeros((1, window), np.int32)
        out[0, :length] = tokens
        return out, np.array([length], np.int32)
    starts = np.arange(0, length - window + 1, stride)
    if starts[-1] + window < length:  # tail window
        starts = np.append(starts, length - window)
    idx = starts[:, None] + np.arange(window)[None, :]
    return tokens[idx].astype(np.int32), np.full(len(starts), window, np.int32)


def build_refdb(genomes: dict[str, np.ndarray], space: HDSpace, *,
                window: int = 8192, stride: int | None = None,
                batch_size: int = 64, encode_fn=None) -> RefDB:
    """Demeter step 2: encode every reference genome into the AM.

    Windows are encoded in batches through the shared N-gram encoder; the
    host loop only orchestrates (all math is jit'd). One prototype per
    window, tagged with its species.

    Args:
      encode_fn: ``(tokens, lengths) -> (B, W)`` packed encoder; defaults
        to the jit'd reference encoder.  Execution backends pass their own
        so the RefDB is built on the same substrate that queries it.
    """
    stride = stride or window

    all_protos: list[np.ndarray] = []
    all_species: list[np.ndarray] = []
    lengths = np.zeros(len(genomes), np.int32)
    names = tuple(genomes.keys())

    if encode_fn is None:
        im = item_memory.make_item_memory(space)
        tie = item_memory.make_tie_break(space)
        encode = jax.jit(lambda t, l: encoder.encode(t, l, im, tie, space))
    else:
        encode = encode_fn
    for s, (name, toks) in enumerate(genomes.items()):
        lengths[s] = len(toks)
        wins, wlens = window_tokens(np.asarray(toks), window, stride)
        for i in range(0, len(wins), batch_size):
            batch, blen = wins[i:i + batch_size], wlens[i:i + batch_size]
            protos = np.asarray(encode(jnp.asarray(batch), jnp.asarray(blen)))
            all_protos.append(protos)
            all_species.append(np.full(len(batch), s, np.int32))

    return RefDB(
        prototypes=jnp.asarray(np.concatenate(all_protos)),
        proto_species=jnp.asarray(np.concatenate(all_species)),
        genome_lengths=jnp.asarray(lengths),
        num_species=len(genomes),
        species_names=names,
    )


def agreement_matmul(queries: jax.Array, prototypes: jax.Array,
                     dim: int) -> jax.Array:
    """Agreement scores via the +-1 matmul identity (MXU formulation).

    ``agreement = D - Ham(Q,P) = (D + Q_hat @ P_hat.T) / 2`` with
    Q_hat = 2Q-1. This is the software twin of ``kernels/am_matmul``; on
    CPU it maps to BLAS, on TPU the Pallas kernel takes over.
    """
    q = (2.0 * bitops.unpack_bits(queries).astype(jnp.float32) - 1.0)
    p = (2.0 * bitops.unpack_bits(prototypes).astype(jnp.float32) - 1.0)
    s = q @ p.T
    return ((dim + s) / 2.0).astype(jnp.int32)


def agreement_packed_chunked(queries: jax.Array, prototypes: jax.Array,
                             dim: int, chunk: int = 128) -> jax.Array:
    """Agreement via packed XOR+popcount, chunked over prototypes.

    Bandwidth-optimal digital formulation (paper Eq. 2); used when the
    prototype set is large and bf16 expansion would not pay off.
    """
    def one_chunk(p_chunk):
        ham = bitops.popcount_words(
            jnp.bitwise_xor(queries[:, None, :], p_chunk[None, :, :]))
        return dim - ham  # (B, chunk)

    s, w = prototypes.shape
    pad = (-s) % chunk
    padded = jnp.pad(prototypes, ((0, pad), (0, 0)))
    chunks = padded.reshape(-1, chunk, w)
    out = jax.lax.map(one_chunk, chunks)           # (nc, B, chunk)
    out = jnp.moveaxis(out, 0, 1).reshape(queries.shape[0], -1)
    return out[:, :s]


def species_scores(agreement: jax.Array, proto_species: jax.Array,
                   num_species: int) -> jax.Array:
    """Max agreement per species over its window prototypes -> (B, S)."""
    return jax.ops.segment_max(
        agreement.T, proto_species, num_segments=num_species,
        indices_are_sorted=True).T
