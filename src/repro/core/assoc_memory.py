"""Associative memory (AM): the HD reference database (HD-RefDB).

Demeter step 2 builds one (or a few) *prototype* HD vectors per reference
genome; we use windowed prototypes (one per genome window) because
bundling signal decays as 1/sqrt(#grams) — a handful of window prototypes
per species keeps read/prototype correlation detectable on real genome
sizes while keeping the AM tiny (paper §3.2 "one (or few) prototype HD
vector(s)").

The AM is immutable after build (PCM write-once discipline, paper §5.4);
``RefDB`` is a pytree so the query path jits/shards cleanly.
"""

from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops, encoder, item_memory
from repro.core.hd_space import HDSpace


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RefDB:
    """HD reference database (the content of Acc-Demeter's AM unit).

    prototypes: ``(S, W)`` packed prototype HD vectors (S = total windows).
    proto_species: ``(S,)`` int32 species index of each prototype.
    genome_lengths: ``(num_species,)`` int32 reference lengths (abundance).
    """
    prototypes: jax.Array
    proto_species: jax.Array
    genome_lengths: jax.Array
    num_species: int = dataclasses.field(metadata=dict(static=True))
    species_names: tuple[str, ...] = dataclasses.field(metadata=dict(static=True))

    @property
    def num_prototypes(self) -> int:
        return self.prototypes.shape[0]

    def memory_bytes(self) -> int:
        """Size of the working data structure (paper Fig. 6 comparison)."""
        return (self.prototypes.size * 4 + self.proto_species.size * 4
                + self.genome_lengths.size * 4)


def window_tokens(tokens: np.ndarray, window: int, stride: int) -> np.ndarray:
    """Slice a genome token array into ``(num_windows, window)`` (padded)."""
    length = len(tokens)
    if length <= window:
        out = np.zeros((1, window), np.int32)
        out[0, :length] = tokens
        return out, np.array([length], np.int32)
    starts = np.arange(0, length - window + 1, stride)
    if starts[-1] + window < length:  # tail window
        starts = np.append(starts, length - window)
    idx = starts[:, None] + np.arange(window)[None, :]
    return tokens[idx].astype(np.int32), np.full(len(starts), window, np.int32)


class RefDBBuilder:
    """Incremental RefDB construction, one reference genome at a time.

    The streaming form of Demeter step 2: callers feed genomes with
    :meth:`add_genome` (each is windowed and encoded immediately, so only
    the finished prototype rows are retained — never two genomes' raw
    windows at once) and :meth:`finish` assembles the immutable
    :class:`RefDB`.  :func:`build_refdb` is a thin loop over this class;
    the on-disk store (:mod:`repro.pipeline.refdb_store`) uses it to build
    and persist genome-by-genome.
    """

    def __init__(self, space: HDSpace, *, window: int = 8192,
                 stride: int | None = None, batch_size: int = 64,
                 encode_fn=None):
        self.space = space
        self.window = window
        self.stride = stride or window
        self.batch_size = batch_size
        if encode_fn is None:
            im = item_memory.make_item_memory(space)
            tie = item_memory.make_tie_break(space)
            encode_fn = jax.jit(
                lambda t, l: encoder.encode(t, l, im, tie, space))
        self._encode = encode_fn
        self._protos: list[np.ndarray] = []
        self._species: list[np.ndarray] = []
        self._lengths: list[int] = []
        self._names: list[str] = []

    def add_genome(self, name: str, tokens: np.ndarray) -> np.ndarray:
        """Window + encode one genome; returns its ``(n_windows, W)`` block.

        Atomic on failure: state is committed only after the whole genome
        encoded, so a raising encode (bad tokens, device OOM) leaves the
        builder exactly as before — the genome can be retried or skipped
        without corrupting ``finish()``'s species bookkeeping.
        """
        if name in self._names:
            raise ValueError(f"genome {name!r} already added")
        wins, wlens = window_tokens(np.asarray(tokens), self.window,
                                    self.stride)
        blocks = []
        for i in range(0, len(wins), self.batch_size):
            batch, blen = wins[i:i + self.batch_size], wlens[i:i + self.batch_size]
            blocks.append(np.asarray(
                self._encode(jnp.asarray(batch), jnp.asarray(blen))))
        block = np.concatenate(blocks)
        self._species.append(np.full(len(block), len(self._names), np.int32))
        self._names.append(name)
        self._lengths.append(len(tokens))
        self._protos.append(block)
        return block

    def finish(self) -> RefDB:
        """Assemble the immutable RefDB from everything added so far."""
        if not self._names:
            raise ValueError("no genomes added")
        return RefDB(
            prototypes=jnp.asarray(np.concatenate(self._protos)),
            proto_species=jnp.asarray(np.concatenate(self._species)),
            genome_lengths=jnp.asarray(np.asarray(self._lengths, np.int32)),
            num_species=len(self._names),
            species_names=tuple(self._names),
        )


def build_refdb(genomes: dict[str, np.ndarray], space: HDSpace, *,
                window: int = 8192, stride: int | None = None,
                batch_size: int = 64, encode_fn=None) -> RefDB:
    """Demeter step 2: encode every reference genome into the AM.

    Windows are encoded in batches through the shared N-gram encoder; the
    host loop only orchestrates (all math is jit'd). One prototype per
    window, tagged with its species.

    Args:
      encode_fn: ``(tokens, lengths) -> (B, W)`` packed encoder; defaults
        to the jit'd reference encoder.  Execution backends pass their own
        so the RefDB is built on the same substrate that queries it.
    """
    builder = RefDBBuilder(space, window=window, stride=stride,
                           batch_size=batch_size, encode_fn=encode_fn)
    for name, toks in genomes.items():
        builder.add_genome(name, toks)
    return builder.finish()


def remove_species(db: RefDB, names) -> RefDB:
    """Drop species (and their prototype rows) from a RefDB.

    The surviving rows are byte-identical to the original build — removal
    never re-encodes — and species ids are remapped to stay contiguous.
    Because ``proto_species`` is non-decreasing and the remap is monotone,
    the invariant :func:`species_scores` relies on survives.  Raises on
    unknown names and on removing every species (an AM must stay
    non-empty; delete the database instead).
    """
    drop = set(names)
    unknown = drop - set(db.species_names)
    if unknown:
        raise KeyError(f"cannot remove unknown species {sorted(unknown)}; "
                       f"database has {list(db.species_names)}")
    if len(drop) == db.num_species:
        raise ValueError("refusing to remove every species (an associative "
                         "memory cannot be empty); delete the database")
    if not drop:
        return db
    keep = np.array([i for i, n in enumerate(db.species_names)
                     if n not in drop], np.int32)
    remap = np.full(db.num_species, -1, np.int32)
    remap[keep] = np.arange(len(keep), dtype=np.int32)
    ps = np.asarray(db.proto_species)
    rows = np.isin(ps, keep)
    return RefDB(
        prototypes=jnp.asarray(np.asarray(db.prototypes)[rows]),
        proto_species=jnp.asarray(remap[ps[rows]]),
        genome_lengths=jnp.asarray(np.asarray(db.genome_lengths)[keep]),
        num_species=len(keep),
        species_names=tuple(db.species_names[i] for i in keep),
    )


def add_species(db: RefDB, addition: RefDB) -> RefDB:
    """Append another RefDB's species to ``db`` (incremental add delta).

    ``addition`` is a streaming build of only the *new* genomes (same
    space/window/stride — the caller guarantees build-config parity; the
    packed widths are checked here).  Appending keeps ``proto_species``
    non-decreasing: new species take ids ``db.num_species ..``.  The
    existing rows are untouched, so queries against surviving species are
    bit-identical before and after the delta.
    """
    if db.prototypes.shape[1] != addition.prototypes.shape[1]:
        raise ValueError(
            f"packed width mismatch: database W={db.prototypes.shape[1]}, "
            f"addition W={addition.prototypes.shape[1]} (different HD "
            f"space/dim — deltas must be built with the database's config)")
    clash = set(db.species_names) & set(addition.species_names)
    if clash:
        raise ValueError(
            f"species already present: {sorted(clash)} (remove them first "
            f"to replace, or rename the additions)")
    return RefDB(
        prototypes=jnp.concatenate(
            [jnp.asarray(db.prototypes), jnp.asarray(addition.prototypes)]),
        proto_species=jnp.concatenate(
            [jnp.asarray(db.proto_species),
             jnp.asarray(addition.proto_species) + db.num_species]),
        genome_lengths=jnp.concatenate(
            [jnp.asarray(db.genome_lengths),
             jnp.asarray(addition.genome_lengths)]),
        num_species=db.num_species + addition.num_species,
        species_names=db.species_names + addition.species_names,
    )


def apply_delta(db: RefDB, *, add: RefDB | None = None,
                remove=()) -> RefDB:
    """One incremental update: remove species, then append new ones.

    Remove-before-add makes an in-place genome refresh a single delta
    (``remove=["x"], add=<rebuilt x>``).  The result is a plain host
    RefDB; callers re-run backend placement (``place_refdb``) when
    serving it.
    """
    out = remove_species(db, remove) if remove else db
    if add is not None:
        out = add_species(out, add)
    return out


def rebinarize_counters(counters: jax.Array | np.ndarray,
                        fallback_bits: jax.Array | np.ndarray) -> jax.Array:
    """Sign-threshold bundling counters back into packed prototypes.

    The inverse of losing the bundling sums at build time: retraining
    passes (:mod:`repro.accel.codesign`) keep integer per-bit counters
    ``(S, dim)`` and re-binarize after each update round.  Positive
    counters become 1-bits, negative become 0-bits, and an exact zero —
    the retrained information cancelled out — falls back to
    ``fallback_bits`` (the naive build's bit), so an untouched prototype
    row packs back byte-identical to the original build.
    """
    c = jnp.asarray(counters)
    bits = jnp.where(c > 0, 1,
                     jnp.where(c < 0, 0,
                               jnp.asarray(fallback_bits).astype(jnp.int32)))
    return bitops.pack_bits(bits)


def agreement_matmul(queries: jax.Array, prototypes: jax.Array,
                     dim: int) -> jax.Array:
    """Agreement scores via the +-1 matmul identity (MXU formulation).

    ``agreement = D - Ham(Q,P) = (D + Q_hat @ P_hat.T) / 2`` with
    Q_hat = 2Q-1. This is the software twin of ``kernels/am_matmul``; on
    CPU it maps to BLAS, on TPU the Pallas kernel takes over.
    """
    q = (2.0 * bitops.unpack_bits(queries).astype(jnp.float32) - 1.0)
    p = (2.0 * bitops.unpack_bits(prototypes).astype(jnp.float32) - 1.0)
    s = q @ p.T
    return ((dim + s) / 2.0).astype(jnp.int32)


def agreement_packed_chunked(queries: jax.Array, prototypes: jax.Array,
                             dim: int, chunk: int = 128) -> jax.Array:
    """Agreement via packed XOR+popcount, chunked over prototypes.

    Bandwidth-optimal digital formulation (paper Eq. 2); used when the
    prototype set is large and bf16 expansion would not pay off.
    """
    def one_chunk(p_chunk):
        ham = bitops.popcount_words(
            jnp.bitwise_xor(queries[:, None, :], p_chunk[None, :, :]))
        return dim - ham  # (B, chunk)

    s, w = prototypes.shape
    pad = (-s) % chunk
    padded = jnp.pad(prototypes, ((0, pad), (0, 0)))
    chunks = padded.reshape(-1, chunk, w)
    out = jax.lax.map(one_chunk, chunks)           # (nc, B, chunk)
    out = jnp.moveaxis(out, 0, 1).reshape(queries.shape[0], -1)
    return out[:, :s]


def species_scores(agreement: jax.Array, proto_species: jax.Array,
                   num_species: int) -> jax.Array:
    """Max agreement per species over its window prototypes -> (B, S).

    Works on any *subset* of the prototypes (one device's shard): a
    species with no prototype in the subset comes back as the dtype's
    minimum (the identity of the max-merge across shards), and indices
    ``>= num_species`` (mesh-padding rows) are dropped by segment_max.
    ``proto_species`` must be non-decreasing — true for full builds and
    for any contiguous shard of one.
    """
    return jax.ops.segment_max(
        agreement.T, proto_species, num_segments=num_species,
        indices_are_sorted=True).T
