"""Demeter step 5: species-level relative abundance estimation.

Two-phase scheme from paper §3.5:

1. uniquely-mapped reads are assigned to their species directly;
2. multi-mapped reads are split across their candidate species
   proportionally to ``unique_count[s] / genome_length[s]`` (the unique-
   coverage rate), falling back to a uniform split when no candidate has
   unique support.

This step runs on the host CPU in Acc-Demeter (paper §5.5) — here it is a
small jit'd function; the heavy inputs (hit masks) stream from step 4.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classifier


def split_multi_counts(unique_counts: np.ndarray, multi_hits: np.ndarray,
                       genome_lengths: np.ndarray) -> np.ndarray:
    """Phase 2 on the host, exactly: split multi-mapped reads by unique
    coverage rate.

    The single source of truth for the streaming pipeline's end-of-run
    split — :class:`~repro.pipeline.report.ProfileAccumulator` calls
    this with the *global* unique counts so the result never depends on
    how the stream was batched.  Pure float64 numpy: bit-stable across
    backends and devices, unlike the jit'd :func:`estimate` (which
    remains the one-shot float32 device path).

    Args:
      unique_counts: ``(S,)`` int unique-read counts (phase 1, global).
      multi_hits: ``(R, S)`` bool hit mask of the multi-mapped reads.
      genome_lengths: ``(S,)`` reference genome lengths.

    Returns:
      ``(S,)`` float64 fractional multi-mapped mass per species.
    """
    lens = np.maximum(np.asarray(genome_lengths, np.float64), 1.0)
    rate = np.asarray(unique_counts, np.float64) / lens
    m = np.asarray(multi_hits, bool)
    w = m * rate[None, :]
    mass = w.sum(axis=-1, keepdims=True)
    # Fallback: uniform split over hit species when no unique support.
    uniform = m / np.maximum(m.sum(axis=-1, keepdims=True), 1)
    w = np.where(mass > 0, w / np.maximum(mass, 1e-30), uniform)
    return w.sum(axis=0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AbundanceResult:
    abundance: jax.Array        # (S,) float32 — relative abundance (sums to 1 over mapped)
    unique_counts: jax.Array    # (S,) int32
    multi_counts: jax.Array     # (S,) float32 — fractional multi-mapped mass
    unmapped_fraction: jax.Array  # () float32
    multi_fraction: jax.Array     # () float32


@jax.jit
def estimate(hits: jax.Array, category: jax.Array,
             genome_lengths: jax.Array) -> AbundanceResult:
    """Estimate relative abundance from per-read hit masks.

    Args:
      hits: ``(R, S)`` bool hit mask from step 4.
      category: ``(R,)`` int32 read category (UNMAPPED/UNIQUE/MULTI).
      genome_lengths: ``(S,)`` int32 reference genome lengths.
    """
    r = hits.shape[0]
    unique = (category == classifier.UNIQUE)[:, None] & hits
    unique_counts = unique.sum(axis=0).astype(jnp.int32)

    # Phase 2: proportional split of multi-mapped reads.
    rate = unique_counts.astype(jnp.float32) / jnp.maximum(
        genome_lengths.astype(jnp.float32), 1.0)
    multi_rows = (category == classifier.MULTI)[:, None] & hits
    w = multi_rows.astype(jnp.float32) * rate[None, :]
    row_mass = w.sum(axis=-1, keepdims=True)
    # Fallback: uniform split over hit species when no unique support.
    uniform = multi_rows.astype(jnp.float32)
    uniform = uniform / jnp.maximum(uniform.sum(axis=-1, keepdims=True), 1.0)
    w = jnp.where(row_mass > 0, w / jnp.maximum(row_mass, 1e-30), uniform)
    multi_counts = w.sum(axis=0)

    mapped = unique_counts.astype(jnp.float32) + multi_counts
    total_mapped = jnp.maximum(mapped.sum(), 1e-30)
    return AbundanceResult(
        abundance=mapped / total_mapped,
        unique_counts=unique_counts,
        multi_counts=multi_counts,
        unmapped_fraction=(category == classifier.UNMAPPED).mean(),
        multi_fraction=(category == classifier.MULTI).mean(),
    )


def merge(results: list[AbundanceResult],
          genome_lengths: jax.Array) -> AbundanceResult:
    """Merge per-batch abundance partials (streamed profiling).

    Unique/multi counts are additive; the proportional split is recomputed
    implicitly because each batch already applied its own weights — for
    exact streaming semantics, callers should accumulate hit masks and call
    :func:`estimate` once, which `profiler.Demeter.profile` does by
    accumulating count vectors instead (cheap) and only re-splitting multi
    mass at the end.
    """
    unique = sum(r.unique_counts for r in results)
    multi = sum(r.multi_counts for r in results)
    mapped = unique.astype(jnp.float32) + multi
    total = jnp.maximum(mapped.sum(), 1e-30)
    n = len(results)
    return AbundanceResult(
        abundance=mapped / total,
        unique_counts=unique,
        multi_counts=multi,
        unmapped_fraction=sum(r.unmapped_fraction for r in results) / n,
        multi_fraction=sum(r.multi_fraction for r in results) / n,
    )
