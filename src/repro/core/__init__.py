"""Demeter core: the paper's contribution as composable JAX modules.

Five-step pipeline (paper Fig. 1):
  1. HD space        -> hd_space.HDSpace
  2. HD-RefDB build  -> assoc_memory.build_refdb
  3. read conversion -> encoder.encode
  4. classification  -> classifier.classify / classifier.from_agreement
  5. abundance       -> abundance.estimate

Packed-bit substrate in bitops; the TPU-accelerated twins of encode and
classify live in repro.kernels.

These are the *algorithmic* building blocks.  The public entry point is
the unified API in :mod:`repro.pipeline` — ``ProfilerConfig`` + the
backend registry + ``ReadSource`` + ``ProfilingSession`` — which selects
among the substrates by name (see docs/API.md).  The retired ``Demeter``
and ``batch_reads`` shims now raise with a pointer to that API.
"""

from repro.core.hd_space import HDSpace
from repro.core.assoc_memory import RefDB, RefDBBuilder, build_refdb
from repro.core.classifier import (ReadClassification, classify,
                                   from_agreement, from_scores, merge_scores,
                                   partial_scores, UNMAPPED, UNIQUE, MULTI)
from repro.core.abundance import AbundanceResult, estimate
from repro.core.profiler import Demeter, ProfileReport, batch_reads

__all__ = [
    "HDSpace", "RefDB", "RefDBBuilder", "build_refdb", "ReadClassification",
    "classify", "from_agreement", "from_scores", "merge_scores",
    "partial_scores", "UNMAPPED", "UNIQUE", "MULTI", "AbundanceResult",
    "estimate", "Demeter", "ProfileReport", "batch_reads",
]
