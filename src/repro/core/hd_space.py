"""Step 1 of Demeter: definition of the hyperdimensional space.

The paper fixes the HD space in four stages (dimension+sparsity, atomic
vectors, encoding mechanism, similarity metric+threshold).  ``HDSpace`` is
the immutable record of those choices; everything downstream (encoder,
associative memory, classifier, kernels) takes it as input, so a profile
run is reproducible from the config alone.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Literal

from repro.core import bitops

SimilarityMetric = Literal["hamming", "dot"]
Encoding = Literal["ngram"]


@dataclasses.dataclass(frozen=True)
class HDSpace:
    """Immutable HD-space configuration (paper Fig. 1, step 1).

    Attributes:
      dim: HD dimensionality D. The paper's sweet spot is 40,000; we default
        to 40,960 (= 1280 uint32 words, 128-lane aligned) for TPU layouts.
      ngram: N of the N-gram encoder (k-mer length in DNA terms).
      alphabet_size: number of atomic item-memory vectors (4 for DNA).
      density: expected bit density of atomic vectors (0.5 = paper's DDR).
      metric: similarity metric for step 4.
      z_threshold: classification threshold in standard deviations above
        the random-agreement mean D/2 (sigma = sqrt(D)/2 for hamming
        agreement between random vectors). Using sigma units makes T
        transferable across D; the absolute paper-style threshold is
        ``threshold_bits``.
      seed: base PRNG seed; item memory and tie-break vectors derive
        deterministically from it.
    """

    dim: int = 40960
    ngram: int = 16
    alphabet_size: int = 4
    density: float = 0.5
    encoding: Encoding = "ngram"
    metric: SimilarityMetric = "hamming"
    z_threshold: float = 4.0
    seed: int = 0x5EED

    def __post_init__(self) -> None:
        bitops.num_words(self.dim)  # validates dim % 32 == 0
        if self.ngram < 1:
            raise ValueError("ngram must be >= 1")
        if self.ngram > self.num_words:
            raise ValueError(
                f"ngram={self.ngram} exceeds the number of words {self.num_words}; "
                "the word-roll permutation would alias")
        if not 0.0 < self.density < 1.0:
            raise ValueError("density must be in (0, 1)")

    @property
    def num_words(self) -> int:
        return bitops.num_words(self.dim)

    @property
    def mean_agreement(self) -> float:
        """Expected agreement (matching bits) of two random HD vectors."""
        return self.dim / 2.0

    @property
    def sigma_agreement(self) -> float:
        """Std-dev of the agreement between two random HD vectors."""
        return (self.dim ** 0.5) / 2.0

    @property
    def threshold_bits(self) -> float:
        """Absolute agreement threshold T (paper Eq. 2) implied by z_threshold."""
        return self.mean_agreement + self.z_threshold * self.sigma_agreement

    def fingerprint(self) -> str:
        """Stable hash identifying the space (used to key RefDB artifacts)."""
        payload = json.dumps(dataclasses.asdict(self), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]
