"""Packed-bit utilities for binary hyperdimensional vectors.

A binary HD vector of dimension ``D`` (``D % 32 == 0``) is stored as a
``uint32`` array of ``W = D // 32`` words, LSB-first within each word:
bit ``d`` of the HD vector lives at ``words[d // 32] >> (d % 32) & 1``.

The HDC permutation ``rho`` (the paper's free flip-flop shift) is realized
as a rotation by whole 32-bit words — a pure relayout (``jnp.roll`` on the
word axis), free on TPU. See DESIGN.md §2 for why a word-roll is an
equally valid HDC permutation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32

# (1, 2, 4, ..., 2**31) used to pack/unpack LSB-first.
_BIT_WEIGHTS = (1 << np.arange(WORD_BITS, dtype=np.uint64)).astype(np.uint32)


def num_words(dim: int) -> int:
    """Number of uint32 words holding a ``dim``-bit HD vector."""
    if dim % WORD_BITS != 0:
        raise ValueError(f"HD dimension must be a multiple of {WORD_BITS}, got {dim}")
    return dim // WORD_BITS


def pad_to_multiple(x: jax.Array, axis: int, multiple: int,
                    fill=0) -> jax.Array:
    """Pad ``x`` along ``axis`` up to the next multiple of ``multiple``.

    Shared by the Pallas wrappers (block alignment, via
    :mod:`repro.kernels.ops`), the accel crossbar tiling
    (:mod:`repro.accel.crossbar`), and the prototype-axis sharding
    (:mod:`repro.pipeline.sharded`).  The default zero fill is inert to
    downstream math; sharding passes ``fill=num_species`` for the species
    tags so the segment reduction drops padding rows.
    """
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack ``(..., D)`` {0,1} bits into ``(..., D//32)`` uint32 words."""
    d = bits.shape[-1]
    w = num_words(d)
    grouped = bits.astype(jnp.uint32).reshape(*bits.shape[:-1], w, WORD_BITS)
    weights = jnp.asarray(_BIT_WEIGHTS, dtype=jnp.uint32)
    return (grouped * weights).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array) -> jax.Array:
    """Unpack ``(..., W)`` uint32 words into ``(..., W*32)`` uint8 bits."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS).astype(jnp.uint8)


def popcount_words(words: jax.Array) -> jax.Array:
    """Total number of set bits along the trailing word axis -> int32."""
    return jnp.bitwise_count(words).astype(jnp.int32).sum(axis=-1)


def rho(words: jax.Array, k: int = 1) -> jax.Array:
    """Apply the HDC permutation ``rho**k`` (rotate by ``k`` words).

    Equivalent to ``jnp.roll(bits, 32 * k)`` on the unpacked bit vector.
    """
    return jnp.roll(words, k, axis=-1)


def hamming_packed(a: jax.Array, b: jax.Array) -> jax.Array:
    """Hamming distance between packed vectors, broadcasting leading dims."""
    return popcount_words(jnp.bitwise_xor(a, b))


def agreement_packed(a: jax.Array, b: jax.Array, dim: int) -> jax.Array:
    """Number of agreeing bit positions (the paper's XNOR+popcount, Eq. 2)."""
    return dim - hamming_packed(a, b)


def random_packed(key: jax.Array, shape: tuple[int, ...], dim: int,
                  density: float = 0.5) -> jax.Array:
    """Random packed HD vectors with the given bit density.

    ``density == 0.5`` (the paper's dense distributed representation) uses
    raw PRNG words; other densities threshold per-bit uniforms and pack.
    """
    w = num_words(dim)
    if density == 0.5:
        return jax.random.bits(key, shape + (w,), dtype=jnp.uint32)
    bits = (jax.random.uniform(key, shape + (dim,)) < density).astype(jnp.uint8)
    return pack_bits(bits)
