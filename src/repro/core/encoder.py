"""N-gram HD encoder: binding (XOR + permutation) and bundling (majority).

Implements paper Eq. 1:

    gram_i = B[c_i]  XOR  rho(B[c_{i+1}])  XOR ... XOR  rho^{N-1}(B[c_{i+N-1}])

followed by bundling: per-bit counters over all grams of a sequence, then
a majority threshold (ties broken by a fixed random vector).

Two formulations are provided, both exact:

* ``encode_grams`` — gather-based, materializes all grams; used for short
  reads and as the oracle for the Pallas kernel.
* ``bundle_counts`` — rolling-gram recurrence
  ``gram_{i+1} = rho^-1(gram_i ^ B[c_i]) ^ rho^{N-1}(B[c_{i+N}])``
  inside a ``lax.fori_loop`` — O(1) work per position independent of N and
  O(B*D) memory; this is the software form of Acc-Demeter's flip-flop
  pipeline (DESIGN.md §2).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitops, item_memory
from repro.core.hd_space import HDSpace


def num_grams(seq_len: int, n: int) -> int:
    return max(seq_len - n + 1, 0)


def encode_grams(tokens: jax.Array, im_rolled: jax.Array) -> jax.Array:
    """All n-gram HD vectors of ``tokens``.

    Args:
      tokens: ``(..., L)`` int32 symbol ids in [0, alphabet).
      im_rolled: ``(N, alphabet, W)`` from :func:`item_memory.rolled`.

    Returns:
      ``(..., L-N+1, W)`` packed gram vectors.
    """
    n = im_rolled.shape[0]
    length = tokens.shape[-1]
    g = num_grams(length, n)
    acc = im_rolled[0][tokens[..., 0:g]]
    for j in range(1, n):
        acc = jnp.bitwise_xor(acc, im_rolled[j][tokens[..., j:j + g]])
    return acc


def _first_gram(tokens: jax.Array, im_rolled: jax.Array) -> jax.Array:
    """gram_0 for the rolling recurrence: XOR_j rho^j(B[c_j])."""
    n = im_rolled.shape[0]
    acc = im_rolled[0][tokens[..., 0]]
    for j in range(1, n):
        acc = jnp.bitwise_xor(acc, im_rolled[j][tokens[..., j]])
    return acc


@partial(jax.jit, static_argnames=("n", "dim"))
def bundle_counts(tokens: jax.Array, lengths: jax.Array, im: jax.Array,
                  im_last: jax.Array, *, n: int, dim: int
                  ) -> tuple[jax.Array, jax.Array]:
    """Per-bit bundling counters over all valid grams of each sequence.

    Args:
      tokens: ``(B, L)`` int32 padded symbol ids.
      lengths: ``(B,)`` int32 true sequence lengths (<= L).
      im: ``(alphabet, W)`` packed item memory.
      im_last: ``rho^{N-1}(im)``, i.e. ``item_memory.rolled(im, n)[n-1]``.
      n: n-gram size.
      dim: HD dimension D.

    Returns:
      counts: ``(B, D)`` int32 per-bit counters.
      m: ``(B,)`` int32 number of valid grams per sequence.
    """
    b, length = tokens.shape
    g = num_grams(length, n)
    m = jnp.maximum(lengths - n + 1, 0).astype(jnp.int32)
    if g == 0:
        return jnp.zeros((b, dim), jnp.int32), m

    im_rolled = item_memory.rolled(im, n)
    gram0 = _first_gram(tokens, im_rolled)
    counts0 = jnp.zeros((b, dim), jnp.int32)

    def body(i, carry):
        gram, counts = carry
        valid = (i < m)[:, None]
        counts = counts + jnp.where(valid, bitops.unpack_bits(gram), 0)
        # gram_{i+1} = rho^-1(gram_i ^ B[c_i]) ^ rho^{N-1}(B[c_{i+n}])
        nxt_tok = tokens[:, jnp.minimum(i + n, length - 1)]
        gram = jnp.bitwise_xor(
            bitops.rho(jnp.bitwise_xor(gram, im[tokens[:, i]]), -1),
            im_last[nxt_tok])
        return gram, counts

    _, counts = jax.lax.fori_loop(0, g, body, (gram0, counts0))
    return counts, m


def binarize_majority(counts: jax.Array, m: jax.Array,
                      tie_break: jax.Array) -> jax.Array:
    """Majority threshold over bundling counters -> packed HD vector.

    bit = 1 if 2*count > m; exact ties (even m) take the tie-break bit.
    """
    tie_bits = bitops.unpack_bits(tie_break)
    twice = 2 * counts
    m_col = m[..., None]
    bits = jnp.where(twice == m_col, tie_bits, (twice > m_col).astype(jnp.uint8))
    return bitops.pack_bits(bits)


def encode(tokens: jax.Array, lengths: jax.Array, im: jax.Array,
           tie_break: jax.Array, space: HDSpace) -> jax.Array:
    """Full encode of a batch of sequences -> ``(B, W)`` packed HD vectors.

    This is Demeter step 3 (read conversion) and the inner loop of step 2
    (reference construction runs it over genome windows).
    """
    im_last = bitops.rho(im, space.ngram - 1)
    counts, m = bundle_counts(tokens, lengths, im, im_last,
                              n=space.ngram, dim=space.dim)
    return binarize_majority(counts, m, tie_break)
