"""Legacy profiler entry point — a deprecation shim over `repro.pipeline`.

The five-step Demeter pipeline is now driven through the unified API in
:mod:`repro.pipeline`:

  * :class:`repro.pipeline.ProfilerConfig` — one frozen record of the run
    (HD space, windowing, batching, backend name).
  * the backend registry — ``reference`` / ``reference_packed`` /
    ``pallas_matmul`` / ``pallas_packed`` replace the old
    ``use_kernels`` / ``packed_path`` boolean switches.
  * :class:`repro.pipeline.ReadSource` — streaming read input, replacing
    hand-rolled ``batch_reads`` loops.
  * :class:`repro.pipeline.ProfilingSession` — the facade running
    steps 2-5.

:class:`Demeter` remains for existing callers and delegates everything to
a :class:`~repro.pipeline.session.ProfilingSession`; it emits a
``DeprecationWarning`` on construction.  ``ProfileReport`` is re-exported
from its new home in :mod:`repro.pipeline.report`.  See ``docs/API.md``
for the migration table.
"""

from __future__ import annotations

import warnings
from typing import Iterable, Iterator

import jax
import numpy as np

from repro.core.assoc_memory import RefDB
from repro.core.hd_space import HDSpace
from repro.pipeline.report import ProfileReport  # noqa: F401  (re-export)


class Demeter:
    """Deprecated facade; use :class:`repro.pipeline.ProfilingSession`.

    The legacy boolean switches map onto named backends:

      ``Demeter(space)``                        -> ``backend="reference"``
      ``Demeter(space, packed_path=True)``      -> ``backend="reference_packed"``
      ``Demeter(space, use_kernels=True)``      -> ``backend="pallas_matmul"``
    """

    def __init__(self, space: HDSpace, *, window: int = 8192,
                 stride: int | None = None, batch_size: int = 256,
                 packed_path: bool = False, use_kernels: bool = False):
        warnings.warn(
            "Demeter is deprecated; use repro.pipeline.ProfilingSession with "
            "a ProfilerConfig naming a backend (see docs/API.md)",
            DeprecationWarning, stacklevel=2)
        from repro.pipeline import ProfilerConfig, ProfilingSession
        if use_kernels:
            backend = "pallas_matmul"
        elif packed_path:
            backend = "reference_packed"
        else:
            backend = "reference"
        self._session = ProfilingSession(ProfilerConfig(
            space=space, window=window, stride=stride,
            batch_size=batch_size, backend=backend))

    @property
    def space(self) -> HDSpace:
        return self._session.space

    @property
    def window(self) -> int:
        return self._session.config.window

    @property
    def stride(self) -> int:
        return self._session.config.effective_stride

    @property
    def batch_size(self) -> int:
        return self._session.config.batch_size

    # -- Step 2 ------------------------------------------------------------
    def build_refdb(self, genomes: dict[str, np.ndarray]) -> RefDB:
        return self._session.build_refdb(genomes)

    # -- Step 3 ------------------------------------------------------------
    def encode_reads(self, tokens: jax.Array, lengths: jax.Array) -> jax.Array:
        """Convert a read batch ``(B, L)`` into query HD vectors ``(B, W)``."""
        return self._session.encode_reads(tokens, lengths)

    # -- Step 4 ------------------------------------------------------------
    def classify_batch(self, refdb: RefDB, queries: jax.Array):
        return self._session.classify_queries(queries, refdb)

    # -- Steps 3+4+5 streamed ----------------------------------------------
    def profile(self, refdb: RefDB,
                read_batches: Iterable[tuple[np.ndarray, np.ndarray]]
                ) -> ProfileReport:
        """Profile a food sample given an iterator of (tokens, lengths) batches."""
        return self._session.profile(read_batches, refdb=refdb)


def batch_reads(tokens: np.ndarray, lengths: np.ndarray,
                batch_size: int) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield fixed-size (padded) read batches from a read set.

    Deprecated alongside :class:`Demeter`: new code streams through a
    :class:`repro.pipeline.ReadSource` instead.
    """
    n = len(tokens)
    for i in range(0, n, batch_size):
        t, l = tokens[i:i + batch_size], lengths[i:i + batch_size]
        if len(t) < batch_size:  # pad the tail batch to a stable shape
            pad = batch_size - len(t)
            t = np.concatenate([t, np.zeros((pad,) + t.shape[1:], t.dtype)])
            l = np.concatenate([l, np.zeros(pad, l.dtype)])
            yield t, l
            return
        yield t, l
