"""Retired legacy profiler entry point (migration stubs only).

The five-step Demeter pipeline is driven through the unified API in
:mod:`repro.pipeline`:

  * :class:`repro.pipeline.ProfilerConfig` — one frozen record of the run
    (HD space, windowing, batching, backend name + options).
  * the backend registry — ``reference`` / ``reference_packed`` /
    ``pallas_matmul`` / ``pallas_packed`` / ``pallas_fused`` /
    ``pcm_sim`` / ``racetrack_sim`` / ``sharded`` replace the old
    ``use_kernels`` / ``packed_path`` boolean switches.
  * :class:`repro.pipeline.ReadSource` — streaming read input, replacing
    hand-rolled ``batch_reads`` loops.
  * :class:`repro.pipeline.ProfilingSession` — the facade running
    steps 2-5.

``Demeter`` spent its deprecation period as a delegating shim emitting a
``DeprecationWarning``; it is now retired.  Constructing it (or calling
:func:`batch_reads`) raises with a pointer to the migration table in
``docs/API.md``.  ``ProfileReport`` is still re-exported from its real
home in :mod:`repro.pipeline.report` for old import paths.
"""

from __future__ import annotations

from repro.pipeline.report import ProfileReport  # noqa: F401  (re-export)

_MIGRATION = (
    "is retired; use repro.pipeline.ProfilingSession with a ProfilerConfig "
    "naming a backend, and stream reads through a repro.pipeline.ReadSource "
    "(ArraySource / FastqSource).  Flag mapping: Demeter(space) -> "
    "backend='reference', packed_path=True -> 'reference_packed', "
    "use_kernels=True -> 'pallas_matmul'.  See the migration table in "
    "docs/API.md.")


class Demeter:
    """Retired facade; see the migration table in ``docs/API.md``."""

    def __init__(self, *args, **kwargs):
        raise RuntimeError(f"repro.core.Demeter {_MIGRATION}")


def batch_reads(*args, **kwargs):
    """Retired batching helper; stream through a ``ReadSource`` instead."""
    raise RuntimeError(f"repro.core.batch_reads {_MIGRATION}")
