"""The five-step Demeter pipeline (paper Fig. 1), orchestrated.

Step 1  define HD space            -> :class:`repro.core.hd_space.HDSpace`
Step 2  build HD-RefDB             -> :func:`build_refdb`
Step 3  read conversion            -> :meth:`Demeter.encode_reads`
Step 4  multi-species classify     -> :meth:`Demeter.classify_batch`
Step 5  abundance estimation       -> :meth:`Demeter.profile`

Steps 3+4 stream batch-by-batch (the paper pipelines them in hardware; we
rely on XLA async dispatch to overlap the encode of batch i+1 with the
classification of batch i).  Step 5 is exact-streaming: unique counts
accumulate online, multi-read hit masks are retained compactly and split
once at the end with the *global* unique-coverage rates.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abundance, assoc_memory, classifier, encoder, item_memory
from repro.core.assoc_memory import RefDB, build_refdb
from repro.core.hd_space import HDSpace


@dataclasses.dataclass(frozen=True)
class ProfileReport:
    """Final output of a profiling run."""
    species_names: tuple[str, ...]
    abundance: np.ndarray          # (S,) relative abundance over mapped reads
    unique_counts: np.ndarray      # (S,)
    multi_counts: np.ndarray       # (S,) fractional
    total_reads: int
    unmapped_reads: int
    multi_reads: int

    def top(self, k: int = 10) -> list[tuple[str, float]]:
        order = np.argsort(-self.abundance)[:k]
        return [(self.species_names[i], float(self.abundance[i])) for i in order]


class Demeter:
    """Platform-independent Demeter profiler (the paper's framework).

    The same object backs the pure-JAX CPU path, the Pallas TPU kernels
    (``use_kernels=True`` routes encode/similarity through
    ``repro.kernels.ops``) and the distributed pjit path
    (``repro.launch.profile_run``).
    """

    def __init__(self, space: HDSpace, *, window: int = 8192,
                 stride: int | None = None, batch_size: int = 256,
                 packed_path: bool = False, use_kernels: bool = False):
        self.space = space
        self.window = window
        self.stride = stride or window
        self.batch_size = batch_size
        self.packed_path = packed_path
        self.use_kernels = use_kernels
        self.im = item_memory.make_item_memory(space)
        self.tie = item_memory.make_tie_break(space)
        self._encode = jax.jit(self._encode_impl)
        self._classify = jax.jit(self._classify_impl)

    # -- Step 2 ------------------------------------------------------------
    def build_refdb(self, genomes: dict[str, np.ndarray]) -> RefDB:
        return build_refdb(genomes, self.space, window=self.window,
                           stride=self.stride, batch_size=self.batch_size)

    # -- Step 3 ------------------------------------------------------------
    def _encode_impl(self, tokens: jax.Array, lengths: jax.Array) -> jax.Array:
        if self.use_kernels:
            from repro.kernels import ops
            return ops.hdc_encode(tokens, lengths, self.im, self.tie, self.space)
        return encoder.encode(tokens, lengths, self.im, self.tie, self.space)

    def encode_reads(self, tokens: jax.Array, lengths: jax.Array) -> jax.Array:
        """Convert a read batch ``(B, L)`` into query HD vectors ``(B, W)``."""
        return self._encode(tokens, lengths)

    # -- Step 4 ------------------------------------------------------------
    def _classify_impl(self, queries: jax.Array, refdb: RefDB
                       ) -> classifier.ReadClassification:
        if self.use_kernels:
            from repro.kernels import ops
            agree = ops.am_agreement(queries, refdb.prototypes, self.space.dim)
            scores = assoc_memory.species_scores(
                agree, refdb.proto_species, refdb.num_species)
            hits = scores >= jnp.asarray(self.space.threshold_bits, scores.dtype)
            n = hits.sum(axis=-1)
            cat = jnp.where(n == 0, classifier.UNMAPPED,
                            jnp.where(n == 1, classifier.UNIQUE, classifier.MULTI))
            return classifier.ReadClassification(
                hits=hits, scores=scores, category=cat.astype(jnp.int32))
        return classifier.classify(queries, refdb, self.space,
                                   packed_path=self.packed_path)

    def classify_batch(self, refdb: RefDB, queries: jax.Array
                       ) -> classifier.ReadClassification:
        return self._classify(queries, refdb)

    # -- Steps 3+4+5 streamed ----------------------------------------------
    def profile(self, refdb: RefDB,
                read_batches: Iterable[tuple[np.ndarray, np.ndarray]]
                ) -> ProfileReport:
        """Profile a food sample given an iterator of (tokens, lengths) batches."""
        s = refdb.num_species
        unique_counts = np.zeros(s, np.int64)
        multi_hit_rows: list[np.ndarray] = []
        total = unmapped = multi_n = 0

        for tokens, lengths in read_batches:
            q = self.encode_reads(jnp.asarray(tokens), jnp.asarray(lengths))
            res = self.classify_batch(refdb, q)
            hits = np.asarray(res.hits)
            cat = np.asarray(res.category)
            total += len(cat)
            unmapped += int((cat == classifier.UNMAPPED).sum())
            uniq = hits[cat == classifier.UNIQUE]
            if len(uniq):
                unique_counts += uniq.sum(axis=0)
            m = hits[cat == classifier.MULTI]
            if len(m):
                multi_hit_rows.append(np.packbits(m, axis=-1))
                multi_n += len(m)

        # Step 5 with global unique-coverage rates.
        lens = np.maximum(np.asarray(refdb.genome_lengths, np.float64), 1.0)
        rate = unique_counts / lens
        multi_counts = np.zeros(s, np.float64)
        for packed in multi_hit_rows:
            m = np.unpackbits(packed, axis=-1, count=s).astype(bool)
            w = m * rate[None, :]
            mass = w.sum(axis=-1, keepdims=True)
            uniform = m / np.maximum(m.sum(axis=-1, keepdims=True), 1)
            w = np.where(mass > 0, w / np.maximum(mass, 1e-30), uniform)
            multi_counts += w.sum(axis=0)

        mapped = unique_counts + multi_counts
        denom = max(mapped.sum(), 1e-30)
        return ProfileReport(
            species_names=refdb.species_names,
            abundance=(mapped / denom).astype(np.float64),
            unique_counts=unique_counts.astype(np.int64),
            multi_counts=multi_counts,
            total_reads=total,
            unmapped_reads=unmapped,
            multi_reads=multi_n,
        )


def batch_reads(tokens: np.ndarray, lengths: np.ndarray,
                batch_size: int) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield fixed-size (padded) read batches from a read set."""
    n = len(tokens)
    for i in range(0, n, batch_size):
        t, l = tokens[i:i + batch_size], lengths[i:i + batch_size]
        if len(t) < batch_size:  # pad the tail batch to a stable shape
            pad = batch_size - len(t)
            t = np.concatenate([t, np.zeros((pad,) + t.shape[1:], t.dtype)])
            l = np.concatenate([l, np.zeros(pad, l.dtype)])
            yield t, l
            return
        yield t, l
