"""Item memory (IM): the atomic HD vectors for the genome alphabet.

Mirrors Acc-Demeter's IM unit (paper §5.2): generated once per HD space,
then read-only.  On TPU the IM is tiny (alphabet_size x W words, ~20 KB at
D=40,960) and lives in VMEM replicated per core, playing the role of the
row-major PCM array that can be read in one cycle.

``rolled`` precomputes ``rho**j(IM)`` for j in [0, N) so the Pallas encoder
kernel can treat every word-block independently (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitops
from repro.core.hd_space import HDSpace


def make_item_memory(space: HDSpace) -> jax.Array:
    """Generate the ``(alphabet_size, W)`` packed atomic HD vectors."""
    key = jax.random.key(space.seed)
    return bitops.random_packed(
        key, (space.alphabet_size,), space.dim, space.density)


def make_tie_break(space: HDSpace) -> jax.Array:
    """Fixed random packed vector used to break majority ties (even M)."""
    key = jax.random.key(space.seed ^ 0x7EB4EA4)
    return bitops.random_packed(key, (), space.dim, 0.5)


def rolled(im: jax.Array, n: int) -> jax.Array:
    """Stack ``rho**j(im)`` for j in [0, n) -> ``(n, alphabet, W)``.

    The j-th character of an n-gram is bound through ``rho**j`` (paper
    Eq. 1); precomputing the rolled copies turns every gram into a pure
    gather+XOR with no cross-word traffic inside kernels.
    """
    return jnp.stack([bitops.rho(im, j) for j in range(n)], axis=0)
