"""Demeter step 4: multi-species classification per read.

Unlike prior HDC systems (winner-take-all), a read may match one, many, or
*no* species (paper §3.4) — the classifier returns the full hit mask plus
a category per read:

    0 = unmapped   (no species above threshold)
    1 = unique     (exactly one)
    2 = multi      (more than one)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import assoc_memory
from repro.core.assoc_memory import RefDB
from repro.core.hd_space import HDSpace

UNMAPPED, UNIQUE, MULTI = 0, 1, 2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ReadClassification:
    """Per-read classification outcome for a batch of R reads, S species."""
    hits: jax.Array        # (R, S) bool — agreement >= T
    scores: jax.Array      # (R, S) int32 — best agreement per species
    category: jax.Array    # (R,) int32 — UNMAPPED / UNIQUE / MULTI

    @property
    def num_hits(self) -> jax.Array:
        return self.hits.sum(axis=-1)


def from_agreement(agreement: jax.Array, proto_species: jax.Array,
                   num_species: int, threshold_bits: float
                   ) -> ReadClassification:
    """Classify from a precomputed ``(R, S_protos)`` agreement matrix.

    The substrate-independent tail of step 4: reduce per-prototype
    agreement to per-species scores, threshold (paper Eq. 2), categorize.
    Execution backends (:mod:`repro.pipeline.backend`) produce the
    agreement matrix; this is shared by all of them.
    """
    scores = assoc_memory.species_scores(agreement, proto_species,
                                         num_species)
    hits = scores >= jnp.asarray(threshold_bits, scores.dtype)
    n = hits.sum(axis=-1)
    category = jnp.where(n == 0, UNMAPPED, jnp.where(n == 1, UNIQUE, MULTI))
    return ReadClassification(hits=hits, scores=scores,
                              category=category.astype(jnp.int32))


def classify(queries: jax.Array, refdb: RefDB, space: HDSpace, *,
             threshold_bits: float | None = None) -> ReadClassification:
    """Score query HD vectors against the AM and threshold (paper Eq. 2).

    Uses the ±1 matmul agreement formulation; alternative substrates
    (packed popcount, Pallas kernels) are selected by *name* through the
    backend registry in :mod:`repro.pipeline.backend`, which routes their
    agreement matrices through :func:`from_agreement`.

    Args:
      queries: ``(R, W)`` packed query HD vectors (Demeter step 3 output).
      refdb: the HD-RefDB.
      threshold_bits: absolute agreement threshold T; defaults to the HD
        space's z-score-derived threshold.
    """
    t = space.threshold_bits if threshold_bits is None else threshold_bits
    agree = assoc_memory.agreement_matmul(queries, refdb.prototypes,
                                          space.dim)
    return from_agreement(agree, refdb.proto_species, refdb.num_species, t)
