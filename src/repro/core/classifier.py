"""Demeter step 4: multi-species classification per read.

Unlike prior HDC systems (winner-take-all), a read may match one, many, or
*no* species (paper §3.4) — the classifier returns the full hit mask plus
a category per read:

    0 = unmapped   (no species above threshold)
    1 = unique     (exactly one)
    2 = multi      (more than one)

The species reduction is deliberately factored into three composable
pieces so the prototype axis can be partitioned across devices (the
in-memory-HDC analogue of splitting the AM over crossbar arrays):

    partial_scores  per-prototype agreement -> per-species max, over any
                    *subset* of the prototypes (one shard's slice);
    merge_scores    associative, commutative elementwise max — merging
                    per-shard partials equals reducing the concatenated
                    prototype set (property-tested in tests/);
    from_scores     threshold + categorize, once, over merged scores.

``from_agreement`` (the single-shard path every backend already routes
through) is exactly ``from_scores(partial_scores(...))``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import assoc_memory
from repro.core.assoc_memory import RefDB
from repro.core.hd_space import HDSpace

UNMAPPED, UNIQUE, MULTI = 0, 1, 2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ReadClassification:
    """Per-read classification outcome for a batch of R reads, S species."""
    hits: jax.Array        # (R, S) bool — agreement >= T
    scores: jax.Array      # (R, S) int32 — best agreement per species
    category: jax.Array    # (R,) int32 — UNMAPPED / UNIQUE / MULTI

    @property
    def num_hits(self) -> jax.Array:
        return self.hits.sum(axis=-1)


#: Score of a species with no prototype in a shard: the identity of the
#: max-merge, so empty segments never win against any real agreement.
NO_SCORE = jnp.iinfo(jnp.int32).min


def partial_scores(agreement: jax.Array, proto_species: jax.Array,
                   num_species: int) -> jax.Array:
    """Per-species max over *any subset* of the prototypes -> ``(R, S)``.

    ``agreement`` is ``(R, S_shard)`` over one shard's prototype slice and
    ``proto_species`` carries the **global** species index of each local
    prototype.  Species absent from the shard come back as :data:`NO_SCORE`
    (the merge identity); rows padded to the mesh size may carry
    ``proto_species == num_species`` — segment_max drops out-of-range
    indices, so padding can never leak into a real species' score.
    """
    return assoc_memory.species_scores(agreement, proto_species, num_species)


def merge_scores(*partials: jax.Array) -> jax.Array:
    """Merge per-shard partial score matrices: elementwise max.

    Associative and commutative, so any shard order / tree shape gives the
    same result as :func:`partial_scores` over the concatenated prototypes
    (the property test in ``tests/test_sharded.py`` pins this).
    """
    return functools.reduce(jnp.maximum, partials)


def from_scores(scores: jax.Array, threshold_bits: float
                ) -> ReadClassification:
    """Threshold merged ``(R, S)`` species scores and categorize reads."""
    hits = scores >= jnp.asarray(threshold_bits, scores.dtype)
    n = hits.sum(axis=-1)
    category = jnp.where(n == 0, UNMAPPED, jnp.where(n == 1, UNIQUE, MULTI))
    return ReadClassification(hits=hits, scores=scores,
                              category=category.astype(jnp.int32))


def from_agreement(agreement: jax.Array, proto_species: jax.Array,
                   num_species: int, threshold_bits: float
                   ) -> ReadClassification:
    """Classify from a precomputed ``(R, S_protos)`` agreement matrix.

    The substrate-independent tail of step 4: reduce per-prototype
    agreement to per-species scores, threshold (paper Eq. 2), categorize.
    Execution backends (:mod:`repro.pipeline.backend`) produce the
    agreement matrix; this is shared by all of them.  Sharded execution
    runs :func:`partial_scores` per prototype shard, :func:`merge_scores`
    across shards, and the same :func:`from_scores` tail.
    """
    return from_scores(partial_scores(agreement, proto_species, num_species),
                       threshold_bits)


def classify(queries: jax.Array, refdb: RefDB, space: HDSpace, *,
             threshold_bits: float | None = None) -> ReadClassification:
    """Score query HD vectors against the AM and threshold (paper Eq. 2).

    Uses the ±1 matmul agreement formulation; alternative substrates
    (packed popcount, Pallas kernels) are selected by *name* through the
    backend registry in :mod:`repro.pipeline.backend`, which routes their
    agreement matrices through :func:`from_agreement`.

    Args:
      queries: ``(R, W)`` packed query HD vectors (Demeter step 3 output).
      refdb: the HD-RefDB.
      threshold_bits: absolute agreement threshold T; defaults to the HD
        space's z-score-derived threshold.
    """
    t = space.threshold_bits if threshold_bits is None else threshold_bits
    agree = assoc_memory.agreement_matmul(queries, refdb.prototypes,
                                          space.dim)
    return from_agreement(agree, refdb.proto_species, refdb.num_species, t)
