"""Demeter step 4: multi-species classification per read.

Unlike prior HDC systems (winner-take-all), a read may match one, many, or
*no* species (paper §3.4) — the classifier returns the full hit mask plus
a category per read:

    0 = unmapped   (no species above threshold)
    1 = unique     (exactly one)
    2 = multi      (more than one)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import assoc_memory
from repro.core.assoc_memory import RefDB
from repro.core.hd_space import HDSpace

UNMAPPED, UNIQUE, MULTI = 0, 1, 2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ReadClassification:
    """Per-read classification outcome for a batch of R reads, S species."""
    hits: jax.Array        # (R, S) bool — agreement >= T
    scores: jax.Array      # (R, S) int32 — best agreement per species
    category: jax.Array    # (R,) int32 — UNMAPPED / UNIQUE / MULTI

    @property
    def num_hits(self) -> jax.Array:
        return self.hits.sum(axis=-1)


def classify(queries: jax.Array, refdb: RefDB, space: HDSpace, *,
             threshold_bits: float | None = None,
             packed_path: bool = False) -> ReadClassification:
    """Score query HD vectors against the AM and threshold (paper Eq. 2).

    Args:
      queries: ``(R, W)`` packed query HD vectors (Demeter step 3 output).
      refdb: the HD-RefDB.
      threshold_bits: absolute agreement threshold T; defaults to the HD
        space's z-score-derived threshold.
      packed_path: use the XOR+popcount formulation instead of the +-1
        matmul one (identical results; different roofline).
    """
    t = space.threshold_bits if threshold_bits is None else threshold_bits
    if packed_path:
        agree = assoc_memory.agreement_packed_chunked(
            queries, refdb.prototypes, space.dim)
    else:
        agree = assoc_memory.agreement_matmul(
            queries, refdb.prototypes, space.dim)
    scores = assoc_memory.species_scores(
        agree, refdb.proto_species, refdb.num_species)
    hits = scores >= jnp.asarray(t, scores.dtype)
    n = hits.sum(axis=-1)
    category = jnp.where(n == 0, UNMAPPED, jnp.where(n == 1, UNIQUE, MULTI))
    return ReadClassification(hits=hits, scores=scores,
                              category=category.astype(jnp.int32))
