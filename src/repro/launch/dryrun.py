"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 host placeholder devices, lowers the real step
function (train_step / prefill / decode) with production shardings,
compiles it, and records memory_analysis / cost_analysis / the collective
schedule parsed from the partitioned HLO.  Artifacts feed EXPERIMENTS.md
§Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""

# The placeholder-device flag MUST precede any jax initialization.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import functools
import json
import pathlib
import re
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.configs import all_archs, get_config
from repro.configs import shapes as shapes_mod
from repro.distributed import param_specs, sharding
from repro.launch.mesh import make_production_mesh
from repro.serve import serve_step
from repro.train import train_step as ts

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def _link_bytes(ctype: str, result_bytes: int, g: int) -> float:
    """Per-device bytes over ICI links (ring algorithms), from result size.

    all-gather: result is the gathered tensor; each device receives
      (g-1)/g of it.  all-reduce: reduce-scatter + all-gather = 2(g-1)/g.
    reduce-scatter: result is the scattered shard; sends (g-1) shards.
    all-to-all: result-sized exchange, (g-1)/g leaves the device.
    collective-permute: the whole result moves.
    """
    if g <= 1:
        return 0.0
    f = (g - 1) / g
    return {
        "all-gather": result_bytes * f,
        "all-reduce": 2.0 * result_bytes * f,
        "reduce-scatter": result_bytes * (g - 1),
        "all-to-all": result_bytes * f,
        "collective-permute": float(result_bytes),
    }[ctype]


def parse_collectives(hlo_text: str) -> dict:
    """Collective schedule from the partitioned (per-device) module.

    Result shapes in the partitioned module are per-device; we record raw
    result bytes per collective type plus a ring-algorithm link-bytes
    estimate (the §Roofline collective term numerator).
    """
    out = {c: {"count": 0, "result_bytes": 0, "link_bytes": 0.0}
           for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        if not s.startswith("%") and not s.startswith("ROOT"):
            continue
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        # result shapes sit between '=' and the op name
        for c in _COLLECTIVES:
            pos = rhs.find(f" {c}(")
            if pos < 0:
                pos = rhs.find(f" {c}-start(")
            if pos < 0:
                continue
            if f"{c}-done" in rhs[:pos + len(c) + 7]:
                break
            result_sec = rhs[:pos]
            shapes = [_shape_bytes(m) for m in _SHAPE_RE.finditer(result_sec)]
            # tuple results (async start): take the largest component
            byt = max(shapes) if shapes else 0
            g = _group_size(line)
            out[c]["count"] += 1
            out[c]["result_bytes"] += byt
            out[c]["link_bytes"] += _link_bytes(c, byt, g)
            break
    out["total_link_bytes"] = sum(
        v["link_bytes"] for v in out.values() if isinstance(v, dict))
    out["total_result_bytes"] = sum(
        v["result_bytes"] for v in out.values() if isinstance(v, dict))
    return out


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals", "optimal_seconds")
                or k.startswith("bytes accessed"))}


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds: float
    skip_reason: str = ""
    error: str = ""
    memory: dict = dataclasses.field(default_factory=dict)
    cost: dict = dataclasses.field(default_factory=dict)
    collectives: dict = dataclasses.field(default_factory=dict)
    extra: dict = dataclasses.field(default_factory=dict)


def _rules_for(kind: str) -> sharding.Rules:
    return {"train": sharding.TRAIN_RULES,
            "prefill": sharding.PREFILL_RULES,
            "decode": sharding.DECODE_RULES}[kind]


def lower_cell(cfg: ModelConfig, shape: shapes_mod.ShapeSpec, mesh,
               *, microbatches: int = 1, cost_exact: bool = False):
    """Lower the step function for one cell.

    cost_exact: emit a loop-free program (unrolled segment scans,
    straight-line attention tiles, single-chunk loss) so XLA's cost
    analysis counts every FLOP — used by the two-point depth extrapolation.
    The default (scan) form is what memory_analysis and the collective
    schedule are read from.
    """
    rules = _rules_for(shape.kind)
    # Cost-exact tiles are sized to keep per-op buffers reasonable while
    # keeping loop trip counts == 1 wherever a loop would hide FLOPs.
    qc = kvc = 2048 if cost_exact else 512
    with sharding.use_rules(mesh, rules):
        specs = shapes_mod.input_specs(cfg, shape)
        batch_sh = param_specs.batch_shardings(specs, mesh, rules)

        if shape.kind == "train":
            tc = ts.TrainConfig(
                microbatches=microbatches,
                unroll=cost_exact,
                loss_chunk=shape.seq_len if cost_exact else 512,
                q_chunk=qc, kv_chunk=kvc if not cost_exact else 4096,
                remat=True)  # cost mode stays remat-faithful: recompute FLOPs count
            state_shapes = jax.eval_shape(functools.partial(
                ts.init_train_state, cfg=cfg, tc=tc), jax.random.key(0))
            state_sh = param_specs.state_shardings(state_shapes, mesh, rules)
            step = ts.make_train_step(cfg, tc,
                                      grad_shardings=state_sh["params"])
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=0)
            return jitted.lower(state_shapes, specs)

        params_shapes = shapes_mod.param_specs(cfg)
        param_sh = param_specs.param_shardings(params_shapes, mesh, rules)

        if shape.kind == "prefill":
            fn = serve_step.make_prefill_step(
                cfg, max_len=shape.seq_len, unroll=cost_exact,
                q_chunk=qc, kv_chunk=4096 if cost_exact else 1024)
            cache_shapes = jax.eval_shape(
                lambda p, t, **kw: fn(p, t, **kw)[1], params_shapes,
                specs["tokens"],
                **{k: v for k, v in specs.items() if k != "tokens"})
            cache_sh = param_specs.cache_shardings(
                cache_shapes, mesh, sharding.DECODE_RULES)
            logits_sh = NamedSharding(mesh, param_specs._resolve_leaf(
                (shape.global_batch, cfg.vocab), ("batch", "vocab"),
                mesh, rules))
            kw_names = [k for k in specs if k != "tokens"]
            jitted = jax.jit(
                lambda p, t, **kw: fn(p, t, **kw),
                in_shardings=(param_sh, batch_sh["tokens"]),
                out_shardings=(logits_sh, cache_sh))
            if kw_names:
                jitted = jax.jit(
                    fn, in_shardings=(param_sh, batch_sh["tokens"]) + tuple(
                        batch_sh[k] for k in kw_names),
                    out_shardings=(logits_sh, cache_sh))
                return jitted.lower(params_shapes, specs["tokens"],
                                    *[specs[k] for k in kw_names])
            return jitted.lower(params_shapes, specs["tokens"])

        # decode
        cache_shapes = shapes_mod.cache_specs(cfg, shape)
        cache_sh = param_specs.cache_shardings(cache_shapes, mesh, rules)
        logits_sh = NamedSharding(mesh, param_specs._resolve_leaf(
            (shape.global_batch, cfg.vocab), ("batch", "vocab"), mesh, rules))
        decode = serve_step.make_decode_step(cfg, unroll=cost_exact)
        jitted = jax.jit(
            decode,
            in_shardings=(param_sh, batch_sh["token"], cache_sh,
                          NamedSharding(mesh, P())),
            out_shardings=(logits_sh, cache_sh),
            donate_argnums=2)
        return jitted.lower(params_shapes, specs["token"], cache_shapes,
                            specs["cur_pos"])


def _with_layers(cfg: ModelConfig, k: int) -> ModelConfig:
    kw = {"n_layers": k}
    if cfg.is_encdec:
        kw["n_enc_layers"] = k
    if cfg.n_dense_layers:
        kw["n_dense_layers"] = 1
    return dataclasses.replace(cfg, **kw)


def _layer_points(cfg: ModelConfig) -> tuple[int, int]:
    """Two reduced depths whose delta isolates one (scannable) layer."""
    if cfg.family == "hybrid":
        return 5, 7          # 3 globals fixed; delta = 2 SWA layers
    if cfg.moe is not None and cfg.n_dense_layers:
        return 2, 3          # dense prefix fixed; delta = 1 MoE layer
    return 1, 2


def _roofline_metrics(compiled) -> dict:
    cost = _cost_dict(compiled)
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "transcendentals": cost.get("transcendentals", 0.0),
        "link_bytes": coll["total_link_bytes"],
        "coll_counts": {c: coll[c]["count"] for c in _COLLECTIVES},
        "coll_link": {c: coll[c]["link_bytes"] for c in _COLLECTIVES},
    }


def extrapolated_roofline(cfg: ModelConfig, shape, mesh) -> dict:
    """Layer-exact roofline numerators via two-point depth extrapolation.

    XLA's cost analysis counts a while-loop (scan) body once, so the
    full-depth compile undercounts per-layer work by the trip count.  We
    compile the same cell at two reduced depths; the difference is exactly
    one layer's cost, scaled back to full depth.
    """
    k1, k2 = _layer_points(cfg)
    m = {}
    for k in (k1, k2):
        lowered = lower_cell(_with_layers(cfg, k), shape, mesh,
                             cost_exact=True)
        m[k] = _roofline_metrics(lowered.compile())

    def combine(a, b):
        if isinstance(a, dict):
            return {kk: combine(a[kk], b[kk]) for kk in a}
        per_layer = (b - a) / (k2 - k1)
        return a + per_layer * (cfg.n_layers - k1)

    out = combine(m[k1], m[k2])
    out["per_layer_flops"] = (m[k2]["flops"] - m[k1]["flops"]) / (k2 - k1)
    out["depth_points"] = [k1, k2]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             *, parse_hlo: bool = True) -> CellResult:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    shape = shapes_mod.SHAPES[shape_name]
    cfg = get_config(arch)
    t0 = time.time()
    runs, reason = shapes_mod.applicable(cfg, shape)
    if not runs:
        return CellResult(arch, shape_name, mesh_name, ok=True, seconds=0.0,
                          skip_reason=reason)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered = lower_cell(cfg, shape, mesh)
        compiled = lowered.compile()
        res = CellResult(
            arch, shape_name, mesh_name, ok=True, seconds=time.time() - t0,
            memory=_mem_dict(compiled), cost=_cost_dict(compiled),
            collectives=(parse_collectives(compiled.as_text())
                         if parse_hlo else {}),
        )
        res.extra["model_flops_6nd"] = 6 * cfg.active_param_count() * (
            shape.global_batch * shape.seq_len if shape.kind == "train"
            else (shape.global_batch * shape.seq_len
                  if shape.kind == "prefill" else shape.global_batch))
        if shape.kind != "train":   # decode/prefill: 2ND forward-only
            res.extra["model_flops_6nd"] //= 3
        if parse_hlo:
            res.extra["roofline"] = extrapolated_roofline(cfg, shape, mesh)
        return res
    except Exception as e:
        return CellResult(arch, shape_name, mesh_name, ok=False,
                          seconds=time.time() - t0,
                          error=f"{type(e).__name__}: {e}\n"
                                + traceback.format_exc(limit=8))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = list(all_archs()) if (args.all or not args.arch) else [args.arch]
    shapes = (list(shapes_mod.SHAPES) if (args.all or not args.shape)
              else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                res = run_cell(arch, shape_name, mp)
                tag = f"{res.arch}.{res.shape}.{res.mesh}"
                path = outdir / f"{tag}.json"
                path.write_text(json.dumps(dataclasses.asdict(res), indent=1))
                status = ("SKIP " + res.skip_reason[:40] if res.skip_reason
                          else ("OK" if res.ok else "FAIL " + res.error[:120]))
                flops = res.cost.get("flops", 0)
                print(f"[{tag:55s}] {status}  compile={res.seconds:6.1f}s "
                      f"flops/dev={flops:.3e}", flush=True)
                n_fail += (not res.ok)
    print(f"dry-run complete, failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
