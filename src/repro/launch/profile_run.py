"""End-to-end Demeter profiling driver (the paper's production entry point).

    python -m repro.launch.profile_run --ref ref.fasta --sample reads.fastq
    python -m repro.launch.profile_run --synthetic     # no files needed

Runs the five-step pipeline: HD space (step 1, from flags), HD-RefDB build
(step 2, cached by space fingerprint like the paper's config check),
streamed read conversion + classification (steps 3-4), abundance (step 5).
"""

from __future__ import annotations

import argparse
import pathlib
import pickle
import time

import numpy as np

from repro.core import HDSpace, Demeter, batch_reads
from repro.eval import score_profile
from repro.genomics import fasta, synth


def profile(genomes: dict, tokens: np.ndarray, lengths: np.ndarray, *,
            space: HDSpace, window: int, batch_size: int,
            cache_dir: str | None, use_kernels: bool = False):
    dm = Demeter(space, window=window, batch_size=batch_size,
                 use_kernels=use_kernels)

    db = None
    cache = None
    if cache_dir:
        cache = (pathlib.Path(cache_dir)
                 / f"refdb_{space.fingerprint()}_{window}.pkl")
        if cache.exists():                       # paper's step-1 config check
            db = pickle.loads(cache.read_bytes())
            print(f"loaded HD-RefDB from {cache}")
    t0 = time.perf_counter()
    if db is None:
        db = dm.build_refdb(genomes)
        if cache:
            cache.parent.mkdir(parents=True, exist_ok=True)
            cache.write_bytes(pickle.dumps(db))
    t_build = time.perf_counter() - t0

    t0 = time.perf_counter()
    rep = dm.profile(db, batch_reads(tokens, lengths, batch_size))
    t_query = time.perf_counter() - t0

    print(f"\nbuild {t_build:.2f}s | query {t_query:.2f}s "
          f"({len(tokens) / max(t_query, 1e-9):.0f} reads/s) | "
          f"AM {db.memory_bytes() / 1e6:.2f} MB "
          f"({db.num_prototypes} prototypes)")
    print(f"reads: {rep.total_reads}  unmapped: {rep.unmapped_reads}  "
          f"multi: {rep.multi_reads}")
    print("\nspecies-level abundance (step 5):")
    for name, ab in rep.top(12):
        if ab > 0.001:
            print(f"  {name:24s} {100 * ab:6.2f}%")
    return rep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref", help="reference FASTA")
    ap.add_argument("--sample", help="sample FASTQ")
    ap.add_argument("--synthetic", action="store_true")
    ap.add_argument("--dim", type=int, default=8192)
    ap.add_argument("--ngram", type=int, default=16)
    ap.add_argument("--z-threshold", type=float, default=5.0)
    ap.add_argument("--window", type=int, default=4096)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--read-len", type=int, default=150)
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--use-kernels", action="store_true",
                    help="route through the Pallas kernels (interpret on CPU)")
    args = ap.parse_args()

    space = HDSpace(dim=args.dim, ngram=args.ngram,
                    z_threshold=args.z_threshold)
    if args.synthetic or not args.ref:
        spec = synth.CommunitySpec(num_species=10, genome_len=60_000)
        genomes, toks, lens, truth, true_ab = synth.make_sample(
            spec, num_reads=2_000)
        rep = profile(genomes, toks, lens, space=space, window=args.window,
                      batch_size=args.batch_size, cache_dir=args.cache_dir,
                      use_kernels=args.use_kernels)
        m = score_profile(rep.abundance, true_ab)
        print(f"\nvs ground truth: {m.row()}")
        return
    genomes = fasta.read_fasta(args.ref)
    toks, lens = fasta.read_fastq(args.sample, args.read_len)
    profile(genomes, toks, lens, space=space, window=args.window,
            batch_size=args.batch_size, cache_dir=args.cache_dir,
            use_kernels=args.use_kernels)


if __name__ == "__main__":
    main()
