"""End-to-end Demeter profiling driver (the paper's production entry point).

    python -m repro.launch.profile_run --ref ref.fasta --sample reads.fastq
    python -m repro.launch.profile_run --synthetic --backend pallas_matmul

Runs the five-step pipeline through the unified API: one
:class:`~repro.pipeline.config.ProfilerConfig` (step 1 from flags) drives
a :class:`~repro.pipeline.session.ProfilingSession` — RefDB build or load
(step 2, cached by the config's content fingerprint plus a genome digest,
so neither a changed space/window/stride nor a swapped reference FASTA
can reuse a stale database), streamed
read conversion + classification (steps 3-4), abundance (step 5).
"""

from __future__ import annotations

import argparse
import pathlib
import time

from repro.core import HDSpace
from repro.eval import score_profile
from repro.genomics import fasta, synth
from repro.pipeline import (ArraySource, FastqSource, ProfilerConfig,
                            ProfilingSession, ReadSource, available_backends,
                            resolve_backend)


def profile(genomes: dict, source: ReadSource | tuple, *,
            config: ProfilerConfig, cache_dir: str | None = None,
            json_path: str | None = None):
    """Build-or-load the RefDB for ``config`` and profile ``source``."""
    session = ProfilingSession(config)

    t0 = time.perf_counter()
    db = session.build_or_load_refdb(genomes, cache_dir=cache_dir)
    t_build = time.perf_counter() - t0
    if session.refdb_loaded_from_cache:
        print(f"loaded HD-RefDB from {session.refdb_cache_file}")

    t0 = time.perf_counter()
    rep = session.profile(source)
    t_query = time.perf_counter() - t0

    print(f"\nbackend {config.backend} | build {t_build:.2f}s | "
          f"query {t_query:.2f}s "
          f"({rep.total_reads / max(t_query, 1e-9):.0f} reads/s) | "
          f"AM {db.memory_bytes() / 1e6:.2f} MB "
          f"({db.num_prototypes} prototypes)")
    shards = getattr(session.backend, "num_shards", 1)
    if shards > 1:
        from repro.pipeline import per_device_bytes
        print(f"sharded {shards} ways ({session.backend.base.name} base): "
              f"{per_device_bytes(db, shards) / 1e6:.2f} MB per device")
    print(f"reads: {rep.total_reads}  unmapped: {rep.unmapped_reads}  "
          f"multi: {rep.multi_reads}")
    print("\nspecies-level abundance (step 5):")
    for name, ab in rep.top(12):
        if ab > 0.001:
            print(f"  {name:24s} {100 * ab:6.2f}%")
    if json_path is not None:
        # The same machine-readable artifact ProfilingService snapshots
        # emit: one ProfileReport JSON (round-trips via from_json).
        p = pathlib.Path(json_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(rep.to_json(indent=2))
        print(f"\nwrote report JSON to {p}")
    return rep


def _parse_spec(spec: str) -> tuple[str, str]:
    """Split ``KEY=VALUE`` (values stay raw; the schema types them)."""
    key, sep, raw = spec.partition("=")
    if not sep or not key:
        raise SystemExit(f"--backend-option expects KEY=VALUE, got {spec!r}")
    return key, raw


def _typed_options(ap, backend: str,
                   pairs: list[tuple[str, str]]) -> dict:
    """Coerce raw ``--backend-option`` values through ``backend``'s
    declared schema (`repro.pipeline.options`): unknown keys and values
    that don't parse as the declared kind are CLI errors naming the
    option, identical across every backend.  For a passthrough backend
    (``sharded``) unknown keys fall through to the wrapped base's schema.
    """
    from repro.pipeline.backend import options_schema
    from repro.pipeline.options import OptionError

    schema = options_schema(backend)
    base_schema = None
    if schema.passthrough:
        base = dict(pairs).get("base", "reference")
        if base in available_backends():
            base_schema = options_schema(base)
    out = {}
    for key, raw in pairs:
        use = schema
        if (schema.option(key) is None and schema.passthrough
                and base_schema is not None):
            use = base_schema
        try:
            out[key] = use.parse_cli(key, raw)
        except OptionError as e:
            ap.error(f"--backend-option: {e}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref", help="reference FASTA")
    ap.add_argument("--sample", help="sample FASTQ")
    ap.add_argument("--synthetic", action="store_true")
    ap.add_argument("--dim", type=int, default=8192)
    ap.add_argument("--ngram", type=int, default=16)
    ap.add_argument("--z-threshold", type=float, default=5.0)
    ap.add_argument("--window", type=int, default=4096)
    ap.add_argument("--stride", type=int, default=None,
                    help="window stride (default: non-overlapping)")
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--read-len", type=int, default=150)
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the ProfileReport as JSON (the same "
                         "artifact ProfilingService snapshots emit)")
    ap.add_argument("--backend", default="reference",
                    help="execution backend, one of the registered names "
                         "(see --list-backends; Pallas backends run in "
                         "interpret mode on CPU)")
    ap.add_argument("--backend-option", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="backend-specific option, repeatable (e.g. "
                         "--backend pcm_sim --backend-option preset=pcm "
                         "--backend-option read_sigma=0.05)")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="size of the 1-D ('shard',) profiling mesh. One "
                         "shard lives on each mesh device, so this and "
                         "--shards are the same knob (given both, they "
                         "must agree); grow the host device count with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="shard the RefDB prototype axis N ways: wraps the "
                         "chosen backend in the 'sharded' backend (reports "
                         "stay bit-identical; each device holds 1/N of the "
                         "database)")
    ap.add_argument("--list-backends", action="store_true",
                    help="print the registered backend names with their "
                         "declared options and exit")
    ap.add_argument("--noise-aware-refdb", action="store_true",
                    help="retrain the RefDB prototypes on simulated noisy "
                         "readout through the chosen backend (the "
                         "margin-maximizing co-design pass; joins the "
                         "RefDB cache key)")
    ap.add_argument("--noise-aware-iters", type=int, default=2,
                    help="retraining passes for --noise-aware-refdb")
    args = ap.parse_args()

    if args.list_backends:
        from repro.pipeline.backend import options_schema
        for name in available_backends():
            print(name)
            schema = options_schema(name)
            for row in schema.describe():
                print(f"  {row}")
            if schema.passthrough:
                print("  (+ the wrapped base backend's options, validated "
                      "by its own schema)")
        return
    if args.backend not in available_backends():
        ap.error(f"unknown backend {args.backend!r}; available: "
                 f"{', '.join(available_backends())}")

    pairs = [_parse_spec(s) for s in args.backend_option]
    # The schema that types the values is the *effective* backend's: with
    # --shards/--mesh the options ride into 'sharded' (whose passthrough
    # forwards base-backend knobs), otherwise the named backend's own.
    wrapping = ((args.shards is not None or args.mesh is not None)
                and args.backend != "sharded")
    base_hint = ([("base", args.backend)]
                 if wrapping and "base" not in dict(pairs) else [])
    options = _typed_options(
        ap, "sharded" if wrapping else args.backend, pairs + base_hint)
    if base_hint:       # parse-time hint only; the wrap logic re-adds it
        del options["base"]
    backend = args.backend
    if args.mesh is not None and args.shards is not None \
            and args.mesh != args.shards:
        ap.error(f"--mesh {args.mesh} conflicts with --shards "
                 f"{args.shards}: the mesh holds one shard per device, "
                 f"so the two must agree (or give just one)")
    shards = args.shards if args.shards is not None else args.mesh
    if shards is not None:
        # An explicit flag must never be silently overridden by a
        # conflicting backend-option (same contract as --mesh vs --shards
        # above: disagreement is an error, not a quiet winner).
        if "shards" in options and options["shards"] != shards:
            ap.error(f"--shards {shards} conflicts with "
                     f"--backend-option shards={options['shards']}")
        if backend != "sharded":
            if "base" in options and options["base"] != backend:
                ap.error(f"--backend {backend} conflicts with "
                         f"--backend-option base={options['base']}")
            # --shards N means "this backend, N ways": the sharded backend
            # wraps it as its base, same reports, 1/N database per device.
            options = {**options, "base": backend, "shards": shards}
            backend = "sharded"
        else:
            options["shards"] = shards

    config = ProfilerConfig(
        space=HDSpace(dim=args.dim, ngram=args.ngram,
                      z_threshold=args.z_threshold),
        window=args.window, stride=args.stride,
        batch_size=args.batch_size, backend=backend,
        backend_options=options,
        noise_aware_refdb=args.noise_aware_refdb,
        noise_aware_iters=args.noise_aware_iters)
    try:                      # surface bad --backend-option values as CLI
        resolve_backend(config.backend, config)  # errors, not tracebacks
    except ValueError as e:
        ap.error(str(e))

    if args.synthetic or not args.ref:
        spec = synth.CommunitySpec(num_species=10, genome_len=60_000)
        genomes, toks, lens, truth, true_ab = synth.make_sample(
            spec, num_reads=2_000)
        rep = profile(genomes, ArraySource(toks, lens), config=config,
                      cache_dir=args.cache_dir, json_path=args.json)
        m = score_profile(rep.abundance, true_ab)
        print(f"\nvs ground truth: {m.row()}")
        return
    genomes = fasta.read_fasta(args.ref)
    profile(genomes, FastqSource(args.sample, args.read_len),
            config=config, cache_dir=args.cache_dir, json_path=args.json)


if __name__ == "__main__":
    main()
