"""Profiler serving driver: request-rate / latency harness for the service.

    python -m repro.launch.serve_profiler --requests 16 --rate 20
    python -m repro.launch.serve_profiler --smoke
    python -m repro.launch.serve_profiler --smoke --tenants 2
    python -m repro.launch.serve_profiler --tenants 4 --workers 2 \
        --rate 20,10,10,5 --check

Single-tenant mode (the default) builds one shared RefDB from a
synthetic food community, starts a
:class:`~repro.serve.profiler_service.ProfilingService` with a
background worker, submits many concurrent profiling requests at a
target rate (each request a disjoint slice of sample reads), and
reports sustained throughput plus p50/p99 request latency.

``--tenants N`` switches to the fleet driver: a
:class:`~repro.serve.registry.RefDBRegistry` owns the database, a
:class:`~repro.serve.router.TenantRouter` with ``--workers`` pump
threads serves N tenants at per-tenant arrival rates (``--rate`` takes
a comma list), and **mid-traffic an add-species delta is published** —
the router hot-swaps with zero downtime, so requests admitted before
the swap complete against the old version and later admissions see the
new one.  The report covers fleet and per-tenant p50/p99 plus the
versions each tenant's requests ran against.

With ``--check`` each per-request report is verified bit-identical to a
sequential ``ProfilingSession.profile()`` run of the same reads on the
exact database version that admitted it — the serving layer's
correctness contract, live in the driver.  On any mismatch the driver
prints the failing request ids and exits non-zero.

``--smoke`` shrinks everything so CI can run the full
submit/interleave/stream/finalize(/swap) cycle in seconds.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile
import threading
import time

import numpy as np

from repro import obs
from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession,
                            available_backends)
from repro.serve import ProfilingService, RefDBRegistry, TenantRouter


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def _report_check_failures(failing_ids: list[str]) -> None:
    """Per the serving contract: a --check mismatch is a hard failure."""
    print(f"CHECK FAILED: {len(failing_ids)} request(s) diverged from "
          f"their sequential runs: {' '.join(failing_ids)}", file=sys.stderr)
    raise SystemExit(1)


def drive(*, config: ProfilerConfig, num_species: int, genome_len: int,
          num_requests: int, reads_per_request: int, rate_hz: float,
          max_active: int = 8, check: bool = False,
          json_dir: str | None = None) -> dict:
    """Run the rate-driven serving experiment; returns the summary dict."""
    spec = synth.CommunitySpec(num_species=num_species,
                               genome_len=genome_len, seed=7)
    genomes, toks, lens, _, _ = synth.make_sample(
        spec, num_reads=num_requests * reads_per_request)

    session = ProfilingSession(config)
    t0 = time.perf_counter()
    session.build_refdb(genomes)
    t_build = time.perf_counter() - t0
    print(f"backend {config.backend} | RefDB build {t_build:.2f}s "
          f"({session.refdb.num_prototypes} prototypes, shared by "
          f"{num_requests} requests)")

    # Each request profiles its own disjoint slice of the sample.
    sources = [ArraySource(toks[i::num_requests], lens[i::num_requests])
               for i in range(num_requests)]

    service = ProfilingService(session, max_active=max_active,
                               max_queue=max(num_requests, 1))
    handles = []
    t0 = time.perf_counter()
    with service:
        for i, src in enumerate(sources):
            if rate_hz > 0 and i:
                # open-loop arrivals: steady 1/rate spacing from t0
                time.sleep(max(0.0, t0 + i / rate_hz - time.perf_counter()))
            handles.append(service.submit(src, request_id=f"req-{i}"))
        reports = [h.result(timeout=600) for h in handles]
    wall = time.perf_counter() - t0

    lat = [h.latency_s for h in handles]
    total_reads = sum(r.total_reads for r in reports)
    summary = {
        "backend": config.backend,
        "requests": num_requests,
        "reads": total_reads,
        "wall_s": wall,
        "reads_per_s": total_reads / max(wall, 1e-9),
        "p50_ms": _percentile(lat, 50) * 1e3,
        "p99_ms": _percentile(lat, 99) * 1e3,
        "cohorts": service.cohorts_run,
    }
    print(f"{num_requests} requests x {reads_per_request} reads in "
          f"{wall:.2f}s | {summary['reads_per_s']:.0f} reads/s | "
          f"latency p50 {summary['p50_ms']:.0f}ms "
          f"p99 {summary['p99_ms']:.0f}ms | {service.cohorts_run} cohorts")

    if json_dir is not None:
        out = pathlib.Path(json_dir)
        out.mkdir(parents=True, exist_ok=True)
        for h, rep in zip(handles, reports):
            (out / f"{h.request_id}.json").write_text(rep.to_json(indent=2))
        print(f"wrote {len(reports)} report snapshots to {out}/")

    if check:
        failing = []
        for h, src, rep in zip(handles, sources, reports):
            if rep.to_json() != session.profile(src).to_json():
                failing.append(h.request_id)
        if failing:
            _report_check_failures(failing)
        print(f"check OK: all {num_requests} reports bit-identical to "
              f"sequential ProfilingSession.profile() runs")
    return summary


def drive_fleet(*, config: ProfilerConfig, num_species: int, genome_len: int,
                tenants: int, requests_per_tenant: int,
                reads_per_request: int, rates_hz: list[float],
                workers: int = 1, max_active: int = 4, max_queue: int = 16,
                check: bool = False, store: str | None = None,
                json_dir: str | None = None,
                gate_last_on_delta: bool = False,
                gc_keep_last: int | None = None) -> dict:
    """Multi-tenant fleet experiment with a mid-traffic delta hot-swap.

    ``gate_last_on_delta`` holds each tenant's final request until the
    delta is published, guaranteeing the run exercises admissions on
    both sides of the swap (the CI smoke asserts this).

    ``gc_keep_last`` runs a post-drain registry sweep keeping that many
    newest versions — previewed with ``dry_run=True`` first (the safe
    operator flow), then applied for real; both land in the summary.
    """
    spec = synth.CommunitySpec(num_species=num_species,
                               genome_len=genome_len, seed=7)
    total_requests = tenants * requests_per_tenant
    genomes, toks, lens, _, _ = synth.make_sample(
        spec, num_reads=total_requests * reads_per_request)
    # The mid-traffic update: one genuinely new species for the delta.
    rng = np.random.default_rng(spec.seed + 1)
    delta_genomes = {"sp_delta": rng.integers(0, 4, genome_len,
                                              dtype=np.int32)}

    root = store or tempfile.mkdtemp(prefix="refdb-registry-")
    registry = RefDBRegistry(root=root)
    t0 = time.perf_counter()
    registry.create("food", genomes, config)
    t_build = time.perf_counter() - t0
    print(f"backend {config.backend} | registry at {root} | "
          f"RefDB food:v1 build {t_build:.2f}s | "
          f"{tenants} tenants x {requests_per_tenant} requests")

    router = TenantRouter(registry)
    names = [f"tenant{i}" for i in range(tenants)]
    for name in names:
        router.add_tenant(name, database="food",
                          max_active=max_active, max_queue=max_queue)

    per_tenant = {
        name: [ArraySource(
            toks[(t * requests_per_tenant + i)::total_requests],
            lens[(t * requests_per_tenant + i)::total_requests])
            for i in range(requests_per_tenant)]
        for t, name in enumerate(names)}

    handles: dict[str, list] = {name: [] for name in names}
    submitted = threading.Semaphore(0)
    delta_published = threading.Event()
    errors: list[BaseException] = []

    def tenant_load(name: str, rate_hz: float) -> None:
        """Open-loop arrivals for one tenant (blocking on its quota)."""
        t0 = time.perf_counter()
        try:
            for i, src in enumerate(per_tenant[name]):
                if rate_hz > 0 and i:
                    time.sleep(max(0.0, t0 + i / rate_hz
                                   - time.perf_counter()))
                if gate_last_on_delta and i == requests_per_tenant - 1:
                    delta_published.wait(timeout=600)
                handles[name].append(router.submit(
                    src, tenant=name, block=True, timeout=600))
                submitted.release()
        except BaseException as e:          # surfaced after the join
            errors.append(e)

    loaders = [threading.Thread(target=tenant_load, args=(n, r), daemon=True)
               for n, r in zip(names, rates_hz)]
    t0 = time.perf_counter()
    router.start(workers)
    try:
        for t in loaders:
            t.start()
        # Publish the add-species delta once half the fleet's requests are
        # admitted: the router auto-swaps, in-flight work drains on v1.
        for _ in range(total_requests // 2):
            submitted.acquire()
        t_delta = time.perf_counter()
        snap2 = registry.apply_delta("food", add=delta_genomes)
        delta_published.set()
        print(f"published delta v{snap2.version} (+{snap2.delta['added']}) "
              f"at t={t_delta - t0:.2f}s; serving "
              f"v{router.serving_version('food')}")
        for t in loaders:
            t.join()
        reports = {name: [h.result(timeout=600) for h in hs]
                   for name, hs in handles.items()}
    finally:
        router.stop()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]

    all_handles = [h for hs in handles.values() for h in hs]
    lat = [h.latency_s for h in all_handles]
    total_reads = sum(r.total_reads for rs in reports.values() for r in rs)
    summary = {
        "backend": config.backend,
        "tenants": tenants,
        "workers": workers,
        "requests": total_requests,
        "reads": total_reads,
        "wall_s": wall,
        "reads_per_s": total_reads / max(wall, 1e-9),
        "p50_ms": _percentile(lat, 50) * 1e3,
        "p99_ms": _percentile(lat, 99) * 1e3,
        "swaps": router.swaps,
        "versions": sorted({h.version for h in all_handles}),
        "per_tenant": {},
    }
    print(f"fleet: {total_requests} requests ({total_reads} reads) in "
          f"{wall:.2f}s | {summary['reads_per_s']:.0f} reads/s | "
          f"p50 {summary['p50_ms']:.0f}ms p99 {summary['p99_ms']:.0f}ms | "
          f"{router.swaps} swap(s), versions {summary['versions']}")
    metrics = obs.metrics()
    for name, rate in zip(names, rates_hz):
        hs = handles[name]
        lat_t = [h.latency_s for h in hs]
        vs = sorted({h.version for h in hs})
        treads = sum(r.total_reads for r in reports[name])
        summary["per_tenant"][name] = {
            "rate_hz": rate,
            "reads": treads,
            "reads_per_s": treads / max(wall, 1e-9),
            "p50_ms": _percentile(lat_t, 50) * 1e3,
            "p99_ms": _percentile(lat_t, 99) * 1e3,
            "versions": vs,
        }
        if metrics.enabled:
            metrics.gauge(
                "tenant_reads_per_s",
                "Sustained reads/s per tenant over the drive window.",
            ).set(summary["per_tenant"][name]["reads_per_s"], tenant=name)
        print(f"  {name}: rate {rate:g}/s | "
              f"{summary['per_tenant'][name]['reads_per_s']:.0f} reads/s | "
              f"p50 {summary['per_tenant'][name]['p50_ms']:.0f}ms "
              f"p99 {summary['per_tenant'][name]['p99_ms']:.0f}ms | "
              f"versions {vs}")
    router.close()

    if json_dir is not None:
        out = pathlib.Path(json_dir)
        out.mkdir(parents=True, exist_ok=True)
        for hs, rs in ((handles[n], reports[n]) for n in names):
            for h, rep in zip(hs, rs):
                (out / f"{h.request_id}.json").write_text(
                    rep.to_json(indent=2))
        print(f"wrote {len(all_handles)} report snapshots to {out}/")

    if check:
        # Each report must be bit-identical to a sequential run on the
        # version that ADMITTED the request — the zero-downtime contract.
        sessions: dict[int, ProfilingSession] = {}

        def sequential(version: int) -> ProfilingSession:
            if version not in sessions:
                s = ProfilingSession(config)
                s.adopt_refdb(registry.snapshot("food", version).db)
                sessions[version] = s
            return sessions[version]

        failing = []
        for name in names:
            for h, src, rep in zip(handles[name], per_tenant[name],
                                   reports[name]):
                want = sequential(h.version).profile(src)
                if rep.to_json() != want.to_json():
                    failing.append(h.request_id)
        if failing:
            _report_check_failures(failing)
        pre = sum(h.version == 1 for h in all_handles)
        if gate_last_on_delta and not 0 < pre < total_requests:
            print(f"CHECK FAILED: swap not exercised on both sides "
                  f"({pre}/{total_requests} requests on v1)",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"check OK: all {total_requests} reports bit-identical to "
              f"sequential runs on their admitted versions "
              f"({pre} on v1, {total_requests - pre} on v{snap2.version})")

    if gc_keep_last is not None:
        # Operator flow: dry-run preview first, then the real sweep —
        # identical victim sets by construction (nothing published in
        # between), asserted here so the preview stays trustworthy.
        # Runs last: --check still needs the old versions' snapshots.
        preview = registry.gc("food", keep_last=gc_keep_last, dry_run=True)
        print(f"gc preview (keep_last={gc_keep_last}): would collect "
              f"versions {[v for _, v in preview.collected]} "
              f"({preview.reclaimed_bytes} bytes)")
        swept = registry.gc("food", keep_last=gc_keep_last)
        assert swept.collected == preview.collected
        print(f"gc: collected versions {[v for _, v in swept.collected]} "
              f"({swept.reclaimed_bytes} bytes reclaimed)")
        summary["gc"] = {
            "keep_last": gc_keep_last,
            "collected": [list(c) for c in swept.collected],
            "reclaimed_bytes": swept.reclaimed_bytes,
        }
    return summary


def _parse_rates(raw: str, tenants: int) -> list[float]:
    rates = [float(r) for r in raw.split(",")]
    if len(rates) == 1:
        rates *= tenants
    if len(rates) != tenants:
        raise SystemExit(f"--rate gave {len(rates)} rates for "
                         f"{tenants} tenants")
    return rates


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16,
                    help="requests (per tenant, with --tenants > 1)")
    ap.add_argument("--reads-per-request", type=int, default=512)
    ap.add_argument("--rate", default="0",
                    help="request arrival rate in req/s (0 = all at once);"
                         " with --tenants, a comma list gives per-tenant"
                         " rates")
    ap.add_argument("--tenants", type=int, nargs="?", const=2, default=1,
                    help="> 1 switches to the registry+router fleet driver"
                         " with a mid-traffic delta hot-swap (bare"
                         " --tenants means 2)")
    ap.add_argument("--workers", type=int, default=1,
                    help="router pump threads (fleet mode)")
    ap.add_argument("--max-active", type=int, default=8)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--ngram", type=int, default=16)
    ap.add_argument("--window", type=int, default=4096)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--species", type=int, default=8)
    ap.add_argument("--genome-len", type=int, default=40_000)
    ap.add_argument("--backend", default="reference",
                    choices=available_backends())
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="registry root (fleet mode); default: a temp dir")
    ap.add_argument("--gc-keep-last", type=int, default=None, metavar="N",
                    help="after the drain, sweep the registry keeping the"
                         " N newest versions (dry-run preview first, then"
                         " the real collection; fleet mode only)")
    ap.add_argument("--check", action="store_true",
                    help="verify each report against a sequential run on"
                         " its admitted database version; exit non-zero"
                         " with the failing request ids on mismatch")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="write each request's ProfileReport JSON here")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="enable the observability layer and write the"
                         " metrics snapshot (+ sampled traces) here")
    ap.add_argument("--trace", type=int, default=0, metavar="N",
                    help="record spans for the first N requests"
                         " (admission -> schedule -> execute -> finalize);"
                         " implies metrics collection")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="capture a jax.profiler device/XLA trace of the"
                         " serving window into DIR")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (implies --check)")
    args = ap.parse_args()

    # Observability is opt-in: the globals flip before any session /
    # service / router is constructed, so every layer resolves them.
    reg = rec = None
    if args.metrics_json or args.trace:
        reg = obs.enable_metrics()
        if args.trace:
            rec = obs.enable_tracing(sample=args.trace)

    if args.smoke:
        config = ProfilerConfig(
            space=HDSpace(dim=512, ngram=8, z_threshold=3.0),
            window=1024, batch_size=32, backend=args.backend)
        with obs.jax_trace(args.jax_profile):
            if args.tenants > 1:
                summary = drive_fleet(
                    config=config, num_species=4, genome_len=8_000,
                    tenants=args.tenants, requests_per_tenant=6,
                    reads_per_request=32, rates_hz=[0.0] * args.tenants,
                    workers=args.workers, max_active=1, max_queue=1,
                    check=True, store=args.store, json_dir=args.json,
                    gate_last_on_delta=True,
                    gc_keep_last=args.gc_keep_last)
            else:
                summary = drive(
                    config=config, num_species=4, genome_len=8_000,
                    num_requests=8, reads_per_request=48, rate_hz=0.0,
                    max_active=4, check=True, json_dir=args.json)
        _dump_observability(args, summary, reg, rec)
        return
    config = ProfilerConfig(
        space=HDSpace(dim=args.dim, ngram=args.ngram),
        window=args.window, batch_size=args.batch_size,
        backend=args.backend)
    with obs.jax_trace(args.jax_profile):
        if args.tenants > 1:
            summary = drive_fleet(
                config=config, num_species=args.species,
                genome_len=args.genome_len, tenants=args.tenants,
                requests_per_tenant=args.requests,
                reads_per_request=args.reads_per_request,
                rates_hz=_parse_rates(args.rate, args.tenants),
                workers=args.workers, max_active=args.max_active,
                check=args.check, store=args.store, json_dir=args.json,
                gc_keep_last=args.gc_keep_last)
        else:
            summary = drive(
                config=config, num_species=args.species,
                genome_len=args.genome_len, num_requests=args.requests,
                reads_per_request=args.reads_per_request,
                rate_hz=float(args.rate.split(",")[0]),
                max_active=args.max_active, check=args.check,
                json_dir=args.json)
    _dump_observability(args, summary, reg, rec)


def _dump_observability(args, summary: dict, reg, rec) -> None:
    """Write the run's metrics snapshot + sampled traces, if enabled."""
    if rec is not None:
        for t in rec.to_dicts():
            phases = " ".join(f"{s['name']} {s['duration_s'] * 1e3:.1f}ms"
                              for s in t["spans"][1:])
            print(f"trace {t['trace_id']} [{t['state']}] "
                  f"{t['duration_s'] * 1e3:.1f}ms: {phases}")
    if args.metrics_json is None:
        return
    payload = {"schema": 1, "run": summary, "metrics": reg.snapshot()}
    if rec is not None:
        payload["traces"] = rec.to_dicts()
    path = pathlib.Path(args.metrics_json)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(payload, indent=2, sort_keys=True))
    print(f"wrote metrics snapshot to {path}")


if __name__ == "__main__":
    main()
