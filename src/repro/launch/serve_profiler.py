"""Profiler serving driver: request-rate / latency harness for the service.

    python -m repro.launch.serve_profiler --requests 16 --rate 20
    python -m repro.launch.serve_profiler --smoke
    python -m repro.launch.serve_profiler --backend pallas_matmul --json out/

Builds one shared RefDB from a synthetic food community, starts a
:class:`~repro.serve.profiler_service.ProfilingService` with a background
worker, submits many concurrent profiling requests at a target rate
(each request a disjoint slice of sample reads), and reports sustained
throughput plus p50/p99 request latency.  With ``--check`` each
per-request report is verified bit-identical to a sequential
``ProfilingSession.profile()`` run of the same reads — the serving
layer's correctness contract, live in the driver.

``--smoke`` shrinks everything so CI can run the full
submit/interleave/stream/finalize cycle in seconds.
"""

from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession,
                            available_backends)
from repro.serve import ProfilingService


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def drive(*, config: ProfilerConfig, num_species: int, genome_len: int,
          num_requests: int, reads_per_request: int, rate_hz: float,
          max_active: int = 8, check: bool = False,
          json_dir: str | None = None) -> dict:
    """Run the rate-driven serving experiment; returns the summary dict."""
    spec = synth.CommunitySpec(num_species=num_species,
                               genome_len=genome_len, seed=7)
    genomes, toks, lens, _, _ = synth.make_sample(
        spec, num_reads=num_requests * reads_per_request)

    session = ProfilingSession(config)
    t0 = time.perf_counter()
    session.build_refdb(genomes)
    t_build = time.perf_counter() - t0
    print(f"backend {config.backend} | RefDB build {t_build:.2f}s "
          f"({session.refdb.num_prototypes} prototypes, shared by "
          f"{num_requests} requests)")

    # Each request profiles its own disjoint slice of the sample.
    sources = [ArraySource(toks[i::num_requests], lens[i::num_requests])
               for i in range(num_requests)]

    service = ProfilingService(session, max_active=max_active,
                               max_queue=max(num_requests, 1))
    handles = []
    t0 = time.perf_counter()
    with service:
        for i, src in enumerate(sources):
            if rate_hz > 0 and i:
                # open-loop arrivals: steady 1/rate spacing from t0
                time.sleep(max(0.0, t0 + i / rate_hz - time.perf_counter()))
            handles.append(service.submit(src, request_id=f"req-{i}"))
        reports = [h.result(timeout=600) for h in handles]
    wall = time.perf_counter() - t0

    lat = [h.latency_s for h in handles]
    total_reads = sum(r.total_reads for r in reports)
    summary = {
        "backend": config.backend,
        "requests": num_requests,
        "reads": total_reads,
        "wall_s": wall,
        "reads_per_s": total_reads / max(wall, 1e-9),
        "p50_ms": _percentile(lat, 50) * 1e3,
        "p99_ms": _percentile(lat, 99) * 1e3,
        "cohorts": service.cohorts_run,
    }
    print(f"{num_requests} requests x {reads_per_request} reads in "
          f"{wall:.2f}s | {summary['reads_per_s']:.0f} reads/s | "
          f"latency p50 {summary['p50_ms']:.0f}ms "
          f"p99 {summary['p99_ms']:.0f}ms | {service.cohorts_run} cohorts")

    if json_dir is not None:
        out = pathlib.Path(json_dir)
        out.mkdir(parents=True, exist_ok=True)
        for h, rep in zip(handles, reports):
            (out / f"{h.request_id}.json").write_text(rep.to_json(indent=2))
        print(f"wrote {len(reports)} report snapshots to {out}/")

    if check:
        for h, src, rep in zip(handles, sources, reports):
            want = session.profile(src)
            np.testing.assert_array_equal(rep.abundance, want.abundance)
            assert rep.to_json() == want.to_json(), h.request_id
        print(f"check OK: all {num_requests} reports bit-identical to "
              f"sequential ProfilingSession.profile() runs")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--reads-per-request", type=int, default=512)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="request arrival rate in req/s (0 = all at once)")
    ap.add_argument("--max-active", type=int, default=8)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--ngram", type=int, default=16)
    ap.add_argument("--window", type=int, default=4096)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--species", type=int, default=8)
    ap.add_argument("--genome-len", type=int, default=40_000)
    ap.add_argument("--backend", default="reference",
                    choices=available_backends())
    ap.add_argument("--check", action="store_true",
                    help="verify each report against a sequential run")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="write each request's ProfileReport JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (implies --check)")
    args = ap.parse_args()

    if args.smoke:
        config = ProfilerConfig(
            space=HDSpace(dim=512, ngram=8, z_threshold=3.0),
            window=1024, batch_size=32, backend=args.backend)
        drive(config=config, num_species=4, genome_len=8_000,
              num_requests=8, reads_per_request=48, rate_hz=0.0,
              max_active=4, check=True, json_dir=args.json)
        return
    config = ProfilerConfig(
        space=HDSpace(dim=args.dim, ngram=args.ngram),
        window=args.window, batch_size=args.batch_size,
        backend=args.backend)
    drive(config=config, num_species=args.species,
          genome_len=args.genome_len, num_requests=args.requests,
          reads_per_request=args.reads_per_request, rate_hz=args.rate,
          max_active=args.max_active, check=args.check, json_dir=args.json)


if __name__ == "__main__":
    main()
