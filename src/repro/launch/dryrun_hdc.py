"""Dry-run for the paper's own workload: the Demeter HDC query step.

Proves the HDC profiler's production sharding compiles on the 16x16 and
2x16x16 meshes: reads sharded over (pod, data), HD dimension (words) over
model; encoding is bitwise-local (zero collectives), classification
contracts D -> one reduce over 'model'.

Two classification shardings are lowered (the §Perf H3 comparison):
  d_contract — prototypes replicated, agreement psum over 'model'
  proto_shard — queries all-gathered over 'model', prototypes sharded,
                scores land sharded over S (no all-reduce)

Usage:  python -m repro.launch.dryrun_hdc [--multi-pod]
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import assoc_memory, encoder, item_memory
from repro.core.hd_space import HDSpace
from repro.launch.mesh import make_production_mesh
from repro.launch import dryrun as dr

SPACE = HDSpace(dim=40960, ngram=16, z_threshold=5.0)
BATCH = 65536           # reads per query step (global)
READ_LEN = 152
NUM_PROTOS = 2048


def build_query_step(variant: str, mesh=None, data_axes=("data",)):
    im = item_memory.make_item_memory(SPACE)
    tie = item_memory.make_tie_break(SPACE)
    im_last = jnp.roll(im, SPACE.ngram - 1, axis=-1)

    def query_step(tokens, lengths, protos_pm):
        counts, m = encoder.bundle_counts(
            tokens, lengths, im, im_last, n=SPACE.ngram, dim=SPACE.dim)
        q = encoder.binarize_majority(counts, m, tie)
        if variant == "query_a2a" and mesh is not None:
            # §Perf H-paper iteration 2: encode stays D-sharded (zero
            # redundancy), then the PACKED queries reshard batch over
            # (data x model) via one all-to-all — 3.2x fewer link bytes
            # than psum-ing the (B, S) agreement partials.
            q = jax.lax.with_sharding_constraint(
                q, NamedSharding(mesh, P(data_axes + ("model",), None)))
        agree = assoc_memory.agreement_matmul(q, protos_pm, SPACE.dim)
        return agree

    return query_step


def run(multi_pod: bool, variant: str = "d_contract") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    step = build_query_step(variant, mesh=mesh, data_axes=data_axes)

    tokens = jax.ShapeDtypeStruct((BATCH, READ_LEN), jnp.int32)
    lengths = jax.ShapeDtypeStruct((BATCH,), jnp.int32)
    protos = jax.ShapeDtypeStruct((NUM_PROTOS, SPACE.num_words), jnp.uint32)

    if variant == "d_contract":
        proto_sh = NamedSharding(mesh, P(None, "model"))
        out_sh = NamedSharding(mesh, P(data_axes, None))
    elif variant == "query_a2a":
        proto_sh = NamedSharding(mesh, P())            # replicated (10 MB)
        out_sh = NamedSharding(mesh, P(data_axes + ("model",), None))
    else:  # proto_shard
        proto_sh = NamedSharding(mesh, P("model", None))
        out_sh = NamedSharding(mesh, P(data_axes, "model"))

    jitted = jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(data_axes, None)),
                      NamedSharding(mesh, P(data_axes)),
                      proto_sh),
        out_shardings=out_sh)
    lowered = jitted.lower(tokens, lengths, protos)
    compiled = lowered.compile()
    return {
        "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "ok": True,
        "memory": dr._mem_dict(compiled),
        "cost": dr._cost_dict(compiled),
        "collectives": dr.parse_collectives(compiled.as_text()),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    for variant in ("d_contract", "proto_shard", "query_a2a"):
        res = run(args.multi_pod, variant)
        tag = f"demeter_hdc.query.{variant}.{res['mesh']}"
        (out / f"{tag}.json").write_text(json.dumps(res, indent=1))
        print(f"[{tag}] OK link_bytes/dev="
              f"{res['collectives']['total_link_bytes']:.3e}", flush=True)


if __name__ == "__main__":
    main()
