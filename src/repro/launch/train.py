"""Production training driver: mesh + pjit + checkpoint/restart + monitoring.

    python -m repro.launch.train --arch stablelm-3b --steps 100 \
        --global-batch 32 --seq-len 256 [--smoke] [--ckpt-dir ckpt/]

On the CPU container this runs reduced configs end-to-end (the examples
use it); on a real pod the same driver runs the full mesh (--mesh prod).
Fault tolerance: async checkpoints every --ckpt-every steps, deterministic
data replay from the step index, restart-safe (see
distributed/fault_tolerance.py).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.checkpoint import checkpointer as ck
from repro.data import lm_data
from repro.distributed import fault_tolerance as ft
from repro.distributed import param_specs, sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train import train_step as ts
from repro.train.optimizer import OptConfig


def make_batch_fn(cfg, dc: lm_data.DataConfig):
    rng = np.random.default_rng(dc.seed + 17)

    def at(step: int) -> dict:
        batch = lm_data.batch_at(dc, step)
        b = dc.global_batch
        if cfg.family == "audio":
            batch["enc_embeds"] = rng.normal(
                size=(b, dc.seq_len, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            batch["prefix_embeds"] = rng.normal(
                size=(b, cfg.vlm_prefix, cfg.d_model)).astype(np.float32)
        return jax.tree.map(jnp.asarray, batch)

    return at


def train(arch: str, *, steps: int, global_batch: int, seq_len: int,
          smoke: bool = True, mesh_kind: str = "host",
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          peak_lr: float = 3e-3, log_every: int = 10) -> dict:
    cfg = get_config(arch, smoke=smoke)
    tc = ts.TrainConfig(
        opt=OptConfig(peak_lr=peak_lr, warmup_steps=max(steps // 20, 5),
                      total_steps=steps),
        loss_chunk=min(512, seq_len),
        q_chunk=min(512, seq_len), kv_chunk=min(512, seq_len))
    dc = lm_data.DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                            global_batch=global_batch)
    batch_at = make_batch_fn(cfg, dc)

    mesh = None
    if mesh_kind == "prod":
        mesh = make_production_mesh()
    elif mesh_kind == "host" and len(jax.devices()) > 1:
        mesh = make_host_mesh()

    step_fn = ts.make_train_step(cfg, tc)
    rules = sharding.TRAIN_RULES
    monitor = ft.StragglerMonitor()
    acp = ck.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None

    def init_state():
        state = ts.init_train_state(jax.random.key(0), cfg, tc)
        if mesh is not None:
            st_sh = param_specs.state_shardings(state, mesh, rules)
            state = jax.device_put(state, st_sh)
        return state

    state = None
    start = 0
    if acp and ck.latest_step(ckpt_dir) is not None:
        target = jax.eval_shape(lambda: ts.init_train_state(
            jax.random.key(0), cfg, tc))
        sh = (param_specs.state_shardings(target, mesh, rules)
              if mesh is not None else None)
        state, start = ck.restore(ckpt_dir, target, shardings=sh)
        print(f"resumed from step {start}")
    if state is None:
        state = init_state()

    ctx = sharding.use_rules(mesh, rules) if mesh is not None else None
    if ctx:
        ctx.__enter__()
    try:
        jitted = jax.jit(step_fn, donate_argnums=0)
        losses = []
        for i in range(start, steps):
            t0 = time.perf_counter()
            state, metrics = jitted(state, batch_at(i))
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            monitor.observe("worker0", i, dt)
            if i % log_every == 0 or i == steps - 1:
                print(f"step {i:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt * 1e3:.0f}ms",
                      flush=True)
            if acp and (i + 1) % ckpt_every == 0:
                acp.save(state, i + 1)
        if acp:
            acp.save(state, steps)
            acp.wait()
    finally:
        if ctx:
            ctx.__exit__(None, None, None)
    return {"final_loss": losses[-1], "losses": losses}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="full (non-smoke) config — pod scale only")
    ap.add_argument("--mesh", default="host", choices=["host", "prod", "none"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    train(args.arch, steps=args.steps, global_batch=args.global_batch,
          seq_len=args.seq_len, smoke=not args.full, mesh_kind=args.mesh,
          ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
          peak_lr=args.lr)


if __name__ == "__main__":
    main()
