"""Fleet serving driver: multi-host failover + fleet-swap harness.

    python -m repro.launch.serve_fleet --smoke
    python -m repro.launch.serve_fleet --hosts 3 --tenants 2 \
        --kill-host 1 --swap-at 8 --check
    python -m repro.launch.serve_fleet --hosts 4 --requests 8 \
        --metrics-json fleet_metrics.json

Builds one source-of-truth :class:`~repro.serve.registry.RefDBRegistry`
database, spins up a :class:`~repro.serve.fleet.FleetController` with
``--hosts`` simulated host replicas (each its own mirror registry +
tenant router + metrics registry), and drives multi-tenant traffic
through the fleet.  Mid-run it can

* **kill a host** (``--kill-host K``; ``-1`` picks the host with the
  most in-flight requests): every affected request is re-submitted on a
  surviving replica, and with ``--check`` each rerouted report is
  verified bit-identical to a sequential run — the determinism argument
  that makes fleet failover safe;
* **fleet-swap** (``--swap-at T``: after the T-th submission an
  add-species delta publishes and the fleet runs its two-phase swap) —
  prepare pins the new version on every host before any router flips,
  and the old version's source pins are only released after every host
  drains (asserted here: the driver waits for retire, then shows the
  source registry's pin table).

``--metrics-json`` writes the merged fleet snapshot — every per-host
series carries a ``host`` label, alongside the controller's fleet
gauges.  ``--smoke`` shrinks everything to CI size (implies ``--check``,
an auto kill, and a mid-run swap).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro.core import HDSpace
from repro.genomics import synth
from repro.pipeline import (ArraySource, ProfilerConfig, ProfilingSession,
                            available_backends)
from repro.serve import FleetController, RefDBRegistry
from repro.serve.fleet import HostState


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def drive(*, config: ProfilerConfig, num_species: int, genome_len: int,
          hosts: int, tenants: int, requests_per_tenant: int,
          reads_per_request: int, workers_per_host: int = 1,
          kill_host: int | None = None, swap_at: int | None = None,
          check: bool = False, metrics_json: str | None = None) -> dict:
    """Run the fleet experiment; returns the summary dict."""
    spec = synth.CommunitySpec(num_species=num_species,
                               genome_len=genome_len, seed=7)
    total = tenants * requests_per_tenant
    genomes, toks, lens, _, _ = synth.make_sample(
        spec, num_reads=total * reads_per_request)
    rng = np.random.default_rng(spec.seed + 1)
    delta_genomes = {"sp_delta": rng.integers(0, 4, genome_len,
                                              dtype=np.int32)}

    source_reg = RefDBRegistry(root=None)
    t0 = time.perf_counter()
    source_reg.create("food", genomes, config)
    print(f"backend {config.backend} | RefDB food:v1 build "
          f"{time.perf_counter() - t0:.2f}s | fleet of {hosts} host(s), "
          f"{tenants} tenant(s) x {requests_per_tenant} requests")

    fleet = FleetController(source_reg, hosts=hosts,
                            workers_per_host=workers_per_host)
    names = [f"tenant{i}" for i in range(tenants)]
    for name in names:
        fleet.add_tenant(name, "food", max_active=2, max_queue=total)

    sources = [ArraySource(toks[i::total], lens[i::total])
               for i in range(total)]
    handles = []
    killed = rerouted = None
    swap_versions: tuple[int, int] | None = None
    t0 = time.perf_counter()
    with fleet:
        kill_at = total // 3 if kill_host is not None else None
        for i, src in enumerate(sources):
            if kill_at is not None and i == kill_at:
                killed = _pick_victim(fleet, handles, kill_host)
                rerouted = fleet.kill_host(killed)
                print(f"killed {killed} after {i} submissions; "
                      f"rerouted {len(rerouted)} request(s): "
                      f"{' '.join(rerouted) or '(none in flight)'}")
            if swap_at is not None and i == swap_at:
                old_v = source_reg.current("food").version
                snap = source_reg.apply_delta("food", add=delta_genomes)
                new_v = fleet.fleet_swap("food", version=snap.version)
                swap_versions = (old_v, new_v)
                print(f"fleet swap v{old_v} -> v{new_v} after {i} "
                      f"submissions ({2 * len(fleet.healthy_hosts())} "
                      f"phase steps)")
            handles.append(fleet.submit(src, tenant=names[i % tenants],
                                        request_id=f"req-{i}"))
        reports = [h.result(timeout=600) for h in handles]
        if swap_versions is not None:
            fleet.wait_retired("food", swap_versions[0], timeout=600)
            print(f"retire complete: source pins now "
                  f"{source_reg.pins('food')} (old v{swap_versions[0]} "
                  f"gc-eligible)")
        if metrics_json is not None:
            merged = fleet.metrics_snapshot()
            payload = {"schema": 1, "hosts": hosts,
                       "metrics": merged.snapshot()}
            path = pathlib.Path(metrics_json)
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(payload, indent=2, sort_keys=True))
            print(f"wrote merged fleet metrics snapshot to {path}")
    wall = time.perf_counter() - t0

    lat = [h._attempts[-1][1].latency_s for h in handles]
    total_reads = sum(r.total_reads for r in reports)
    by_host: dict[str, int] = {}
    for h in handles:
        by_host[h.host] = by_host.get(h.host, 0) + 1
    summary = {
        "backend": config.backend,
        "hosts": hosts,
        "tenants": tenants,
        "requests": total,
        "reads": total_reads,
        "wall_s": wall,
        "reads_per_s": total_reads / max(wall, 1e-9),
        "p50_ms": _percentile(lat, 50) * 1e3,
        "p99_ms": _percentile(lat, 99) * 1e3,
        "by_host": dict(sorted(by_host.items())),
        "killed": killed,
        "rerouted": rerouted or [],
        "swap": swap_versions,
    }
    print(f"fleet: {total} requests ({total_reads} reads) in {wall:.2f}s | "
          f"{summary['reads_per_s']:.0f} reads/s aggregate | "
          f"p50 {summary['p50_ms']:.0f}ms p99 {summary['p99_ms']:.0f}ms | "
          f"placement {summary['by_host']}")

    if check:
        sessions: dict[int, ProfilingSession] = {}

        def sequential(version: int) -> ProfilingSession:
            if version not in sessions:
                s = ProfilingSession(config)
                s.adopt_refdb(source_reg.snapshot("food", version).db)
                sessions[version] = s
            return sessions[version]

        failing = []
        for h, src, rep in zip(handles, sources, reports):
            if rep.to_json() != sequential(h.version).profile(src).to_json():
                failing.append(h.request_id)
        if failing:
            print(f"CHECK FAILED: {len(failing)} report(s) diverged from "
                  f"sequential runs: {' '.join(failing)}", file=sys.stderr)
            raise SystemExit(1)
        n_re = sum(h.rerouted for h in handles)
        print(f"check OK: all {total} reports bit-identical to sequential "
              f"runs on their admitted versions ({n_re} rerouted)")
    return summary


def _pick_victim(fleet: FleetController, handles, kill_host: int) -> str:
    """The host to kill: an explicit index, or (``-1``) the healthy host
    carrying the most live requests — guaranteeing the kill actually
    hits in-flight work."""
    if kill_host >= 0:
        return f"host{kill_host}"
    live: dict[str, int] = {}
    for h in handles:
        if not h.done:
            live[h.host] = live.get(h.host, 0) + 1
    healthy = [hid for hid in live
               if fleet.host(hid).state is HostState.HEALTHY]
    if healthy:
        return max(healthy, key=lambda hid: live[hid])
    return fleet.healthy_hosts()[0]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hosts", type=int, default=3)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per tenant")
    ap.add_argument("--reads-per-request", type=int, default=256)
    ap.add_argument("--workers", type=int, default=1,
                    help="pump threads per host")
    ap.add_argument("--kill-host", type=int, default=None, metavar="K",
                    help="kill hostK a third of the way through the"
                         " submissions (-1: auto-pick the busiest host);"
                         " affected requests fail over to survivors")
    ap.add_argument("--swap-at", type=int, default=None, metavar="T",
                    help="publish an add-species delta and run the"
                         " two-phase fleet swap after the T-th submission")
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--ngram", type=int, default=16)
    ap.add_argument("--window", type=int, default=4096)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--species", type=int, default=8)
    ap.add_argument("--genome-len", type=int, default=40_000)
    ap.add_argument("--backend", default="reference",
                    choices=available_backends())
    ap.add_argument("--check", action="store_true",
                    help="verify every report (rerouted ones included)"
                         " bit-identical to a sequential run on its"
                         " admitted database version")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the merged fleet metrics snapshot"
                         " (per-host labelled series + fleet gauges) here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run: 3 hosts x 2 tenants, one"
                         " auto-picked host kill, one fleet swap,"
                         " --check on")
    args = ap.parse_args()

    if args.smoke:
        config = ProfilerConfig(
            space=HDSpace(dim=512, ngram=8, z_threshold=3.0),
            window=1024, batch_size=32, backend=args.backend)
        drive(config=config, num_species=4, genome_len=8_000,
              hosts=3, tenants=2, requests_per_tenant=6,
              reads_per_request=32, workers_per_host=args.workers,
              kill_host=-1, swap_at=8, check=True,
              metrics_json=args.metrics_json)
        return
    config = ProfilerConfig(
        space=HDSpace(dim=args.dim, ngram=args.ngram),
        window=args.window, batch_size=args.batch_size,
        backend=args.backend)
    drive(config=config, num_species=args.species,
          genome_len=args.genome_len, hosts=args.hosts,
          tenants=args.tenants, requests_per_tenant=args.requests,
          reads_per_request=args.reads_per_request,
          workers_per_host=args.workers, kill_host=args.kill_host,
          swap_at=args.swap_at, check=args.check,
          metrics_json=args.metrics_json)


if __name__ == "__main__":
    main()
