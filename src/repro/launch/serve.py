"""Batched LM serving driver: cohort scheduler over prefill/decode steps.

LEGACY: kept working for the seed repo's LM stack; the profiler-first
serving entry point is ``repro.launch.serve_profiler`` (docs/API.md
"Serving").

    python -m repro.launch.serve --arch stablelm-3b --requests 8 --steps 16

Requests are grouped into fixed-shape cohorts (prompts padded to the
cohort max); each cohort prefills once and decodes in lockstep — the
dry-run's decode_32k shape is one production cohort. On real pods the
same driver runs under the decode-rules mesh (seq-sharded KV).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve import serve_step


def serve(arch: str, *, num_requests: int, decode_steps: int,
          prompt_len: int = 32, smoke: bool = True,
          temperature: float = 0.0) -> dict:
    cfg = get_config(arch, smoke=smoke)
    params = lm.init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    max_len = prompt_len + decode_steps + 1

    prefill = jax.jit(serve_step.make_prefill_step(
        cfg, max_len, q_chunk=min(512, prompt_len),
        kv_chunk=min(512, prompt_len)))
    decode = jax.jit(serve_step.make_decode_step(cfg))

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (num_requests, prompt_len)), jnp.int32)
    t0 = time.perf_counter()
    logits, caches = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    key = jax.random.key(1)
    tok = serve_step.sample(logits, key, temperature)
    outs = [tok]
    t0 = time.perf_counter()
    for i in range(decode_steps):
        logits, caches = decode(params, tok, caches,
                                jnp.int32(prompt_len + i))
        key, sub = jax.random.split(key)
        tok = serve_step.sample(logits, sub, temperature)
        outs.append(tok)
    jax.block_until_ready(outs[-1])
    t_decode = time.perf_counter() - t0

    toks_per_s = num_requests * decode_steps / max(t_decode, 1e-9)
    print(f"cohort={num_requests} prefill {t_prefill * 1e3:.0f}ms | "
          f"decode {decode_steps} steps {t_decode * 1e3:.0f}ms "
          f"({toks_per_s:.0f} tok/s)")
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tokens": np.asarray(jnp.stack(outs, axis=1))}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    serve(args.arch, num_requests=args.requests, decode_steps=args.steps,
          prompt_len=args.prompt_len, smoke=not args.full,
          temperature=args.temperature)


if __name__ == "__main__":
    main()
