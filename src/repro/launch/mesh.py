"""Production mesh definition (per assignment spec).

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1D ('data',) mesh (tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
