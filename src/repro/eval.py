"""Profiling accuracy metrics (paper §4.1: precision / recall / L1).

Presence calls compare estimated abundance against ground truth at a
detection threshold; precision = TP/(TP+FP), recall = TP/(TP+FN) over
species presence, exactly the Fig. 2/3 metrics.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ProfileMetrics:
    precision: float
    recall: float
    f1: float
    l1_error: float          # sum |est - truth| over species (0..2)
    tp: int
    fp: int
    fn: int

    def row(self) -> str:
        return (f"precision={self.precision:.3f} recall={self.recall:.3f} "
                f"f1={self.f1:.3f} l1={self.l1_error:.3f}")


def score_profile(est_abundance: np.ndarray, true_abundance: np.ndarray,
                  detect_threshold: float = 0.01) -> ProfileMetrics:
    est = np.asarray(est_abundance, np.float64)
    tru = np.asarray(true_abundance, np.float64)
    called = est >= detect_threshold
    present = tru > 0
    tp = int((called & present).sum())
    fp = int((called & ~present).sum())
    fn = int((~called & present).sum())
    precision = tp / (tp + fp) if tp + fp else 0.0
    recall = tp / (tp + fn) if tp + fn else 0.0
    f1 = (2 * precision * recall / (precision + recall)
          if precision + recall else 0.0)
    return ProfileMetrics(precision=precision, recall=recall, f1=f1,
                          l1_error=float(np.abs(est - tru).sum()),
                          tp=tp, fp=fp, fn=fn)


def read_level_accuracy(hits: np.ndarray, category: np.ndarray,
                        truth: np.ndarray) -> float:
    """Fraction of reads whose hit set contains the true species."""
    r = len(truth)
    correct = hits[np.arange(r), truth]
    return float(correct.mean())
