"""Pipeline parallelism over the 'pod' axis (GPipe-style, shard_map).

The multi-pod mesh exposes a 'pod' axis; by default it is an extra DP
axis, but `pipelined_apply` turns it into pipeline stages: each pod owns a
contiguous run of layers, microbatches stream through stages with
`jax.lax.ppermute` moving activations pod-to-pod.  The schedule is the
classic GPipe fill-drain loop implemented as a lax.scan over
(num_microbatches + num_stages - 1) ticks, so bubbles are explicit and
the collective is a single neighbour permute per tick — exactly what the
inter-pod DCI can sustain.

This module is deliberately self-contained (layer params stacked on a
leading 'stage' dim) and tested on a small host mesh; the production
launcher enables it with ModelConfig-agnostic stage_fn.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_stages(n_layers: int, n_stages: int) -> list[tuple[int, int]]:
    """Contiguous [start, end) layer ranges per stage (balanced)."""
    base, rem = divmod(n_layers, n_stages)
    out, start = [], 0
    for s in range(n_stages):
        size = base + (1 if s < rem else 0)
        out.append((start, start + size))
        start += size
    return out


def pipelined_apply(stage_params, x: jax.Array, stage_fn: Callable,
                    *, mesh: Mesh, axis: str = "pod",
                    num_microbatches: int) -> jax.Array:
    """Run x through all pipeline stages.

    Args:
      stage_params: pytree with leading dim = n_stages (sharded over axis).
      x: (B, ...) global batch; split into microbatches along dim 0.
      stage_fn: (params_for_stage, microbatch) -> microbatch output
        (same shape — standard homogeneous-stage pipeline).
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % num_microbatches == 0
    mb = b // num_microbatches
    micro = x.reshape(num_microbatches, mb, *x.shape[1:])

    def per_pod(params_local, micro_local):
        # params_local: stage_params for THIS pod (leading dim 1) ->
        # squeeze; micro_local: full microbatch stream (replicated).
        params_me = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        ticks = num_microbatches + n_stages - 1

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 injects microbatch t (if in range); others take buf.
            inject = jnp.where(t < num_microbatches,
                               jnp.clip(t, 0, num_microbatches - 1), 0)
            x_in = jnp.where(stage == 0, micro_local[inject], buf)
            active = (t - stage >= 0) & (t - stage < num_microbatches)
            y = stage_fn(params_me, x_in)
            y = jnp.where(active, y, buf)
            # pass to the next stage (ring; last stage's output wraps to 0
            # where it is ignored)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage writes its finished microbatch
            done_idx = t - (n_stages - 1)
            is_done = (stage == n_stages - 1) & (done_idx >= 0)
            outputs = jax.lax.cond(
                is_done,
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, y[None], jnp.maximum(done_idx, 0), axis=0),
                lambda o: o, outputs)
            return (nxt, outputs), None

        buf0 = jnp.zeros_like(micro_local[0])
        outs0 = jnp.zeros_like(micro_local)
        (_, outputs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(ticks))
        # Only the last stage holds real outputs; masked psum broadcasts
        # them so the result is replicated over the pipeline axis.
        mask = (stage == n_stages - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * mask, axis)

    from repro.distributed.sharding import shard_map_compat
    specs_params = jax.tree.map(lambda _: P(axis), stage_params)
    out = shard_map_compat(
        per_pod, mesh=mesh,
        in_specs=(specs_params, P()), out_specs=P(),
    )(stage_params, micro)
    return out.reshape(b, *x.shape[1:])
