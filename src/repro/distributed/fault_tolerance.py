"""Fault tolerance: heartbeats, failure detection, restart, stragglers.

At thousand-node scale the framework must assume *some* worker is always
unhealthy.  The pieces here are host-side and deterministic, so they are
fully unit-testable on CPU:

* :class:`HeartbeatRegistry` — workers ping; the coordinator marks workers
  dead after ``timeout`` and triggers a restart decision.
* :class:`StragglerMonitor` — per-step duration tracking with a robust
  (median + k*MAD) deadline; repeated offenders are reported for
  replacement (on TPU pods the practical mitigation is rescheduling the
  slice; we surface the decision, the scheduler acts).
* :func:`run_with_restarts` — the crash-safe training driver: steps are a
  pure function of (state, step_index), data order is derived from the
  step index, so resume-from-checkpoint replays identically.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable

import numpy as np


class HeartbeatRegistry:
    def __init__(self, workers: Iterable[str], timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.last_seen = {w: clock() for w in workers}

    def ping(self, worker: str) -> None:
        self.last_seen[worker] = self.clock()

    def dead_workers(self) -> list[str]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout]

    def healthy(self) -> bool:
        return not self.dead_workers()


@dataclasses.dataclass
class StragglerReport:
    worker: str
    step: int
    duration: float
    deadline: float


class StragglerMonitor:
    """Flags workers whose step time exceeds median + k * MAD."""

    def __init__(self, k: float = 5.0, window: int = 32,
                 min_samples: int = 8):
        self.k = k
        self.window = window
        self.min_samples = min_samples
        self.history: list[float] = []
        self.offenders: dict[str, int] = {}

    def deadline(self) -> float:
        if len(self.history) < self.min_samples:
            return float("inf")
        h = np.asarray(self.history[-self.window:])
        med = float(np.median(h))
        mad = float(np.median(np.abs(h - med))) + 1e-9
        return med + self.k * mad

    def observe(self, worker: str, step: int, duration: float
                ) -> StragglerReport | None:
        dl = self.deadline()
        self.history.append(duration)
        if duration > dl:
            self.offenders[worker] = self.offenders.get(worker, 0) + 1
            return StragglerReport(worker, step, duration, dl)
        return None

    def should_replace(self, worker: str, strikes: int = 3) -> bool:
        return self.offenders.get(worker, 0) >= strikes


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0
    completed_steps: int = 0
    resumed_from: list[int] = dataclasses.field(default_factory=list)


def run_with_restarts(*, init_fn, step_fn, save_fn, restore_fn,
                      total_steps: int, checkpoint_every: int,
                      max_restarts: int = 10) -> tuple[object, RestartStats]:
    """Crash-safe driver: (re)loads the newest checkpoint and replays.

    step_fn(state, i) may raise (simulated node failure); the driver
    restores and continues.  Determinism contract: step_fn derives its
    batch from ``i`` alone, so a replayed step is bit-identical.
    """
    stats = RestartStats()
    attempt = 0
    while True:
        try:
            restored = restore_fn()
            if restored is None:
                state, start = init_fn(), 0
            else:
                state, start = restored
                stats.resumed_from.append(start)
            for i in range(start, total_steps):
                state = step_fn(state, i)
                stats.completed_steps = i + 1
                if (i + 1) % checkpoint_every == 0:
                    save_fn(state, i + 1)
            return state, stats
        except Exception:
            attempt += 1
            stats.restarts += 1
            if attempt > max_restarts:
                raise
