"""Logical-axis sharding rules (GSPMD) for params and activations.

Models annotate tensors with *logical* axis names; a rules table maps
logical names to mesh axes per execution mode.  This is the single place
where DP / FSDP / TP / EP / SP decisions live:

* ``train``   — batch over (pod, data); FSDP shards the ff/vocab "fsdp"
  dim of params over data; TP shards heads/ff/experts/vocab over model.
* ``prefill`` — batch over (pod, data); TP over model; params TP +
  FSDP (weights are all-gathered per layer by XLA as needed).
* ``decode``  — batch over (pod, data); KV cache sequence over model
  (flash-decoding combine in serve/decode_attn.py); TP over model.

``use_rules`` installs a rules table into a context; ``logical`` and
``constrain`` are no-ops when no mesh is active, so all model code runs
unchanged on a single CPU device (tests) and under pjit (dry-run).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                    # jax >= 0.6 moved it to the top level
    from jax import shard_map as _shard_map_raw  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map_raw

_state = threading.local()

Rules = dict[str, tuple[str, ...] | str | None]

# Logical axis vocabulary used by the models:
#   batch, seq, embed, heads, kv_heads, qk_dim, v_dim, ff, experts,
#   expert_group, capacity, vocab, kv_seq, state, conv, fsdp(=param ff dim)

TRAIN_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": None,        # kv heads often < model axis; keep replicated
    "ff": "model",
    "experts": "model",
    "expert_group": ("pod", "data"),
    "vocab": "model",
    "kv_seq": None,
    "fsdp": "data",          # FSDP: shard the non-TP param dim over data
    "state": None,
    "ssm_heads": "model",
    # Megatron-style sequence parallelism: the residual stream between
    # blocks lives sequence-sharded over 'model'; XLA inserts the
    # all-gather before qkv/ffn and the reduce-scatter after wo/w_out.
    # This is what keeps 95 layers of saved remat residuals inside HBM.
    "residual_seq": "model",
}

PREFILL_RULES: Rules = dict(TRAIN_RULES, fsdp="data")

# Decode: params replicated over 'data' (fsdp=None) — FSDP sharding at
# decode costs a full per-token weight all-gather (§Perf H2a); TP shards
# alone fit HBM for every assigned arch once the KV cache is seq-sharded.
DECODE_RULES: Rules = dict(TRAIN_RULES, kv_seq="model", fsdp=None,
                           residual_seq=None)

# Demeter profiling: the AM search (queries x prototypes agreement) is
# partitioned over the *prototype* axis — the in-memory-HDC analogue of
# splitting the associative memory across crossbar arrays.  Reads and the
# packed HD dimension stay replicated: per-shard partial species scores
# merge with an elementwise max (classifier.merge_scores), so the only
# cross-device traffic is a (B, num_species) pmax.
PROFILE_RULES: Rules = {
    "reads": None,            # query batch: replicated (every shard scores it)
    "protos": "shard",        # prototype rows: split across the mesh
    "hd_words": None,         # packed HD dim: contiguous within a shard
    "species": None,          # per-species scores: replicated after merge
}


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across jax spellings.

    Pallas kernels have no replication rule, so the check must be
    disabled for Pallas-based shard bodies; the flag is ``check_vma`` on
    current jax and ``check_rep`` on older releases.  Import location
    (``jax.shard_map`` vs ``jax.experimental.shard_map``) is handled at
    module import.  Used by :mod:`repro.pipeline.sharded` and the
    multi-device mesh tests.
    """
    for flag in ("check_vma", "check_rep"):
        try:
            return _shard_map_raw(f, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, **{flag: False})
        except TypeError:
            continue
    return _shard_map_raw(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """``jax.sharding.AbstractMesh`` across its two constructor spellings.

    Current jax takes ``(axis_sizes, axis_names)``; jax <= 0.4.x takes a
    single ``((name, size), ...)`` shape tuple.  Device-free: resolves
    sharding rules without any real mesh (used by ``tests/``).
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def make_profile_mesh(num_shards: int | None = None) -> Mesh:
    """1-D ``('shard',)`` mesh over the first ``num_shards`` local devices.

    The profiling analogue of ``launch.mesh``: prototype-axis model
    parallelism only (reads are cheap to replicate; the AM is not).
    """
    devices = jax.devices()
    n = len(devices) if num_shards is None else num_shards
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"num_shards must be in [1, {len(devices)}] (local devices), "
            f"got {n}")
    return Mesh(np.asarray(devices[:n]), ("shard",))


@contextlib.contextmanager
def use_rules(mesh: Mesh | None, rules: Rules | None):
    """Activate (mesh, rules) for logical()/constrain() in this thread."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules) if mesh is not None else None
    try:
        yield
    finally:
        _state.ctx = prev


def active_mesh() -> Mesh | None:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def _resolve(axes: Sequence[str | None]) -> P:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return P()
    mesh, rules = ctx
    spec = []
    for ax in axes:
        if ax is None:
            spec.append(None)
            continue
        target = rules.get(ax, None)
        if target is None:
            spec.append(None)
        elif isinstance(target, tuple):
            spec.append(tuple(t for t in target if t in mesh.axis_names))
        else:
            spec.append(target if target in mesh.axis_names else None)
    return P(*spec)


def spec_for(axes: Sequence[str | None]) -> P:
    """PartitionSpec for a tuple of logical axis names (public)."""
    return _resolve(axes)


def sharding_for(axes: Sequence[str | None]) -> NamedSharding | None:
    mesh = active_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, _resolve(axes))


def constrain(x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _resolve(axes)))


def _divisible(dim: int, mesh: Mesh, target) -> bool:
    if target is None:
        return True
    names = target if isinstance(target, tuple) else (target,)
    size = 1
    for n in names:
        if n in mesh.axis_names:
            size *= mesh.shape[n]
    return size > 0 and dim % size == 0


def axis_size(logical: str) -> int:
    """Mesh size behind a logical axis in the active rules (1 if none)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return 1
    mesh, rules = ctx
    target = rules.get(logical)
    if target is None:
        return 1
    names = target if isinstance(target, tuple) else (target,)
    size = 1
    for n in names:
        if n in mesh.axis_names:
            size *= mesh.shape[n]
    return size


def safe_spec(shape: tuple[int, ...], axes: Sequence[str | None]) -> P:
    """Like spec_for, but drops axes whose mesh size doesn't divide the dim.

    Keeps lowering robust when e.g. kv_heads=4 meets model=16.
    """
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return P()
    mesh, rules = ctx
    out = []
    for dim, ax in zip(shape, axes):
        target = rules.get(ax) if ax else None
        if isinstance(target, tuple):
            target = tuple(t for t in target if t in mesh.axis_names) or None
        elif target is not None and target not in mesh.axis_names:
            target = None
        out.append(target if target and _divisible(dim, mesh, target) else None)
    return P(*out)


def constrain_safe(x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    mesh = active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, safe_spec(x.shape, axes)))
