"""Elastic scaling: move a training job between mesh sizes.

Checkpoints store full host arrays (checkpoint/checkpointer.py), so
elasticity reduces to (1) recomputing shardings for the new mesh and
(2) rescaling schedule-coupled quantities.  ``reshard_plan`` validates
that every parameter still divides the new mesh axes (the name-based
rules drop non-dividing axes automatically) and reports what changed —
at 1000+ nodes you want the delta logged, not silent.
"""

from __future__ import annotations

import dataclasses

from jax.sharding import Mesh

from repro.distributed import param_specs, sharding


@dataclasses.dataclass(frozen=True)
class ReshardReport:
    n_leaves: int
    changed: tuple[str, ...]          # leaves whose PartitionSpec changed
    dropped_axes: tuple[str, ...]     # leaves that lost a sharded axis


def reshard_plan(state_shapes, old_mesh: Mesh, new_mesh: Mesh,
                 rules: sharding.Rules) -> tuple[object, ReshardReport]:
    """New-mesh shardings for a TrainState + a human-readable delta report."""
    import jax

    old = param_specs.state_shardings(state_shapes, old_mesh, rules)
    new = param_specs.state_shardings(state_shapes, new_mesh, rules)

    changed, dropped = [], []
    old_flat = jax.tree_util.tree_flatten_with_path(old)[0]
    new_flat = jax.tree_util.tree_flatten_with_path(new)[0]
    for (path, o), (_, n) in zip(old_flat, new_flat):
        key = "/".join(str(getattr(e, "key", e)) for e in path)
        if o.spec != n.spec:
            changed.append(key)
            o_axes = {a for part in o.spec if part
                      for a in (part if isinstance(part, tuple) else (part,))}
            n_axes = {a for part in n.spec if part
                      for a in (part if isinstance(part, tuple) else (part,))}
            if o_axes - n_axes:
                dropped.append(key)
    return new, ReshardReport(n_leaves=len(new_flat),
                              changed=tuple(changed),
                              dropped_axes=tuple(dropped))


def rescale_batch(global_batch: int, old_data_shards: int,
                  new_data_shards: int, *, keep_global: bool = True) -> int:
    """Elastic batch policy: keep the global batch (preferred — optimizer
    hyperparameters stay valid) as long as it divides the new data axis."""
    if keep_global:
        if global_batch % new_data_shards != 0:
            raise ValueError(
                f"global batch {global_batch} does not divide new data "
                f"axis {new_data_shards}; pick a microbatch-compatible size")
        return global_batch
    per = global_batch // old_data_shards
    return per * new_data_shards
