"""Name-based PartitionSpecs for parameter / optimizer / cache pytrees.

Every model parameter has a stable leaf name (wq, w_in, e_out, ...); this
module maps names to logical axes and resolves them against the active
(mesh, rules) with divisibility checks, yielding NamedShardings for pjit
in_shardings/out_shardings.  Stacked leading layer dims (from scan-stacked
segments) are detected by rank and get a replicated prefix axis.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import Rules

# logical axes per parameter leaf name (unstacked rank)
PARAM_AXES: dict[str, tuple] = {
    "tok_embed": ("vocab", "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    "wq": ("fsdp", "heads", None),
    "wk": ("fsdp", "kv_heads", None),
    "wv": ("fsdp", "kv_heads", None),
    "wo": ("heads", None, "fsdp"),
    "w_dkv": ("fsdp", None),
    "w_kr": ("fsdp", None),
    "w_uk": (None, "heads", None),
    "w_uv": (None, "heads", None),
    "w_in": ("fsdp", "ff"),
    "w_gate": ("fsdp", "ff"),
    "w_out": ("ff", "fsdp"),
    "router": (None, None),
    "e_in": ("experts", "fsdp", None),
    "e_gate": ("experts", "fsdp", None),
    "e_out": ("experts", None, "fsdp"),
    "in_proj": ("fsdp", None),
    "out_proj": (None, "fsdp"),
    "conv_w": (None, None),
    "conv_b": (None,),
    "a_log": (None,),
    "d_skip": (None,),
    "dt_bias": (None,),
    "scale": (None,),
    "bias": (None,),
    "branch_scale": (None,),
}

CACHE_AXES: dict[str, tuple] = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "c": ("batch", "kv_seq", None),
    "kr": ("batch", "kv_seq", None),
    "kpos": ("batch", "kv_seq"),
    "xk": ("batch", "kv_seq", "kv_heads", None),
    "xv": ("batch", "kv_seq", "kv_heads", None),
    "xkpos": ("batch", "kv_seq"),
    "conv": ("batch", None, None),
    "state": ("batch", "ssm_heads", None, None),
}


def _axis_size(mesh: Mesh, target) -> int:
    names = target if isinstance(target, tuple) else (target,)
    size = 1
    for n in names:
        if n in mesh.axis_names:
            size *= mesh.shape[n]
    return size


def _resolve_leaf(shape: tuple[int, ...], axes: tuple, mesh: Mesh,
                  rules: Rules) -> P:
    ndim = len(shape)
    if ndim > len(axes):                 # stacked (scan) leading dims
        axes = (None,) * (ndim - len(axes)) + tuple(axes)
    axes = axes[:ndim]
    out = []
    for dim, ax in zip(shape, axes):
        target = rules.get(ax) if ax else None
        if isinstance(target, tuple):
            target = tuple(t for t in target if t in mesh.axis_names) or None
        elif target is not None and target not in mesh.axis_names:
            target = None
        if target is not None and dim % max(_axis_size(mesh, target), 1) == 0 \
                and _axis_size(mesh, target) > 1:
            out.append(target)
        else:
            out.append(None)
    return P(*out)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def tree_pspecs(tree, mesh: Mesh, rules: Rules, table: dict[str, tuple],
                default: tuple = ()) -> object:
    """Map a pytree of arrays/ShapeDtypeStructs to a pytree of NamedShardings."""
    def one(path, leaf):
        name = _leaf_name(path)
        axes = table.get(name, default)
        return NamedSharding(mesh, _resolve_leaf(tuple(leaf.shape), axes,
                                                 mesh, rules))
    return jax.tree_util.tree_map_with_path(one, tree)


def param_shardings(params, mesh: Mesh, rules: Rules):
    return tree_pspecs(params, mesh, rules, PARAM_AXES)


def state_shardings(state, mesh: Mesh, rules: Rules):
    """TrainState {params, opt{m,v}, step} shardings (opt mirrors params)."""
    return {
        "params": param_shardings(state["params"], mesh, rules),
        "opt": {"m": param_shardings(state["opt"]["m"], mesh, rules),
                "v": param_shardings(state["opt"]["v"], mesh, rules)},
        "step": NamedSharding(mesh, P()),
    }


def cache_shardings(caches, mesh: Mesh, rules: Rules):
    return tree_pspecs(caches, mesh, rules, CACHE_AXES)


def batch_shardings(batch, mesh: Mesh, rules: Rules):
    """Input batches: first dim is batch, everything else replicated."""
    def one(path, leaf):
        axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, _resolve_leaf(tuple(leaf.shape), axes,
                                                 mesh, rules))
    return jax.tree_util.tree_map_with_path(one, batch)
