"""Dependency-free metrics core: counters, gauges, bucketed histograms.

The observability substrate for the serving stack.  Acc-Demeter's whole
argument is a per-stage throughput/energy accounting (PAPER.md §5-6);
this module is the software analogue's measurement layer: every serving
component (:class:`~repro.serve.profiler_service.ProfilingService`,
:class:`~repro.serve.router.TenantRouter`,
:class:`~repro.pipeline.session.ProfilingSession`, the ``accel/``
substrate) records into one shared :class:`MetricsRegistry` and a fleet
snapshot attributes cost per pipeline stage.

Design constraints, in priority order:

* **Zero-cost when disabled.**  The default registry is the
  :class:`NullRegistry` singleton: every instrument it hands out is an
  inert no-op object behind the same interface, and hot paths guard any
  real work (``time.perf_counter()``, label merging) behind the
  registry's ``enabled`` flag — so disabled observability costs one
  attribute load per site, which the benchmark smoke's overhead guard
  (:mod:`benchmarks.smoke`) pins at < 2%.
* **Never perturb results.**  All recording is host-side Python; nothing
  here touches a jax trace, so metrics-on and metrics-off runs are
  bit-identical (``tests/test_obs.py`` enforces this per backend).
* **Thread-safe.**  Serving pumps, tenant loaders, and snapshot readers
  race freely; every instrument serializes on one registry lock (the
  instrumented paths record per *cohort*, not per read, so contention is
  negligible).

Instruments are label-keyed like Prometheus: one instrument name owns
many series, one per distinct label set::

    reg = MetricsRegistry()
    lat = reg.histogram("serve_batch_seconds", "cohort latency",
                        unit="s")
    lat.observe(0.012, backend="pallas_fused")
    lat.percentile(99, backend="pallas_fused")

Histograms use *fixed* bucket upper bounds (cumulative-free storage,
constant memory per series) with quantiles estimated by linear
interpolation inside the owning bucket — the standard
Prometheus-histogram estimator.  States of identical bucketing can be
``merge``-d, so per-process registries aggregate across a fleet.

Exposition: :meth:`MetricsRegistry.snapshot` returns a plain-dict JSON
document (with p50/p95/p99 pre-computed per histogram series) and
:meth:`MetricsRegistry.to_prometheus` renders the Prometheus text
format.
"""

from __future__ import annotations

import bisect
import json
import math
import threading
from typing import Iterable, Mapping

#: Default upper bounds for duration histograms, in seconds: 100 µs to
#: 2 minutes, roughly geometric — wide enough for both a single cohort
#: on an accelerator and a whole request draining behind a queue.
TIME_BUCKETS_S = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0)

#: Default upper bounds for ratio-valued histograms (cohort fill, ...).
RATIO_BUCKETS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0)

#: Percentiles pre-computed into every histogram snapshot.
SNAPSHOT_PERCENTILES = (50, 95, 99)


def exponential_buckets(start: float, factor: float, count: int
                        ) -> tuple[float, ...]:
    """``count`` geometric bucket bounds from ``start`` (Prometheus-style)."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    return tuple(start * factor ** i for i in range(count))


def linear_buckets(start: float, width: float, count: int
                   ) -> tuple[float, ...]:
    """``count`` uniform bucket bounds from ``start`` (Prometheus-style)."""
    if width <= 0 or count < 1:
        raise ValueError("need width > 0, count >= 1")
    return tuple(start + width * i for i in range(count))


def _label_key(labels: Mapping[str, str]) -> tuple[tuple[str, str], ...]:
    """Canonical (sorted, stringified) series key for a label mapping."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class HistogramState:
    """One histogram series: per-bucket counts + sum over fixed bounds.

    ``bounds`` are ascending *upper* bounds; an observation lands in the
    first bucket whose bound is ``>= value`` (boundary values inclusive,
    Prometheus ``le`` semantics) and anything beyond the last bound goes
    to the overflow bucket.  Values are assumed non-negative (times,
    ratios, counts) — the quantile interpolation uses 0 as the first
    bucket's lower edge.
    """

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Iterable[float]):
        self.bounds = tuple(float(b) for b in bounds)
        if not self.bounds or list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("bucket bounds must be non-empty, unique, "
                             "and ascending")
        self.counts = [0] * (len(self.bounds) + 1)     # + overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, value)] += 1
        self.sum += value
        self.count += 1

    def merge(self, other: "HistogramState") -> None:
        """Fold another series of identical bucketing into this one."""
        if other.bounds != self.bounds:
            raise ValueError(
                f"cannot merge histograms with different bounds "
                f"({len(self.bounds)} vs {len(other.bounds)} buckets)")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count

    def percentile(self, q: float) -> float:
        """Estimate the ``q``-th percentile (0-100) from the buckets.

        Linear interpolation within the owning bucket, with 0 as the
        first bucket's lower edge; ranks landing in the overflow bucket
        clamp to the last finite bound (the estimator cannot see beyond
        it).  NaN when the series is empty.
        """
        if not 0 <= q <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {q}")
        if self.count == 0:
            return math.nan
        rank = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts[:-1]):
            if cum + c >= rank and c > 0:
                lo = 0.0 if i == 0 else self.bounds[i - 1]
                hi = self.bounds[i]
                return lo + (hi - lo) * min(max(rank - cum, 0.0), c) / c
            cum += c
        return self.bounds[-1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def to_dict(self) -> dict:
        d = {"counts": list(self.counts), "sum": self.sum,
             "count": self.count}
        for p in SNAPSHOT_PERCENTILES:
            v = self.percentile(p)
            d[f"p{p}"] = None if math.isnan(v) else v
        return d


class _Instrument:
    """Shared shape of every instrument: name, help, label-keyed series."""

    kind = "untyped"

    def __init__(self, name: str, help: str, unit: str,
                 lock: threading.Lock):
        self.name = name
        self.help = help
        self.unit = unit
        self._lock = lock
        self._series: dict[tuple[tuple[str, str], ...], object] = {}

    # Real instruments report their registry as live.
    enabled = True

    def _new_state(self):
        raise NotImplementedError

    def _state(self, labels: Mapping[str, str]):
        key = _label_key(labels)
        state = self._series.get(key)
        if state is None:
            state = self._series[key] = self._new_state()
        return state

    def series(self) -> dict[tuple[tuple[str, str], ...], object]:
        with self._lock:
            return dict(self._series)

    def labelsets(self) -> list[dict[str, str]]:
        with self._lock:
            return [dict(k) for k in sorted(self._series)]


class _Box:
    """Mutable float cell (counters and gauges share it)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0


class Counter(_Instrument):
    """Monotone accumulator (events, reads, bytes)."""

    kind = "counter"

    def _new_state(self) -> _Box:
        return _Box()

    def inc(self, amount: float = 1, **labels: str) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a gauge")
        with self._lock:
            self._state(labels).value += amount

    def value(self, **labels: str) -> float:
        with self._lock:
            state = self._series.get(_label_key(labels))
            return state.value if state is not None else 0.0

    def total(self) -> float:
        """Sum over every label series."""
        with self._lock:
            return sum(s.value for s in self._series.values())


class Gauge(_Instrument):
    """Set-to-current-value instrument (queue depth, live version)."""

    kind = "gauge"

    def _new_state(self) -> _Box:
        return _Box()

    def set(self, value: float, **labels: str) -> None:
        with self._lock:
            self._state(labels).value = float(value)

    def inc(self, amount: float = 1, **labels: str) -> None:
        with self._lock:
            self._state(labels).value += amount

    def dec(self, amount: float = 1, **labels: str) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: str) -> float:
        with self._lock:
            state = self._series.get(_label_key(labels))
            return state.value if state is not None else 0.0


class Histogram(_Instrument):
    """Fixed-bucket distribution instrument with quantile estimation."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", unit: str = "",
                 buckets: Iterable[float] = TIME_BUCKETS_S,
                 lock: threading.Lock | None = None):
        super().__init__(name, help, unit, lock or threading.Lock())
        self.buckets = tuple(float(b) for b in buckets)
        HistogramState(self.buckets)        # validate once, loudly

    def _new_state(self) -> HistogramState:
        return HistogramState(self.buckets)

    def observe(self, value: float, **labels: str) -> None:
        with self._lock:
            self._state(labels).observe(value)

    def percentile(self, q: float, **labels: str) -> float:
        """Estimated percentile of one series (NaN if never observed)."""
        with self._lock:
            state = self._series.get(_label_key(labels))
            return state.percentile(q) if state is not None else math.nan

    def count(self, **labels: str) -> int:
        with self._lock:
            state = self._series.get(_label_key(labels))
            return state.count if state is not None else 0

    def state(self, **labels: str) -> HistogramState | None:
        with self._lock:
            return self._series.get(_label_key(labels))

    def merged(self) -> HistogramState:
        """All series of this instrument folded into one state."""
        out = HistogramState(self.buckets)
        with self._lock:
            for s in self._series.values():
                out.merge(s)
        return out


class MetricsRegistry:
    """Thread-safe, label-keyed instrument registry.

    ``counter`` / ``gauge`` / ``histogram`` are get-or-create: asking for
    an existing name returns the same instrument (a name used as a
    different kind, or a histogram re-requested with different buckets,
    raises — silent schema drift is how dashboards lie).
    """

    enabled = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: dict[str, _Instrument] = {}

    # -- instrument access --------------------------------------------------
    def counter(self, name: str, help: str = "", unit: str = "") -> Counter:
        return self._get(Counter, name, help, unit)

    def gauge(self, name: str, help: str = "", unit: str = "") -> Gauge:
        return self._get(Gauge, name, help, unit)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets: Iterable[float] = TIME_BUCKETS_S) -> Histogram:
        buckets = tuple(float(b) for b in buckets)
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = Histogram(name, help, unit, buckets,
                                 lock=threading.Lock())
                self._instruments[name] = inst
                return inst
            if not isinstance(inst, Histogram):
                raise ValueError(f"metric {name!r} already registered as "
                                 f"a {inst.kind}")
            if inst.buckets != buckets:
                raise ValueError(f"histogram {name!r} already registered "
                                 f"with different buckets")
            return inst

    def _get(self, cls: type, name: str, help: str, unit: str):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, help, unit, threading.Lock())
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise ValueError(f"metric {name!r} already registered as "
                                 f"a {inst.kind}")
            return inst

    def instruments(self) -> tuple[_Instrument, ...]:
        with self._lock:
            return tuple(self._instruments[n]
                         for n in sorted(self._instruments))

    # -- exposition ---------------------------------------------------------
    def snapshot(self) -> dict:
        """The whole registry as one JSON-ready document.

        Histogram series carry their bucket counts plus pre-computed
        p50/p95/p99 so a dumped snapshot answers latency questions
        without re-deriving anything.
        """
        doc: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for inst in self.instruments():
            series = []
            for key, state in sorted(inst.series().items()):
                entry: dict = {"labels": dict(key)}
                if isinstance(state, HistogramState):
                    entry.update(state.to_dict())
                else:
                    entry["value"] = state.value
                series.append(entry)
            section = doc[inst.kind + "s"]
            section[inst.name] = {"help": inst.help, "unit": inst.unit,
                                  "series": series}
            if isinstance(inst, Histogram):
                section[inst.name]["buckets"] = list(inst.buckets)
        return doc

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        kw.setdefault("sort_keys", True)
        return json.dumps(self.snapshot(), **kw)

    # -- cross-process / cross-host aggregation ------------------------------
    def merge_from(self, other: "MetricsRegistry", **labels: str) -> None:
        """Fold every series of ``other`` into this registry.

        ``labels`` are added to each incoming series' label set — the
        fleet controller folds per-host registries with ``host=<id>`` so
        one merged snapshot keeps the per-host breakdown.  Counter
        values add, gauges set (distinct label sets never collide), and
        histogram states fold bucket-by-bucket via
        :meth:`HistogramState.merge`; instruments are get-or-create by
        name, so repeated merges from the same source double-count —
        merge into a fresh registry per snapshot.
        """
        for inst in other.instruments():
            if isinstance(inst, Histogram):
                mine = self.histogram(inst.name, inst.help, inst.unit,
                                      inst.buckets)
            elif isinstance(inst, Counter):
                mine = self.counter(inst.name, inst.help, inst.unit)
            elif isinstance(inst, Gauge):
                mine = self.gauge(inst.name, inst.help, inst.unit)
            else:
                continue
            if not mine.enabled:           # merging into a NullRegistry
                return
            for key, state in sorted(inst.series().items()):
                merged = {**dict(key), **{str(k): str(v)
                                          for k, v in labels.items()}}
                if isinstance(state, HistogramState):
                    with mine._lock:
                        mine._state(merged).merge(state)
                elif isinstance(inst, Counter):
                    mine.inc(state.value, **merged)
                else:
                    mine.set(state.value, **merged)

    @classmethod
    def merged(cls, parts: Mapping[str, "MetricsRegistry"], *,
               label: str = "host") -> "MetricsRegistry":
        """A fresh registry folding ``parts``, each keyed by a ``label``.

        The fleet-snapshot constructor: ``merged({"host0": reg0, ...})``
        returns one registry whose every series carries a ``host`` label
        naming the registry it came from, with same-name histograms
        sharing buckets merged exactly (per-bucket counts add).
        """
        out = cls()
        for part_key in sorted(parts):
            out.merge_from(parts[part_key], **{label: part_key})
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4) of every series."""
        lines: list[str] = []
        for inst in self.instruments():
            if inst.help:
                lines.append(f"# HELP {inst.name} {inst.help}")
            lines.append(f"# TYPE {inst.name} {inst.kind}")
            for key, state in sorted(inst.series().items()):
                labels = dict(key)
                if isinstance(state, HistogramState):
                    cum = 0
                    for bound, c in zip(inst.buckets, state.counts):
                        cum += c
                        lines.append(_prom_line(
                            inst.name + "_bucket",
                            {**labels, "le": _prom_float(bound)}, cum))
                    lines.append(_prom_line(
                        inst.name + "_bucket", {**labels, "le": "+Inf"},
                        state.count))
                    lines.append(_prom_line(inst.name + "_sum", labels,
                                            state.sum))
                    lines.append(_prom_line(inst.name + "_count", labels,
                                            state.count))
                else:
                    lines.append(_prom_line(inst.name, labels, state.value))
        return "\n".join(lines) + ("\n" if lines else "")


class _NullInstrument:
    """Inert counter+gauge+histogram: the disabled-observability recorder.

    Accepts every recording call and drops it; read-side methods return
    zeros/NaN.  One shared instance backs every instrument name of the
    :class:`NullRegistry`, so disabled components pay construction-time
    nothing and per-event almost-nothing (one no-op method call, and the
    hot paths don't even reach that — they bail on ``enabled``).
    """

    kind = "null"
    enabled = False
    name = help = unit = ""
    buckets = ()

    def inc(self, *a, **k) -> None:
        pass

    def dec(self, *a, **k) -> None:
        pass

    def set(self, *a, **k) -> None:
        pass

    def observe(self, *a, **k) -> None:
        pass

    def value(self, **k) -> float:
        return 0.0

    def total(self) -> float:
        return 0.0

    def count(self, **k) -> int:
        return 0

    def percentile(self, q: float, **k) -> float:
        return math.nan

    def state(self, **k) -> None:
        return None

    def series(self) -> dict:
        return {}

    def labelsets(self) -> list:
        return []


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry(MetricsRegistry):
    """The no-op registry behind the same interface: observability off."""

    enabled = False

    def __init__(self) -> None:
        super().__init__()

    def counter(self, name: str, help: str = "", unit: str = ""):
        return _NULL_INSTRUMENT

    def gauge(self, name: str, help: str = "", unit: str = ""):
        return _NULL_INSTRUMENT

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets: Iterable[float] = TIME_BUCKETS_S):
        return _NULL_INSTRUMENT

    def instruments(self) -> tuple:
        return ()


def _prom_float(v: float) -> str:
    """Shortest faithful rendering (Prometheus prefers 0.005 over 5e-03)."""
    return repr(v) if v != int(v) else str(int(v))


def _prom_escape(v: str) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _prom_line(name: str, labels: Mapping[str, str], value: float) -> str:
    if labels:
        body = ",".join(f'{k}="{_prom_escape(v)}"'
                        for k, v in sorted(labels.items()))
        return f"{name}{{{body}}} {_prom_float(float(value))}"
    return f"{name} {_prom_float(float(value))}"
