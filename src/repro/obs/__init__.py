"""Fleet-wide observability: metrics registry + request tracing.

:mod:`repro.obs.metrics` is the dependency-free metrics core (counters,
gauges, fixed-bucket histograms with percentile estimation, JSON
snapshot + Prometheus text exposition); :mod:`repro.obs.trace` is the
span-based request-tracing layer and the unified request latency clock.
``docs/OBSERVABILITY.md`` catalogues every metric and span the serving
stack emits.

Observability is **opt-in and zero-cost when disabled**: the process
default is the :class:`~repro.obs.metrics.NullRegistry` /
:class:`~repro.obs.trace.NullTraceRecorder` pair — no-op recorders
behind the real interface — and instrumented components resolve the
globals at construction time::

    from repro import obs
    reg = obs.enable_metrics()              # before building the stack
    rec = obs.enable_tracing(sample=8)
    ...  # construct sessions / services / routers, serve traffic
    json.dump(reg.snapshot(), fh)
    print(reg.to_prometheus())
    traces = rec.to_dicts()

Components also accept an explicit ``metrics=`` / ``tracer=`` argument
(tests use private registries this way); ``None`` means "the global
default at construction time".  Nothing here ever enters a jax trace,
so enabling observability cannot perturb bit-exactness — and
:func:`jax_trace` is the separate, explicitly opt-in
``jax.profiler`` capture for kernel-level timelines.
"""

from __future__ import annotations

import contextlib
import pathlib

from repro.obs.metrics import (Counter, Gauge, Histogram, HistogramState,
                               MetricsRegistry, NullRegistry, RATIO_BUCKETS,
                               TIME_BUCKETS_S, exponential_buckets,
                               linear_buckets)
from repro.obs.trace import (NullTraceRecorder, RequestTimeline, Span, Trace,
                             TraceRecorder, assemble_trace)

#: The process-wide disabled-mode singletons.
NULL_METRICS = NullRegistry()
NULL_TRACER = NullTraceRecorder()

_metrics: MetricsRegistry = NULL_METRICS
_tracer: TraceRecorder = NULL_TRACER


def enable_metrics(registry: MetricsRegistry | None = None
                   ) -> MetricsRegistry:
    """Install ``registry`` (default: a fresh one) as the global default.

    Components constructed *after* this call record into it; already-
    constructed components keep whatever they resolved.
    """
    global _metrics
    _metrics = registry if registry is not None else MetricsRegistry()
    return _metrics


def enable_tracing(sample: int = 8,
                   recorder: TraceRecorder | None = None) -> TraceRecorder:
    """Install a trace recorder sampling the first ``sample`` requests."""
    global _tracer
    _tracer = recorder if recorder is not None else TraceRecorder(sample)
    return _tracer


def disable() -> None:
    """Reset both globals to the no-op recorders (observability off)."""
    global _metrics, _tracer
    _metrics = NULL_METRICS
    _tracer = NULL_TRACER


def metrics() -> MetricsRegistry:
    """The current global metrics registry (Null when disabled)."""
    return _metrics


def tracer() -> TraceRecorder:
    """The current global trace recorder (Null when disabled)."""
    return _tracer


def resolve_metrics(explicit: MetricsRegistry | None) -> MetricsRegistry:
    """Constructor helper: an explicit registry, or the global default."""
    return explicit if explicit is not None else _metrics


def resolve_tracer(explicit: TraceRecorder | None) -> TraceRecorder:
    """Constructor helper: an explicit recorder, or the global default."""
    return explicit if explicit is not None else _tracer


@contextlib.contextmanager
def jax_trace(log_dir: str | pathlib.Path | None):
    """Opt-in ``jax.profiler`` capture around a hot path.

    ``None`` is a no-op (the default everywhere), so callers can wrap
    their serving loop unconditionally::

        with obs.jax_trace(args.jax_profile):
            router.run_until_idle()

    With a directory, the device/XLA timeline lands there for TensorBoard
    or Perfetto — this is the only observability feature that touches
    jax, and it is never on unless a path is passed.
    """
    if log_dir is None:
        yield
        return
    import jax

    with jax.profiler.trace(str(log_dir)):
        yield


__all__ = [
    "Counter", "Gauge", "Histogram", "HistogramState", "MetricsRegistry",
    "NullRegistry", "RATIO_BUCKETS", "TIME_BUCKETS_S",
    "exponential_buckets", "linear_buckets",
    "NullTraceRecorder", "RequestTimeline", "Span", "Trace",
    "TraceRecorder", "assemble_trace",
    "NULL_METRICS", "NULL_TRACER",
    "enable_metrics", "enable_tracing", "disable", "metrics", "tracer",
    "resolve_metrics", "resolve_tracer", "jax_trace",
]
