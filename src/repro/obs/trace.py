"""Span-based request tracing: where a slow read actually spent its time.

A serving request's life has five phases — admission (queued behind the
service door), scheduling wait (active but not yet in a cohort), cohort
execution, accumulation (demux into its streaming report), finalize —
and ``latency_s`` alone cannot say which one ate the budget.  This
module records the phase boundaries per request and assembles them into
a **trace**: a root ``request`` span plus contiguous child spans, each
with wall-clock and monotonic timestamps and a parent id.

The recording side is deliberately tiny.  Every request owns a
:class:`RequestTimeline` — a dict of monotonic marks, one per phase
boundary — that the service stamps as the request moves through the
pump.  The timeline is *also* the single latency clock: ``latency_s``,
``queue_wait_s`` and ``service_s`` on request handles all derive from
it, so the queue-wait/service split is consistent everywhere (the
accounting that used to be duplicated between ``serve/router.py`` and
``serve/profiler_service.py``).

Because consecutive marks tile the interval from submit to terminal,
the child spans of an assembled trace sum *exactly* to the request's
end-to-end latency — the invariant the serving acceptance test checks.

:class:`TraceRecorder` keeps the first ``sample`` completed traces
(cancelled and failed requests included: their traces simply stop at
the last phase reached).  The :class:`NullTraceRecorder` singleton is
the disabled mode — same interface, records nothing.
"""

from __future__ import annotations

import dataclasses
import threading
import time

#: Canonical phase-boundary marks, in causal order.
MARKS = ("submitted", "started", "first_execute", "accumulate",
         "finalize", "finished")

#: Span name of the interval *starting* at each mark.
_PHASE_OF = {
    "submitted": "admission",      # queued behind the admission door
    "started": "schedule",         # active, waiting to land in a cohort
    "first_execute": "execute",    # cohort classification (all cohorts)
    "accumulate": "accumulate",    # demux into the streaming accumulator
    "finalize": "finalize",        # report finalization + teardown
}

#: Marks that advance on every cohort (keep the latest, not the first).
_LAST_WINS = frozenset({"accumulate"})


class RequestTimeline:
    """Monotonic phase-boundary clock for one request.

    Marks are recorded with ``time.perf_counter()`` on the thread that
    observed the transition; a wall-clock anchor taken at construction
    converts them to absolute times for exposition.  First-wins per mark
    (except ``accumulate``, which tracks the *latest* cohort demux), so
    racing pumps cannot move a boundary backwards.
    """

    __slots__ = ("wall_anchor", "mono_anchor", "_marks")

    def __init__(self) -> None:
        self.wall_anchor = time.time()
        self.mono_anchor = time.perf_counter()
        self._marks: dict[str, float] = {}

    def mark(self, name: str, at: float | None = None) -> float:
        """Stamp ``name`` (a member of :data:`MARKS`) at ``at`` or now."""
        if name not in _PHASE_OF and name != "finished":
            raise ValueError(f"unknown timeline mark {name!r}; "
                             f"expected one of {MARKS}")
        t = time.perf_counter() if at is None else at
        if name in _LAST_WINS or name not in self._marks:
            self._marks[name] = t
        return self._marks[name]

    def at(self, name: str) -> float | None:
        """The monotonic time of ``name``, or None if never reached."""
        return self._marks.get(name)

    def elapsed(self, a: str, b: str) -> float | None:
        """Seconds between two marks; None unless both were reached."""
        ta, tb = self._marks.get(a), self._marks.get(b)
        return None if ta is None or tb is None else tb - ta

    def wall(self, mono: float) -> float:
        """Convert a monotonic mark back to absolute (epoch) seconds."""
        return self.wall_anchor + (mono - self.mono_anchor)

    # -- the unified latency clock ------------------------------------------
    @property
    def latency_s(self) -> float | None:
        """Submit-to-terminal wall time, once terminal."""
        return self.elapsed("submitted", "finished")

    @property
    def queue_wait_s(self) -> float | None:
        """Admission wait: submit until the request went RUNNING."""
        return self.elapsed("submitted", "started")

    @property
    def service_s(self) -> float | None:
        """Active service time: RUNNING until terminal."""
        return self.elapsed("started", "finished")


@dataclasses.dataclass(frozen=True)
class Span:
    """One named interval of a trace.

    ``start_s``/``end_s`` are monotonic (``time.perf_counter``) seconds;
    ``start_unix`` anchors the span on the wall clock for cross-process
    correlation.  ``parent_id`` is None only for the root span.
    """

    name: str
    span_id: int
    parent_id: int | None
    start_unix: float
    start_s: float
    end_s: float

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s

    def to_dict(self) -> dict:
        return {"name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id,
                "start_unix": self.start_unix,
                "duration_s": self.duration_s}


@dataclasses.dataclass(frozen=True)
class Trace:
    """One request's assembled trace: a root span + phase children."""

    trace_id: str
    state: str                     # terminal RequestState value
    spans: tuple[Span, ...]        # root first, children in time order

    @property
    def root(self) -> Span:
        return self.spans[0]

    @property
    def duration_s(self) -> float:
        return self.root.duration_s

    def span(self, name: str) -> Span | None:
        for s in self.spans:
            if s.name == name:
                return s
        return None

    def to_dict(self) -> dict:
        return {"trace_id": self.trace_id, "state": self.state,
                "duration_s": self.duration_s,
                "spans": [s.to_dict() for s in self.spans]}


def assemble_trace(trace_id: str, timeline: RequestTimeline,
                   state: str = "done") -> Trace:
    """Build the per-request trace from a timeline's recorded marks.

    Child spans run between *consecutive reached* marks and are named
    for the phase the interval belongs to, so a request cancelled while
    queued yields a single ``admission`` child and a failed request's
    trace simply stops at the last phase it reached.  Because children
    tile root exactly, ``sum(child.duration_s) == root.duration_s``.
    """
    reached = [(m, timeline.at(m)) for m in MARKS
               if timeline.at(m) is not None]
    if not reached:
        raise ValueError(f"timeline of {trace_id!r} has no marks")
    t0, t_end = reached[0][1], reached[-1][1]
    spans = [Span(name="request", span_id=0, parent_id=None,
                  start_unix=timeline.wall(t0), start_s=t0, end_s=t_end)]
    for i, (mark, t) in enumerate(reached[:-1]):
        spans.append(Span(
            name=_PHASE_OF[mark], span_id=i + 1, parent_id=0,
            start_unix=timeline.wall(t), start_s=t,
            end_s=reached[i + 1][1]))
    return Trace(trace_id=trace_id, state=state, spans=tuple(spans))


class TraceRecorder:
    """Keeps the first ``sample`` completed request traces, thread-safe.

    First-N sampling is deliberate: deterministic under test, and the
    earliest requests of a serving run are the ones that exercise cold
    caches and compilation — the traces worth reading.
    """

    enabled = True

    def __init__(self, sample: int = 8):
        if sample < 1:
            raise ValueError("sample must be >= 1")
        self.sample = sample
        self._lock = threading.Lock()
        self._traces: list[Trace] = []

    def record(self, trace_id: str, timeline: RequestTimeline,
               state: str = "done") -> Trace | None:
        """Assemble + keep the trace if the sample isn't full yet."""
        with self._lock:
            if len(self._traces) >= self.sample:
                return None
            trace = assemble_trace(trace_id, timeline, state)
            self._traces.append(trace)
            return trace

    @property
    def full(self) -> bool:
        with self._lock:
            return len(self._traces) >= self.sample

    def traces(self) -> tuple[Trace, ...]:
        with self._lock:
            return tuple(self._traces)

    def to_dicts(self) -> list[dict]:
        return [t.to_dict() for t in self.traces()]


class NullTraceRecorder(TraceRecorder):
    """Disabled tracing: same interface, keeps nothing."""

    enabled = False

    def __init__(self) -> None:
        super().__init__(sample=1)

    def record(self, trace_id: str, timeline: RequestTimeline,
               state: str = "done") -> None:
        return None

    @property
    def full(self) -> bool:
        return True

    def traces(self) -> tuple[Trace, ...]:
        return ()
