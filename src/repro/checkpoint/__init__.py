"""Sharded checkpointing with async save and elastic resharding on restore."""
