"""Sharded checkpointing: atomic publish, async save, elastic restore.

Format: one directory per step containing
  - ``meta.json``      step metadata + flat-key manifest
  - ``<flatkey>.npy``  one host-side numpy file per leaf

Leaves are written as *full* (unsharded) host arrays, which makes restore
mesh-agnostic: any source mesh -> any destination mesh (elastic scaling);
the restore path reapplies whatever shardings the new mesh dictates via
``jax.device_put``.  Writes go to ``<dir>.tmp`` and are renamed only after
fsync — a crashed save can never corrupt the latest checkpoint (the
restart driver always loads the newest *complete* step).

The async saver snapshots to host memory synchronously (cheap) and does
file IO on a worker thread so the train loop never blocks on disk.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        out[key] = leaf
    return out


def save(path: str | pathlib.Path, state, step: int) -> pathlib.Path:
    """Synchronous sharded save with atomic publish. Returns final dir."""
    root = pathlib.Path(path)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = []
    for key, leaf in _flatten(state).items():
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{abs(hash(key)) :016x}.npy"
        np.save(tmp / fname, arr)
        manifest.append({"key": key, "file": fname,
                         "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "meta.json").write_text(json.dumps(
        {"step": step, "manifest": manifest}))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(path: str | pathlib.Path) -> int | None:
    root = pathlib.Path(path)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.glob("step_*")
             if not p.name.endswith(".tmp") and (p / "meta.json").exists()]
    return max(steps) if steps else None


def restore(path: str | pathlib.Path, target, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``target`` (arrays or SDS pytree).

    ``shardings``: optional matching pytree of NamedShardings — this is the
    elastic-resharding path: the checkpoint was written from any mesh; each
    full host array is re-placed onto the new mesh here.
    """
    root = pathlib.Path(path)
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    by_key = {m["key"]: m for m in meta["manifest"]}

    flat_target = _flatten(target)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    restored = {}
    for key, leaf in flat_target.items():
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(d / by_key[key]["file"])
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: shape {arr.shape} != {want}")
        sh = flat_shard.get(key)
        restored[key] = (jax.device_put(arr, sh) if sh is not None
                         else jax.numpy.asarray(arr))

    # unflatten back into the target treedef
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    keys = [_SEP.join(str(getattr(e, "key", getattr(e, "idx", e)))
                      for e in path) for path, _ in paths]
    return jax.tree_util.tree_unflatten(treedef,
                                        [restored[k] for k in keys]), step


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread checkpointer."""

    def __init__(self, path: str | pathlib.Path, keep: int = 3):
        self.path = pathlib.Path(path)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.error: Exception | None = None

    def save(self, state, step: int) -> None:
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save(self.path, snapshot, step)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(p for p in self.path.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
