"""Gradient compression: int8 quantization with error feedback (EF-SGD).

For cross-pod data parallelism the gradient all-reduce over the slow
inter-pod links dominates; 4x compression (fp32 -> int8 with per-tensor
scale) cuts it proportionally.  Error feedback keeps the *quantization
residual* locally and adds it to the next step's gradient, which restores
convergence to the uncompressed trajectory (Karimireddy et al., 2019).

Usage: wrap grads around the DP reduction:

    cstate = init_state(grads)
    qgrads, cstate = compress(grads, cstate)       # before all-reduce
    grads = decompress(qgrads)                      # after all-reduce

Under pjit the all-reduce is implicit; `compressed_psum` does the explicit
shard_map version for the pipeline/multipod drivers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_state(grads):
    """Error-feedback residuals, zero-initialized, shaped like grads."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant_one(g: jax.Array, err: jax.Array):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_err = g - q.astype(jnp.float32) * scale
    return {"q": q, "scale": scale}, new_err


def compress(grads, err_state):
    """-> (quantized pytree {q, scale}, new error-feedback state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    qs, es = [], []
    for g, e in zip(flat_g, flat_e):
        q, ne = _quant_one(g, e)
        qs.append(q)
        es.append(ne)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, es))


def decompress(qgrads):
    return jax.tree.map(
        lambda q: q["q"].astype(jnp.float32) * q["scale"],
        qgrads, is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def compressed_psum(grads, err_state, axis_name: str):
    """Explicit compressed DP all-reduce (inside shard_map).

    The quantization scale is agreed FIRST (pmax of local maxima — one
    tiny scalar all-reduce), then every replica quantizes against the
    shared scale; int8 payloads sum in int32 (no overflow for <= 2^24
    replicas).  Summing payloads quantized under per-replica scales and
    rescaling by the max would be wrong — values from small-scale
    replicas would be inflated.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        local = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        scale = jax.lax.pmax(local, axis_name)          # shared scale
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        ne = g - q.astype(jnp.float32) * scale          # error feedback
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return summed.astype(jnp.float32) * scale / n, ne

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    outs, errs = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return (jax.tree.unflatten(treedef, list(outs)),
            jax.tree.unflatten(treedef, list(errs)))
