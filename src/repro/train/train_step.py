"""Training step: chunked cross-entropy loss, grad accumulation, AdamW.

Key memory decision: the (B, S, vocab) logits tensor is never materialized
for the whole sequence — the loss runs over sequence chunks with
`jax.checkpoint`, so the peak is (B, chunk, vocab) and the backward
rematerializes per chunk.  At nemotron-4's 256k vocab this is the
difference between 1 TB of logits and ~34 GB across the pod.
"""

from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed import sharding
from repro.models import layers, lm
from repro.train import optimizer as opt_mod

TrainState = dict  # {"params", "opt", "step"}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt_mod.OptConfig = opt_mod.OptConfig()
    loss_chunk: int = 512            # sequence-chunked CE
    microbatches: int = 1            # gradient accumulation
    remat: bool = True
    unroll: bool = False             # dry-run cost-exact mode
    q_chunk: int = 512
    kv_chunk: int = 1024
    z_loss: float = 1e-4             # logit-norm regularizer (stability)


def init_train_state(key: jax.Array, cfg: ModelConfig,
                     tc: TrainConfig) -> TrainState:
    params = lm.init_lm(key, cfg)
    return {"params": params, "opt": opt_mod.init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def chunked_ce_loss(h: jax.Array, embed_params: dict, labels: jax.Array,
                    cfg: ModelConfig, chunk: int, z_loss: float
                    ) -> tuple[jax.Array, jax.Array]:
    """Cross-entropy over sequence chunks; returns (sum_loss, n_tokens).

    labels == -1 positions are masked out.
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = h.shape[1] // chunk
    hc = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def one(hi, li):
        logits = layers.lm_logits(embed_params, hi, cfg)       # fp32
        logits = sharding.constrain_safe(logits, ("batch", "seq", "vocab"))
        mask = (li >= 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mask
        zl = z_loss * jnp.square(lse) * mask
        return (ce + zl).sum(), mask.sum()

    def body(carry, xs):
        tot, n = carry
        l, m = one(*xs)
        return (tot + l, n + m), None

    (tot, n), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros((), jnp.int32)),
                               (hc, lc))
    return tot, n


def make_loss_fn(cfg: ModelConfig, tc: TrainConfig):
    def loss_fn(params, batch):
        kw = {}
        if cfg.family == "audio":
            kw["enc_embeds"] = batch["enc_embeds"]
        if cfg.family == "vlm":
            kw["prefix_embeds"] = batch["prefix_embeds"]
        h, aux, _ = lm.forward(
            params, batch["tokens"], cfg, remat=tc.remat, unroll=tc.unroll,
            q_chunk=tc.q_chunk, kv_chunk=tc.kv_chunk,
            return_hidden=True, **kw)
        labels = batch["labels"]
        if cfg.family == "vlm":      # prefix positions carry no LM loss
            prefix = h.shape[1] - labels.shape[1]
            h = h[:, prefix:]
        tot, n = chunked_ce_loss(h, params["embed"], labels, cfg,
                                 tc.loss_chunk, tc.z_loss)
        loss = tot / jnp.maximum(n, 1) + aux
        return loss, {"ce": tot / jnp.maximum(n, 1), "aux": aux,
                      "tokens": n}
    return loss_fn


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    With tc.microbatches > 1, the batch's leading dim is split and gradients
    accumulate in fp32 across a lax.scan (sequential grad accumulation).

    grad_shardings: optional pytree of NamedShardings matching params —
    pins gradients to the parameter layout so the DP reduction lowers to
    reduce-scatter instead of a full-tensor all-reduce (§Perf H2b).
    """
    loss_fn = make_loss_fn(cfg, tc)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def train_step(state: TrainState, batch: dict):
        params = state["params"]
        if tc.microbatches > 1:
            def split(x):
                b = x.shape[0]
                mb = b // tc.microbatches
                return x.reshape(tc.microbatches, mb, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                grads = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                     acc[0], grads)
                return (grads, acc[1] + loss), metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss_sum), metrics = jax.lax.scan(
                body, (zeros, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
            loss = loss_sum / tc.microbatches
            metrics = jax.tree.map(lambda x: x[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        grads = constrain_grads(grads)

        new_params, new_opt, stats = opt_mod.adamw_update(
            params, grads, state["opt"], state["step"], tc.opt)
        metrics = dict(metrics, loss=loss, **stats)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step
