"""Hand-rolled AdamW with warmup-cosine schedule (no optax dependency).

Optimizer moments are fp32 regardless of param dtype (bf16 params keep an
implicit fp32 master via the update path: update computed in fp32, cast on
write).  Moments inherit the parameters' sharding (FSDP shards optimizer
state over 'data' for free).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(step: jax.Array, oc: OptConfig) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * peak."""
    step = step.astype(jnp.float32)
    warm = oc.peak_lr * step / max(oc.warmup_steps, 1)
    t = jnp.clip((step - oc.warmup_steps)
                 / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.peak_lr * (oc.min_lr_frac
                        + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _is_matrix(p: jax.Array) -> bool:
    return p.ndim >= 2


def adamw_update(params, grads, opt: dict, step: jax.Array, oc: OptConfig):
    """One AdamW step. Returns (new_params, new_opt, stats)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    lr = lr_at(step, oc)
    b1, b2 = oc.b1, oc.b2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         opt["v"], grads)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
        if _is_matrix(p) and oc.weight_decay:
            u = u + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
