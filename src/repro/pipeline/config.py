"""`ProfilerConfig`: the single frozen record of a profiling run's setup.

One config names everything a run depends on — the HD space (step 1), the
RefDB windowing (step 2), the batch shape of the streamed query path
(steps 3-4), and the *backend* that executes encode/agreement.  It is a
frozen dataclass in the style of :class:`repro.config.ModelConfig`:
hashable (usable as a jit static argument) and JSON round-trippable.
:meth:`~ProfilerConfig.fingerprint` covers every field (the config's
identity); :meth:`~ProfilerConfig.refdb_fingerprint` covers exactly the
fields that determine RefDB content, so two configs that could produce
different prototypes can never collide on one cache entry (the session
joins it with a digest of the reference genomes to form the full key).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Mapping

from repro.core.hd_space import HDSpace

#: JSON-primitive types allowed as backend option values.
OptionValue = str | int | float | bool


@dataclasses.dataclass(frozen=True)
class ProfilerConfig:
    """Frozen configuration of a Demeter profiling run.

    Attributes:
      space: the HD space (step 1) — dimension, n-gram, threshold, seed.
      window: reference-genome window length (one AM prototype per window).
      stride: window stride; ``None`` means non-overlapping (= window).
      batch_size: read batch size of the streamed query path.
      backend: registered backend name executing encode/agreement
        (see :mod:`repro.pipeline.backend`); validated at session
        construction so configs may name backends registered later.
      backend_options: backend-specific knobs (e.g. the ``pcm_sim``
        device/crossbar parameters and noise seed).  Accepts a mapping at
        construction time; canonicalized to a sorted tuple of
        ``(name, value)`` pairs so the config stays hashable and
        JSON-round-trippable.  Values must be JSON primitives.
      noise_aware_refdb: build the RefDB noise-aware — after the naive
        build, retrain the prototypes on simulated readout through this
        config's backend + backend_options (the margin-maximizing pass in
        :mod:`repro.accel.codesign`).  When enabled, backend and
        backend_options *join* the RefDB cache key: the refined
        prototypes depend on the device they were trained against.
      noise_aware_iters: retraining passes when ``noise_aware_refdb``.
    """

    space: HDSpace = HDSpace()
    window: int = 8192
    stride: int | None = None
    batch_size: int = 256
    backend: str = "reference"
    backend_options: tuple[tuple[str, OptionValue], ...] = ()
    noise_aware_refdb: bool = False
    noise_aware_iters: int = 2

    def __post_init__(self) -> None:
        if self.window < 1:
            raise ValueError("window must be >= 1")
        if self.stride is not None and self.stride < 1:
            raise ValueError("stride must be >= 1 (or None for = window)")
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if not self.backend or not isinstance(self.backend, str):
            raise ValueError("backend must be a non-empty backend name")
        if self.noise_aware_iters < 1:
            raise ValueError("noise_aware_iters must be >= 1")
        object.__setattr__(self, "backend_options",
                           _canonical_options(self.backend_options))

    @property
    def options(self) -> dict[str, OptionValue]:
        """``backend_options`` as a plain dict (the read-side view)."""
        return dict(self.backend_options)

    def with_options(self, **options: OptionValue) -> "ProfilerConfig":
        """A copy with ``options`` merged over the existing backend options."""
        return dataclasses.replace(
            self, backend_options={**self.options, **options})

    @property
    def effective_stride(self) -> int:
        """The stride actually used: ``stride`` or (if None) ``window``."""
        return self.stride if self.stride is not None else self.window

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)  # recurses into the HDSpace field

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "ProfilerConfig":
        d = dict(d)
        d["space"] = HDSpace(**d["space"])
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ProfilerConfig":
        return cls.from_dict(json.loads(s))

    # -- identity -----------------------------------------------------------
    def fingerprint(self) -> str:
        """Stable hash over *every* field (the config's full identity).

        ``stride`` is canonicalized to :attr:`effective_stride` first, so
        ``stride=None`` and ``stride=window`` hash the same.
        """
        d = self.to_dict()
        d["stride"] = self.effective_stride
        payload = json.dumps(d, sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    def refdb_fingerprint(self) -> str:
        """Stable hash over the fields that determine RefDB *content*.

        Covers space, window and canonicalized stride — everything that
        can change the built prototypes (the old cache key ignored stride
        and silently served wrong databases).  ``batch_size`` (a host
        batching knob) and ``backend``/``backend_options`` (every backend's
        *encode* is bit-exact with the reference — the ``pcm_sim`` device
        non-idealities live entirely in the AM search, enforced by the
        parity tests) are deliberately excluded so tuning any of them
        reuses the cached database instead of forcing a full rebuild.

        With ``noise_aware_refdb`` the exclusion no longer holds: the
        retraining pass reads through the configured backend, so the
        refined prototypes *do* depend on backend, backend_options and
        the iteration count — all three join the key, and a noise-aware
        build can never collide with a naive one.
        """
        d = {"space": dataclasses.asdict(self.space), "window": self.window,
             "stride": self.effective_stride}
        if self.noise_aware_refdb:
            d["noise_aware"] = {"backend": self.backend,
                                "backend_options": list(self.backend_options),
                                "iters": self.noise_aware_iters}
        payload = json.dumps(d, sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _canonical_options(options) -> tuple[tuple[str, OptionValue], ...]:
    """Normalize any mapping / iterable-of-pairs into the canonical sorted
    tuple-of-pairs form (hashable, deterministic JSON)."""
    if isinstance(options, Mapping):
        pairs = list(options.items())
    else:
        pairs = [tuple(p) for p in options]
    out = []
    for pair in pairs:
        if len(pair) != 2:
            raise ValueError(f"backend option must be a (name, value) pair, "
                             f"got {pair!r}")
        name, value = pair
        if not isinstance(name, str) or not name:
            raise ValueError(f"backend option name must be a non-empty "
                             f"string, got {name!r}")
        if not isinstance(value, (str, int, float, bool)):
            raise ValueError(
                f"backend option {name!r} must be a JSON primitive "
                f"(str/int/float/bool), got {type(value).__name__}")
        out.append((name, value))
    names = [n for n, _ in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate backend option names in {names}")
    return tuple(sorted(out))
