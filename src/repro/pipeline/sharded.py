"""``sharded``: prototype-axis model parallelism for the AM search.

The registry's scaling seam, made real.  Demeter's query hot path is one
big ``(B, W) x (S, W)`` agreement against the HD reference database; on a
single accelerator it is capped by that device's memory and FLOPs.
In-memory HDC hardware scales the same search by splitting the
associative memory across crossbar arrays — this backend is the digital
analogue: the *prototype* axis is partitioned across a 1-D ``('shard',)``
device mesh (``repro.distributed.sharding.PROFILE_RULES``), every shard
scores the (replicated, cheap) query batch against its local slice of
prototypes with **any base backend's** ``agreement``, and per-shard
partial species scores merge with an elementwise ``pmax`` — exact, so the
whole path stays bit-identical to the unsharded reference on any mesh
size (enforced in ``tests/test_sharded.py`` on 1 and 8 devices).

Two execution surfaces:

* ``agreement(queries, prototypes)`` — the Backend-protocol primitive,
  ``shard_map``-ped over the prototype axis with the ``(B, S)`` result
  left prototype-sharded (no gather on the hot path; XLA moves rows only
  if a consumer needs them elsewhere).
* ``species_scores(queries, prototypes, proto_species, num_species)`` —
  the fused fast path the session prefers when present: agreement *and*
  the per-species reduction run inside the map, so the only cross-device
  traffic is the ``(B, num_species)`` pmax — independent of S, the axis
  being scaled.

Options (``ProfilerConfig.backend_options``):

    base    name of the wrapped backend ("reference" default; any
            registered name except "sharded" itself).
    shards  mesh size (default: every local device).  Prototype counts
            that don't divide it are zero-padded; padding rows carry
            species id ``num_species``, which the segment reduction
            drops, so they can never reach a report.

``place_refdb`` is the device-placement step ``ProfilingSession`` runs
after build/load: pad S to the mesh, lay prototypes out shard-major, and
``device_put`` them so each device holds ``1/shards`` of the database —
the capacity win that lets the AM outgrow one device's memory.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import assoc_memory
from repro.core.assoc_memory import RefDB
from repro.distributed import sharding
from repro.distributed.sharding import shard_map_compat as _shard_map
from repro.core.bitops import pad_to_multiple
from repro.pipeline.backend import register_backend, resolve_backend
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.options import Option, OptionsSchema, non_negative

#: Options consumed by this backend; everything else is forwarded to the
#: base backend's config (e.g. pcm_sim device knobs under base=pcm_sim).
_OWN_OPTIONS = ("base", "shards")


def _non_sharded(v) -> str | None:
    return None if v != "sharded" else "must name a non-sharded backend"


#: ``passthrough=True``: unknown options are forwarded to the wrapped
#: backend, whose own schema validates them — so a misspelled ``pcm_sim``
#: knob fails with the same error whether it rides directly or through
#: ``sharded``.
SHARDED_OPTIONS = OptionsSchema(backend="sharded", passthrough=True, options=(
    Option("base", "str", default="reference", check=_non_sharded,
           help="wrapped backend name (any registered name but 'sharded')"),
    Option("shards", "int", default=0, check=non_negative,
           help="mesh size; 0 = every local device"),
))


def pad_refdb(db: RefDB, multiple: int) -> RefDB:
    """Pad the prototype axis up to a multiple of ``multiple``.

    Padding rows are all-zero vectors tagged with species id
    ``num_species`` — out of range for the segment reduction, so they are
    dropped there, and sliced off by the ``agreement`` path.  Idempotent
    when S already divides.
    """
    if db.prototypes.shape[0] % multiple == 0:
        return db
    return dataclasses.replace(
        db,
        prototypes=pad_to_multiple(db.prototypes, 0, multiple),
        proto_species=pad_to_multiple(db.proto_species, 0, multiple,
                                      fill=db.num_species),
    )


def placement_shardings(mesh) -> tuple[NamedSharding, NamedSharding]:
    """(prototype, proto_species) shardings under PROFILE_RULES."""
    with sharding.use_rules(mesh, sharding.PROFILE_RULES):
        return (sharding.sharding_for(("protos", "hd_words")),
                sharding.sharding_for(("protos",)))


def place_refdb(db: RefDB, mesh) -> RefDB:
    """Pad S to the mesh and lay the database out across its devices.

    Prototypes and their species tags are split shard-major over the
    ``'shard'`` axis (each device holds ``S_padded / shards`` rows);
    genome lengths are tiny and stay replicated.
    """
    db = pad_refdb(db, mesh.size)
    proto_sh, species_sh = placement_shardings(mesh)
    return dataclasses.replace(
        db,
        prototypes=jax.device_put(db.prototypes, proto_sh),
        proto_species=jax.device_put(db.proto_species, species_sh),
    )


def per_device_bytes(db: RefDB, num_shards: int) -> int:
    """RefDB bytes resident on *each* device at ``num_shards`` shards.

    The sharded halves (prototypes + species tags) divide by the mesh
    size after padding; the genome-length vector is replicated.  With
    ``num_shards=1`` this equals :meth:`RefDB.memory_bytes`.
    """
    s, w = db.prototypes.shape
    rows = -(-s // num_shards)          # ceil: padded rows per shard
    return rows * w * 4 + rows * 4 + db.genome_lengths.size * 4


@register_backend("sharded", schema=SHARDED_OPTIONS)
class ShardedBackend:
    """Prototype-axis sharding wrapped around any base backend."""

    name = "sharded"

    def __init__(self, config: ProfilerConfig):
        own, base_options = SHARDED_OPTIONS.validate(config.options)
        base_name = own.get("base", "reference")
        shards = own.get("shards", 0)
        base_config = dataclasses.replace(
            config, backend=base_name, backend_options=base_options)
        self.config = config
        self.base = resolve_backend(base_name, base_config)
        self.space = base_config.space
        self.mesh = sharding.make_profile_mesh(shards or None)
        self.num_shards = self.mesh.size
        self._agreement = jax.jit(self._agreement_impl)
        self._scores = jax.jit(self._scores_impl,
                               static_argnames=("num_species",))
        # A fused base (tokens_agreement capability, e.g. pallas_fused)
        # stays fused under sharding: each shard streams the raw tokens
        # through the megakernel against its local prototypes — the
        # crossbar-per-array dataflow — so the capabilities are exposed
        # only when the base has them (instance attributes, so the
        # session's getattr dispatch sees exactly what the base offers).
        if getattr(self.base, "tokens_agreement", None) is not None:
            self.tokens_agreement = self._tokens_agreement
            self.tokens_species_scores = self._tokens_species_scores
            self._tok_agree = jax.jit(self._tokens_agreement_impl)
            self._tok_scores = jax.jit(self._tokens_scores_impl,
                                       static_argnames=("num_species",))

    # -- step 3: reads are replicated; encoding is the base's, bit-exact --
    def encode(self, tokens: jax.Array, lengths: jax.Array) -> jax.Array:
        return self.base.encode(tokens, lengths)

    # -- step 4, protocol surface -----------------------------------------
    def agreement(self, queries: jax.Array, prototypes: jax.Array
                  ) -> jax.Array:
        """Per-prototype agreement, computed shard-locally.

        The ``(B, S)`` result stays sharded over S; slicing back to the
        caller's prototype count drops any mesh-padding columns.
        """
        s = prototypes.shape[0]
        p = pad_to_multiple(jnp.asarray(prototypes), 0, self.num_shards)
        return self._agreement(jnp.asarray(queries), p)[:, :s]

    def _agreement_impl(self, q, p):
        return _shard_map(
            lambda qb, pb: self.base.agreement(qb, pb),
            mesh=self.mesh,
            in_specs=(P(None, None), P("shard", None)),
            out_specs=P(None, "shard"))(q, p)

    # -- step 4, fused fast path (used by ProfilingSession when present) --
    def species_scores(self, queries: jax.Array, prototypes: jax.Array,
                       proto_species: jax.Array, num_species: int
                       ) -> jax.Array:
        """Agreement + per-species max, reduced in-shard and pmax-merged.

        Cross-device traffic is one ``(B, num_species)`` integer pmax —
        independent of the prototype count being scaled.  Bit-identical
        to ``species_scores(base.agreement(q, p))`` on the full set.
        """
        p = pad_to_multiple(jnp.asarray(prototypes), 0, self.num_shards)
        ps = pad_to_multiple(jnp.asarray(proto_species), 0, self.num_shards,
                             fill=num_species)
        return self._scores(jnp.asarray(queries), p, ps,
                            num_species=num_species)

    def _scores_impl(self, q, p, ps, *, num_species):
        def per_shard(qb, pb, psb):
            agree = self.base.agreement(qb, pb)
            partial = assoc_memory.species_scores(agree, psb, num_species)
            return jax.lax.pmax(partial, "shard")

        return _shard_map(
            per_shard, mesh=self.mesh,
            in_specs=(P(None, None), P("shard", None), P("shard")),
            out_specs=P(None, None))(q, p, ps)

    # -- steps 3+4 fused per shard (only when the base is fused) ----------
    def _tokens_agreement(self, tokens: jax.Array, lengths: jax.Array,
                          prototypes: jax.Array) -> jax.Array:
        """Fused encode->search per shard: tokens in, agreement out.

        The (replicated, tiny) token stream reaches every shard, which
        runs the base megakernel against its local prototype slice — the
        encoded queries never exist off-VMEM on *any* device.
        """
        s = prototypes.shape[0]
        p = pad_to_multiple(jnp.asarray(prototypes), 0, self.num_shards)
        return self._tok_agree(jnp.asarray(tokens), jnp.asarray(lengths),
                               p)[:, :s]

    def _tokens_agreement_impl(self, t, l, p):
        return _shard_map(
            lambda tb, lb, pb: self.base.tokens_agreement(tb, lb, pb),
            mesh=self.mesh,
            in_specs=(P(None, None), P(None), P("shard", None)),
            out_specs=P(None, "shard"))(t, l, p)

    def _tokens_species_scores(self, tokens: jax.Array, lengths: jax.Array,
                               prototypes: jax.Array,
                               proto_species: jax.Array, num_species: int
                               ) -> jax.Array:
        """Fully fused: encode + search + species reduction in-shard.

        Cross-device traffic is the one ``(B, num_species)`` pmax, same
        as :meth:`species_scores` — but nothing upstream of it ever
        materializes either.
        """
        p = pad_to_multiple(jnp.asarray(prototypes), 0, self.num_shards)
        ps = pad_to_multiple(jnp.asarray(proto_species), 0, self.num_shards,
                             fill=num_species)
        return self._tok_scores(jnp.asarray(tokens), jnp.asarray(lengths),
                                p, ps, num_species=num_species)

    def _tokens_scores_impl(self, t, l, p, ps, *, num_species):
        def per_shard(tb, lb, pb, psb):
            agree = self.base.tokens_agreement(tb, lb, pb)
            partial = assoc_memory.species_scores(agree, psb, num_species)
            return jax.lax.pmax(partial, "shard")

        return _shard_map(
            per_shard, mesh=self.mesh,
            in_specs=(P(None, None), P(None), P("shard", None), P("shard")),
            out_specs=P(None, None))(t, l, p, ps)

    # -- device placement (ProfilingSession hook) -------------------------
    def place_refdb(self, db: RefDB) -> RefDB:
        """Pad + distribute a built/loaded RefDB across the shard mesh."""
        return place_refdb(db, self.mesh)
