"""`ReadSource`: streaming read input for the profiling pipeline.

A source yields fixed-shape :class:`ReadBatch` es — tokens padded to a
stable ``(batch_size, read_len)`` shape so the jit'd encode/classify path
compiles once — while tracking how many rows of the final batch are real
reads (``num_valid``), so padding never leaks into the report.

Concrete sources:

  :class:`ArraySource`      in-memory token/length arrays.
  :class:`FastqSource`      a FASTQ file, parsed lazily record-by-record
                            (the file is never fully materialized).
  :class:`SyntheticSource`  a synthetic food community with ground truth.
  :class:`IterableSource`   adapter for pre-batched ``(tokens, lengths)``
                            iterables (the legacy ``batch_reads`` contract
                            and serving queues).

:func:`prefetch` overlaps host-side batch preparation (file parsing,
padding) with device compute by running the source iterator in a
background thread with a bounded queue.
"""

from __future__ import annotations

import abc
import dataclasses
import pathlib
import queue
import threading
from typing import Iterable, Iterator

import numpy as np

from repro.genomics import fasta, synth


@dataclasses.dataclass(frozen=True)
class ReadBatch:
    """One fixed-shape batch of reads.

    tokens: ``(batch_size, L)`` int32, zero-padded rows past ``num_valid``.
    lengths: ``(batch_size,)`` int32, zero past ``num_valid``.
    num_valid: number of leading rows that are real reads.
    """
    tokens: np.ndarray
    lengths: np.ndarray
    num_valid: int


def _pad_batch(tokens: np.ndarray, lengths: np.ndarray,
               batch_size: int) -> ReadBatch:
    n = len(tokens)
    if n < batch_size:
        pad = batch_size - n
        tokens = np.concatenate(
            [tokens, np.zeros((pad,) + tokens.shape[1:], tokens.dtype)])
        lengths = np.concatenate([lengths, np.zeros(pad, lengths.dtype)])
    return ReadBatch(tokens=tokens, lengths=lengths, num_valid=n)


class ReadSource(abc.ABC):
    """Abstract stream of reads; iterate with :meth:`batches`."""

    @abc.abstractmethod
    def batches(self, batch_size: int) -> Iterator[ReadBatch]:
        """Yield :class:`ReadBatch` es padded to ``batch_size`` rows."""


class ArraySource(ReadSource):
    """Reads already materialized as ``(R, L)`` tokens + ``(R,)`` lengths."""

    def __init__(self, tokens: np.ndarray, lengths: np.ndarray):
        if len(tokens) != len(lengths):
            raise ValueError("tokens and lengths disagree on read count")
        self.tokens = np.asarray(tokens)
        self.lengths = np.asarray(lengths)

    def __len__(self) -> int:
        return len(self.tokens)

    def batches(self, batch_size: int) -> Iterator[ReadBatch]:
        for i in range(0, len(self.tokens), batch_size):
            yield _pad_batch(self.tokens[i:i + batch_size],
                             self.lengths[i:i + batch_size], batch_size)


class FastqSource(ReadSource):
    """Stream reads from a FASTQ file without loading it whole.

    Records are parsed lazily, ``batch_size`` at a time; sequences are
    truncated/zero-padded to ``read_len`` (the fixed query shape).
    """

    def __init__(self, path: str | pathlib.Path, read_len: int = 150):
        self.path = pathlib.Path(path)
        self.read_len = read_len

    def batches(self, batch_size: int) -> Iterator[ReadBatch]:
        toks: list[np.ndarray] = []
        lens: list[int] = []
        for row, n in fasta.iter_fastq(self.path, self.read_len):
            toks.append(row)
            lens.append(n)
            if len(toks) == batch_size:
                yield ReadBatch(np.stack(toks), np.asarray(lens, np.int32),
                                batch_size)
                toks, lens = [], []
        if toks:
            yield _pad_batch(np.stack(toks), np.asarray(lens, np.int32),
                             batch_size)


class SyntheticSource(ArraySource):
    """A synthetic food community sample with ground truth attached.

    Wraps :func:`repro.genomics.synth.make_sample`; exposes ``genomes``
    (the reference database to build the RefDB from), per-read ``truth``
    and the ``true_abundance`` profile for scoring.
    """

    def __init__(self, spec: synth.CommunitySpec, num_reads: int,
                 present: list[int] | None = None):
        genomes, tokens, lengths, truth, true_ab = synth.make_sample(
            spec, num_reads=num_reads, present=present)
        super().__init__(tokens, lengths)
        self.spec = spec
        self.genomes = genomes
        self.truth = truth
        self.true_abundance = true_ab


class IterableSource(ReadSource):
    """Adapter for an iterable of pre-batched ``(tokens, lengths)`` pairs.

    Batches pass through at their own size (``batch_size`` is ignored);
    every row counts as valid — the legacy ``batch_reads`` contract, where
    tail padding was part of the batch.
    """

    def __init__(self, batches: Iterable[tuple[np.ndarray, np.ndarray]]):
        self._batches = batches

    def batches(self, batch_size: int) -> Iterator[ReadBatch]:
        for tokens, lengths in self._batches:
            yield ReadBatch(np.asarray(tokens), np.asarray(lengths),
                            len(tokens))


def as_source(obj) -> ReadSource:
    """Coerce supported inputs to a :class:`ReadSource`.

    Accepts a ``ReadSource`` (passed through), a ``(tokens, lengths)``
    array pair (numpy, jax, or nested lists), or an iterable of
    pre-batched ``(tokens, lengths)`` pairs.
    """
    if isinstance(obj, ReadSource):
        return obj
    if isinstance(obj, tuple) and len(obj) == 2:
        # A (tokens, lengths) pair of any array-likes; pre-batched streams
        # are lists/generators, not 2-tuples, so a 2-tuple is unambiguous.
        try:
            toks, lens = np.asarray(obj[0]), np.asarray(obj[1])
        except Exception:
            toks = lens = None
        if toks is not None and toks.ndim == 2 and lens.ndim == 1:
            return ArraySource(toks, lens)
        raise TypeError(
            "a (tokens, lengths) pair must be (R, L) x (R,) arrays; "
            "pass pre-batched pairs as a list or generator instead")
    if isinstance(obj, Iterable):
        return IterableSource(obj)
    raise TypeError(f"cannot interpret {type(obj).__name__} as a ReadSource")


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Run ``it`` in a background thread, keeping ``depth`` items ready.

    Host-side batch preparation (file IO, padding) overlaps with device
    compute; exceptions from the producer re-raise at the consumer.  If
    the consumer abandons the stream early (error mid-profile, generator
    closed), the producer is signalled to stop and closes ``it`` — no
    thread or file handle is left blocked on the full queue.
    """
    if depth <= 0:
        yield from it
        return
    q: queue.Queue = queue.Queue(maxsize=depth)
    done = object()
    stop = threading.Event()

    def put(item) -> bool:
        while not stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def producer() -> None:
        try:
            for item in it:
                if not put((None, item)):
                    return
        except BaseException as e:  # re-raised on the consumer side
            put((e, None))
        else:
            put((None, done))
        finally:
            close = getattr(it, "close", None)
            if close is not None:
                close()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            err, item = q.get()
            if err is not None:
                raise err
            if item is done:
                return
            yield item
    finally:
        stop.set()
