"""Profiling output: :class:`ProfileReport` and its streaming accumulator.

Step 5 of the pipeline (abundance estimation) is exact-streaming: unique
counts accumulate online, multi-read hit masks are retained compactly
(packed bits) and split once at the end with the *global* unique-coverage
rates.  :class:`ProfileAccumulator` owns that state so any driver — the
:class:`~repro.pipeline.session.ProfilingSession` facade, a serving loop,
a future sharded reducer — can feed it batch classifications and finalize
once.

This module is dependency-light (numpy only) on purpose: it is imported
by both ``repro.core`` and ``repro.pipeline`` without creating a cycle.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np


@dataclasses.dataclass(frozen=True)
class ProfileReport:
    """Final output of a profiling run."""
    species_names: tuple[str, ...]
    abundance: np.ndarray          # (S,) relative abundance over mapped reads
    unique_counts: np.ndarray      # (S,)
    multi_counts: np.ndarray       # (S,) fractional
    total_reads: int
    unmapped_reads: int
    multi_reads: int

    def top(self, k: int = 10) -> list[tuple[str, float]]:
        order = np.argsort(-self.abundance)[:k]
        return [(self.species_names[i], float(self.abundance[i])) for i in order]

    # -- derived abundance summary (core.abundance semantics) ---------------
    @property
    def mapped_reads(self) -> int:
        return self.total_reads - self.unmapped_reads

    @property
    def unmapped_fraction(self) -> float:
        """Fraction of reads the AM search mapped to no species."""
        return self.unmapped_reads / self.total_reads if self.total_reads \
            else 0.0

    @property
    def multi_fraction(self) -> float:
        """Fraction of reads that hit more than one species (split in
        phase 2 by :func:`repro.core.abundance.split_multi_counts`)."""
        return self.multi_reads / self.total_reads if self.total_reads \
            else 0.0

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-primitive dict: the machine-readable run artifact shared by
        ``profile_run --json`` and ``ProfilingService`` report snapshots.

        ``mapped_reads`` / ``unmapped_fraction`` / ``multi_fraction`` are
        derived from the stored counts — :meth:`from_dict` recomputes
        rather than trusts them, so the round-trip stays exact.
        """
        return {
            "species_names": list(self.species_names),
            "abundance": [float(x) for x in self.abundance],
            "unique_counts": [int(x) for x in self.unique_counts],
            "multi_counts": [float(x) for x in self.multi_counts],
            "total_reads": int(self.total_reads),
            "unmapped_reads": int(self.unmapped_reads),
            "multi_reads": int(self.multi_reads),
            "mapped_reads": int(self.mapped_reads),
            "unmapped_fraction": float(self.unmapped_fraction),
            "multi_fraction": float(self.multi_fraction),
        }

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "ProfileReport":
        return cls(
            species_names=tuple(d["species_names"]),
            abundance=np.asarray(d["abundance"], np.float64),
            unique_counts=np.asarray(d["unique_counts"], np.int64),
            multi_counts=np.asarray(d["multi_counts"], np.float64),
            total_reads=int(d["total_reads"]),
            unmapped_reads=int(d["unmapped_reads"]),
            multi_reads=int(d["multi_reads"]),
        )

    @classmethod
    def from_json(cls, s: str) -> "ProfileReport":
        return cls.from_dict(json.loads(s))


class ProfileAccumulator:
    """Streaming abundance estimation (paper step 5) over read batches.

    ``add`` ingests the per-read hit mask and category of one batch;
    ``finalize`` performs the single end-of-stream pass that splits
    multi-mapped reads with the global unique-coverage rates.
    """

    UNMAPPED, UNIQUE, MULTI = 0, 1, 2

    def __init__(self, num_species: int):
        self.num_species = num_species
        self.unique_counts = np.zeros(num_species, np.int64)
        self._multi_hit_rows: list[np.ndarray] = []
        self.total_reads = 0
        self.unmapped_reads = 0
        self.multi_reads = 0

    def add(self, hits: np.ndarray, category: np.ndarray) -> None:
        """Ingest one batch: ``hits (R, S)`` bool, ``category (R,)`` int."""
        hits = np.asarray(hits)
        cat = np.asarray(category)
        self.total_reads += len(cat)
        self.unmapped_reads += int((cat == self.UNMAPPED).sum())
        uniq = hits[cat == self.UNIQUE]
        if len(uniq):
            self.unique_counts += uniq.sum(axis=0)
        m = hits[cat == self.MULTI]
        if len(m):
            self._multi_hit_rows.append(np.packbits(m, axis=-1))
            self.multi_reads += len(m)

    def finalize(self, genome_lengths: np.ndarray,
                 species_names: tuple[str, ...]) -> ProfileReport:
        """Split multi-mapped reads with the global unique rates and report.

        Non-destructive: may be called repeatedly as the stream grows (the
        serving layer snapshots in-flight requests this way).  All retained
        multi-read rows are concatenated into one pass so the result
        depends only on the multi reads and their order — never on how the
        stream happened to be cut into batches (a service interleaving a
        request's reads into shared cohorts reproduces a sequential run's
        report bit-for-bit).
        """
        # Lazy: repro.core pulls in this module (via core.profiler), so a
        # top-level import of core.abundance would be circular.
        from repro.core.abundance import split_multi_counts

        s = self.num_species
        multi_counts = np.zeros(s, np.float64)
        if self._multi_hit_rows:
            packed = np.concatenate(self._multi_hit_rows, axis=0)
            m = np.unpackbits(packed, axis=-1, count=s).astype(bool)
            multi_counts = split_multi_counts(self.unique_counts, m,
                                              genome_lengths)

        mapped = self.unique_counts + multi_counts
        denom = max(mapped.sum(), 1e-30)
        return ProfileReport(
            species_names=tuple(species_names),
            abundance=(mapped / denom).astype(np.float64),
            unique_counts=self.unique_counts.astype(np.int64),
            multi_counts=multi_counts,
            total_reads=self.total_reads,
            unmapped_reads=self.unmapped_reads,
            multi_reads=self.multi_reads,
        )
