"""The unified profiling API (the repo's public entry point).

Three concepts compose every profiling run:

  :class:`~repro.pipeline.config.ProfilerConfig`
      one frozen, JSON-round-trippable record of the run (HD space,
      windowing, batching, backend name); its content fingerprint plus a
      genome digest forms the complete RefDB cache key.
  :class:`~repro.pipeline.backend.Backend` (+ registry)
      named, substrate-specific implementations of the two hot primitives
      ``encode`` / ``agreement`` — ``reference``, ``reference_packed``,
      ``pallas_matmul``, ``pallas_packed`` (all bit-exact twins).
  :class:`~repro.pipeline.source.ReadSource`
      streaming read input (FASTA/FASTQ file, synthetic community,
      in-memory arrays) with host-side prefetch.

:class:`~repro.pipeline.session.ProfilingSession` is the facade that ties
them together; see ``docs/API.md`` for the full guide and the migration
table from the legacy ``Demeter(...)`` flags.
"""

from repro.pipeline.report import ProfileAccumulator, ProfileReport
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.backend import (Backend, available_backends,
                                    register_backend, resolve_backend)
from repro.pipeline.source import (ArraySource, FastqSource, IterableSource,
                                   ReadBatch, ReadSource, SyntheticSource,
                                   as_source, prefetch)
from repro.pipeline import refdb_store
from repro.pipeline.fused import PallasFusedBackend
from repro.pipeline.session import BatchResult, ProfilingSession
from repro.pipeline.sharded import (ShardedBackend, pad_refdb,
                                    per_device_bytes, place_refdb)

# Self-registering backends living outside this package.  Imported last:
# the accel modules import pipeline submodules, which are fully loaded by
# this point.  Registers "pcm_sim" (see repro.accel.backend_pcm).
import repro.accel  # noqa: E402,F401  (registration side effect)

__all__ = [
    "ProfileAccumulator", "ProfileReport", "ProfilerConfig",
    "Backend", "available_backends", "register_backend", "resolve_backend",
    "ArraySource", "FastqSource", "IterableSource", "ReadBatch",
    "ReadSource", "SyntheticSource", "as_source", "prefetch",
    "BatchResult", "PallasFusedBackend", "ProfilingSession",
    "ShardedBackend", "pad_refdb", "per_device_bytes", "place_refdb",
    "refdb_store",
]
