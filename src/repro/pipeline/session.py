"""`ProfilingSession`: the facade over the five-step Demeter pipeline.

One session binds a :class:`~repro.pipeline.config.ProfilerConfig` to a
resolved :class:`~repro.pipeline.backend.Backend` and drives the whole
pipeline::

    config = ProfilerConfig(space=HDSpace(dim=8192), window=4096,
                            backend="pallas_matmul")
    session = ProfilingSession(config)
    session.build_or_load_refdb(genomes, cache_dir="cache/")
    report = session.profile(FastqSource("sample.fastq"))

The query path streams batch-by-batch (the paper pipelines steps 3 and 4
in hardware; here host prefetch plus XLA async dispatch overlap the
encode of batch i+1 with the classification of batch i).  A per-batch
callback hook exposes the raw classifications for serving integration
(incremental responses, monitoring) without buffering the stream.
"""

from __future__ import annotations

import dataclasses
import hashlib
import pathlib
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import assoc_memory, classifier
from repro.core.assoc_memory import RefDB, RefDBBuilder
from repro.pipeline import refdb_store
from repro.pipeline.backend import Backend, resolve_backend
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.report import ProfileAccumulator, ProfileReport
from repro.pipeline.source import as_source, prefetch


@dataclasses.dataclass(frozen=True)
class BatchResult:
    """What the per-batch callback sees: one classified read batch.

    ``queries`` is ``None`` when the backend fused encode into the AM
    search (``tokens_agreement`` capability): the whole point of that
    path is that the encoded ``(B, W)`` matrix is never materialized.
    """
    index: int
    queries: jax.Array | None                           # (B, W) packed
    classification: classifier.ReadClassification      # over all B rows
    num_valid: int                                      # real rows (<= B)


BatchCallback = Callable[[BatchResult], None]


class ProfilingSession:
    """Facade binding a config + backend + (optionally cached) RefDB."""

    def __init__(self, config: ProfilerConfig, *,
                 backend: Backend | None = None,
                 metrics: obs.MetricsRegistry | None = None):
        """Args:
          backend: pre-resolved backend to use instead of resolving
            ``config.backend``.  Sessions sharing one backend share its
            jit caches and any one-time state (programmed pcm_sim
            conductances, the sharded mesh) — the serving router runs one
            session per RefDB version on a single shared backend so a
            hot-swap never recompiles the query path.
          metrics: observability registry; None resolves the process
            global (:func:`repro.obs.metrics`, the no-op registry unless
            observability was enabled).  Recording is host-side only and
            never enters a jax trace — metrics cannot perturb results.
        """
        self.config = config
        self.space = config.space
        self.backend: Backend = (backend if backend is not None
                                 else resolve_backend(config.backend, config))
        self._obs = obs.resolve_metrics(metrics)
        self._m_batch_time = self._obs.histogram(
            "session_classify_batch_seconds",
            "classify_batch dispatch wall time per dispatch path "
            "(async backends: time to hand off, not to complete)",
            unit="s")
        self._m_batches = self._obs.counter(
            "session_classify_batches_total",
            "classify_batch calls per backend and dispatch path")
        self._m_transfers = self._obs.counter(
            "session_host_transfers_total",
            "device->host array transfers on the query path")
        self.refdb: RefDB | None = None
        self.refdb_loaded_from_cache = False
        self.refdb_cache_file: pathlib.Path | None = None
        # Only the substrate-independent tail is jitted here; the
        # backend's own primitives are already jitted per backend.
        # Calling `agreement` outside any outer trace lets stateful
        # backends (pcm_sim) amortize one-time work — programming the
        # crossbar conductances — across the whole batch stream.
        self._from_agreement = jax.jit(
            classifier.from_agreement,
            static_argnames=("num_species", "threshold_bits"))
        self._from_scores = jax.jit(
            classifier.from_scores, static_argnames=("threshold_bits",))

    # -- Step 2 ------------------------------------------------------------
    def build_refdb(self, genomes: dict[str, np.ndarray]) -> RefDB:
        """Encode the reference genomes into the AM through the backend.

        With ``config.noise_aware_refdb`` the naive build is followed by
        the margin-maximizing retraining pass of
        :mod:`repro.accel.codesign`: the prototypes are tuned on
        simulated readout through this session's own backend + options,
        so the database the device serves is the one trained against its
        non-idealities.
        """
        db = assoc_memory.build_refdb(
            genomes, self.space, window=self.config.window,
            stride=self.config.effective_stride,
            batch_size=self.config.batch_size,
            encode_fn=self.backend.encode)
        db = self._maybe_refine(db, genomes)
        self.refdb = self._place(db)
        self.refdb_loaded_from_cache = False
        return self.refdb

    def _maybe_refine(self, db: RefDB,
                      genomes: dict[str, np.ndarray]) -> RefDB:
        """Noise-aware co-design pass, when the config asks for it."""
        if not self.config.noise_aware_refdb:
            return db
        from repro.accel.codesign import noise_aware_refdb
        return noise_aware_refdb(db, genomes, self.config,
                                 iterations=self.config.noise_aware_iters)

    def adopt_refdb(self, db: RefDB) -> RefDB:
        """Make an externally built/loaded RefDB this session's database.

        Runs the backend's device-placement step, exactly like a build or
        cache load would — the serving registry hands out plain host
        databases, and every hot-swap re-places the new version here (the
        ``sharded`` backend re-pads and re-distributes it across its
        mesh).
        """
        self.refdb = self._place(db)
        self.refdb_loaded_from_cache = False
        return self.refdb

    def refdb_cache_path(self, cache_dir: str | pathlib.Path,
                         genomes: dict[str, np.ndarray]) -> pathlib.Path:
        """Cache location keyed by every input that determines RefDB
        content: the config's RefDB fingerprint (space/window/stride) plus
        an order-insensitive digest of the reference genomes themselves."""
        key = f"{self.config.refdb_fingerprint()}_{_genomes_digest(genomes)}"
        return pathlib.Path(cache_dir) / f"refdb_{key}.npz"

    def build_or_load_refdb(self, genomes: dict[str, np.ndarray], *,
                            cache_dir: str | pathlib.Path | None = None
                            ) -> RefDB:
        """Load the RefDB from the content-keyed cache, or build and cache it.

        The key covers every input that can change the built prototypes —
        space, window, stride, and the reference genomes (names + token
        content, insertion-order-insensitive) — so neither a config change
        nor a swapped reference database can silently reuse a stale cache
        entry (the paper's step-1 config check).  ``batch_size``/``backend``
        are excluded: they cannot affect the prototypes (backends are
        bit-exact twins), so tuning them reuses the cache instead of
        rebuilding.

        Entries are persisted through :mod:`repro.pipeline.refdb_store`
        (versioned npz + JSON manifest, written atomically): a truncated
        file, a legacy pickle cache from an older checkout, or a
        format-version mismatch all read as a miss and trigger a clean
        rebuild — never a crash or a silently wrong database.  The build
        itself streams genome-by-genome through
        :class:`~repro.core.assoc_memory.RefDBBuilder`.
        """
        if cache_dir is None:
            return self.build_refdb(genomes)
        cache = self.refdb_cache_path(cache_dir, genomes)
        self.refdb_cache_file = cache
        db = refdb_store.load(cache)
        if db is not None:
            self.refdb = self._place(db)
            self.refdb_loaded_from_cache = True
            return self.refdb
        builder = RefDBBuilder(
            self.space, window=self.config.window,
            stride=self.config.effective_stride,
            batch_size=self.config.batch_size,
            encode_fn=self.backend.encode)
        refine = self.config.noise_aware_refdb
        db = refdb_store.build_streaming(
            genomes, builder, path=None if refine else cache,
            refdb_fingerprint=self.config.refdb_fingerprint(),
            genomes_digest=_genomes_digest(genomes),
            config_fields=self._refdb_config_fields())
        if refine:
            # Cache the *refined* database under the noise-aware key (the
            # fingerprint already folds in backend + options + iters), so
            # a later load gets the retrained prototypes, not the naive
            # intermediate.
            db = self._maybe_refine(db, genomes)
            refdb_store.save(
                cache, db, refdb_fingerprint=self.config.refdb_fingerprint(),
                genomes_digest=_genomes_digest(genomes),
                config_fields=self._refdb_config_fields())
        self.refdb = self._place(db)
        self.refdb_loaded_from_cache = False
        return self.refdb

    def _refdb_config_fields(self) -> dict:
        """Provenance recorded in the store manifest."""
        fields = {"space": dataclasses.asdict(self.space),
                  "window": self.config.window,
                  "stride": self.config.effective_stride}
        if self.config.noise_aware_refdb:
            fields["noise_aware"] = {
                "backend": self.config.backend,
                "backend_options": list(self.config.backend_options),
                "iters": self.config.noise_aware_iters}
        return fields

    # -- Step 3 ------------------------------------------------------------
    def encode_reads(self, tokens, lengths) -> jax.Array:
        """Convert a read batch ``(B, L)`` into query HD vectors ``(B, W)``."""
        return self.backend.encode(jnp.asarray(tokens), jnp.asarray(lengths))

    # -- Step 4 ------------------------------------------------------------
    def classify_queries(self, queries: jax.Array, refdb: RefDB | None = None
                         ) -> classifier.ReadClassification:
        """AM search + threshold over pre-encoded ``(B, W)`` query vectors.

        Backends exposing the fused ``species_scores`` capability (the
        ``sharded`` backend: agreement + per-species reduction inside one
        ``shard_map``, merged with a pmax) skip the per-prototype
        agreement round-trip; everyone else routes through ``agreement``
        and the shared :func:`~repro.core.classifier.from_agreement` tail.
        Both paths are bit-identical.
        """
        db = self._require_refdb(refdb)
        fused = getattr(self.backend, "species_scores", None)
        if fused is not None:
            scores = fused(queries, db.prototypes, db.proto_species,
                           db.num_species)
            return self._from_scores(
                scores, threshold_bits=self.space.threshold_bits)
        agree = self.backend.agreement(queries, db.prototypes)
        return self._from_agreement(
            agree, db.proto_species, num_species=db.num_species,
            threshold_bits=self.space.threshold_bits)

    # -- Steps 3+4: the step-level serving primitive -----------------------
    def classify_batch(self, tokens, lengths, *, refdb: RefDB | None = None,
                       num_valid: int | None = None, index: int = 0
                       ) -> BatchResult:
        """Encode + classify one read batch: the shared hot-path step.

        This is the single place steps 3 and 4 are glued together; both
        :meth:`profile` and the serving layer
        (:class:`repro.serve.profiler_service.ProfilingService`) drive it,
        so any backend, kernel, or dispatch change lands in both paths at
        once.

        Capability dispatch (most-fused first, all bit-identical):

        1. ``tokens_species_scores`` — encode + search + species
           reduction in one backend call (``sharded`` over a fused base).
        2. ``tokens_agreement`` — fused encode->search (``pallas_fused``):
           the encoded queries never touch HBM; ``queries`` is ``None``
           on the returned :class:`BatchResult`.
        3. fallback — separate ``encode`` then :meth:`classify_queries`
           (which itself prefers a ``species_scores`` capability).

        Args:
          tokens: ``(B, L)`` int32 padded read tokens.
          lengths: ``(B,)`` int32 true read lengths (0 for padding rows).
          refdb: database to query; defaults to the session's own.
          num_valid: how many leading rows are real reads (default: all).
          index: stream position recorded on the :class:`BatchResult`.
        """
        db = self._require_refdb(refdb)
        toks, lens = jnp.asarray(tokens), jnp.asarray(lengths)
        fused_full = getattr(self.backend, "tokens_species_scores", None)
        fused = getattr(self.backend, "tokens_agreement", None)
        recording = self._obs.enabled
        t0 = time.perf_counter() if recording else 0.0
        if fused_full is not None:
            path = "tokens_species_scores"
            scores = fused_full(toks, lens, db.prototypes,
                                db.proto_species, db.num_species)
            res = self._from_scores(
                scores, threshold_bits=self.space.threshold_bits)
            q = None
        elif fused is not None:
            path = "tokens_agreement"
            agree = fused(toks, lens, db.prototypes)
            res = self._from_agreement(
                agree, db.proto_species, num_species=db.num_species,
                threshold_bits=self.space.threshold_bits)
            q = None
        else:
            path = "encode_classify"
            q = self.encode_reads(toks, lens)
            res = self.classify_queries(q, db)
        if recording:
            # Host-side timing only — the jax computation is untouched,
            # so recording can never move a bit of the result.
            labels = {"backend": self.config.backend, "path": path}
            self._m_batch_time.observe(time.perf_counter() - t0, **labels)
            self._m_batches.inc(1, **labels)
        n = len(toks) if num_valid is None else num_valid
        return BatchResult(index=index, queries=q, classification=res,
                           num_valid=n)

    # -- Steps 3+4+5 streamed ----------------------------------------------
    def profile(self, source, *, refdb: RefDB | None = None,
                on_batch: BatchCallback | None = None,
                prefetch_depth: int = 2) -> ProfileReport:
        """Profile a sample: stream, encode, classify, estimate abundance.

        Args:
          source: a :class:`~repro.pipeline.source.ReadSource`, a
            ``(tokens, lengths)`` array pair, or an iterable of pre-batched
            pairs (legacy contract).
          refdb: database to query; defaults to the session's own.
          on_batch: optional hook called with a :class:`BatchResult` per
            batch — the serving integration point.
          prefetch_depth: host-side read-batch prefetch depth (0 disables).
        """
        db = self._require_refdb(refdb)
        acc = ProfileAccumulator(db.num_species)
        stream = prefetch(as_source(source).batches(self.config.batch_size),
                          prefetch_depth)
        for i, batch in enumerate(stream):
            res = self.classify_batch(batch.tokens, batch.lengths, refdb=db,
                                      num_valid=batch.num_valid, index=i)
            n = res.num_valid
            acc.add(np.asarray(res.classification.hits)[:n],
                    np.asarray(res.classification.category)[:n])
            self.note_host_transfers(2)       # hits + category to host
            if on_batch is not None:
                on_batch(res)
        return acc.finalize(np.asarray(db.genome_lengths), db.species_names)

    def note_host_transfers(self, n: int) -> None:
        """Count ``n`` device->host transfers against this session.

        Called wherever classification outputs cross to numpy — here in
        :meth:`profile` and by the serving demux
        (:meth:`repro.serve.profiler_service.ProfilingService.step`) —
        so the snapshot shows how chatty each dispatch path is.
        """
        if self._obs.enabled:
            self._m_transfers.inc(n, backend=self.config.backend)

    # ----------------------------------------------------------------------
    def _place(self, db: RefDB) -> RefDB:
        """Run the backend's device-placement step, if it has one.

        The ``sharded`` backend pads the prototype axis to its mesh and
        distributes the database across devices (one shard per device);
        single-device backends have no hook and the db passes through.
        Placement happens here — on build *and* on cache load — so every
        way a session acquires a RefDB ends device-resident the same way.
        """
        place = getattr(self.backend, "place_refdb", None)
        return db if place is None else place(db)

    def _require_refdb(self, refdb: RefDB | None) -> RefDB:
        db = refdb if refdb is not None else self.refdb
        if db is None:
            raise RuntimeError(
                "no RefDB: call build_or_load_refdb()/build_refdb() first "
                "or pass refdb= explicitly")
        return db


def _genomes_digest(genomes: dict[str, np.ndarray]) -> str:
    """Stable, order-insensitive hash of the reference content.

    Each genome hashes as its (name, tokens) pair; the per-genome digests
    are *sorted* before the final hash, so the same reference set built
    from a dict in a different insertion order hits the same cache entry.
    (The cached RefDB is self-describing — ``species_names`` records the
    species order of the build that wrote it — so a load under a
    different insertion order still reports every species correctly.)
    """
    parts = []
    for name, toks in genomes.items():
        h = hashlib.sha256(name.encode())
        h.update(b"\x00")
        h.update(np.ascontiguousarray(toks, dtype=np.int32).tobytes())
        parts.append(h.digest())
    return hashlib.sha256(b"".join(sorted(parts))).hexdigest()[:16]
