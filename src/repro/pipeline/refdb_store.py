"""Versioned, crash-safe on-disk persistence for the HD reference database.

Replaces the pickle cache that ``ProfilingSession.build_or_load_refdb``
used through PR 3.  Pickle had three production problems: a truncated
write (crash, full disk, or a concurrent builder) poisoned every later
load with an opaque ``UnpicklingError``; the format carried no version or
provenance, so nothing could detect that the bytes on disk no longer
matched the code or config that wrote them; and loading executed
arbitrary bytecode from a cache directory.

The store writes one ``refdb_<key>.npz`` file per cache entry: a plain
numpy archive holding the three RefDB arrays plus a JSON *manifest*
embedded under the ``manifest`` key.  Manifest fields:

    format_version   integer; bumped on any layout change.  A mismatch
                     (or absence) makes ``load`` return None — callers
                     rebuild instead of misinterpreting bytes.
    refdb_fingerprint / genomes_digest
                     the two halves of the cache key, recorded for
                     provenance (``manifest(path)`` exposes them).
    space / window / stride
                     the content-determining config, human-readable
                     (passed through ``config_fields`` by the session).
    num_species, num_prototypes, species_names, genome_lengths
                     RefDB metadata (the static pytree fields).
    dim_words        packed width W of the prototype rows.
    version / parent_version / delta
                     live-update provenance (only on snapshots written by
                     the serving registry): the version number, the
                     version it was derived from, and the add/remove
                     delta that produced it.

Writes are atomic: the archive is serialized to a same-directory
``*.tmp-<pid>-…`` file and published with ``os.replace``, so readers see
either the previous entry or the complete new one, never a torn file.
Loads are *tolerant by contract*: any undecodable entry — a legacy
pickle from before this format, a truncated npz, a manifest version from
the future — logs nothing, raises nothing, and returns None, which makes
every corruption mode equivalent to a cache miss (auto-rebuild).

``build_streaming`` builds and persists genome-by-genome through
:class:`repro.core.assoc_memory.RefDBBuilder`, so the raw windows of at
most one reference genome are ever resident alongside the growing
prototype rows.
"""

from __future__ import annotations

import json
import os
import pathlib
import tempfile
from typing import Callable, Iterable

import jax.numpy as jnp
import numpy as np

from repro.core.assoc_memory import RefDB, RefDBBuilder

#: Bump on any change to the array layout or manifest schema.  Readers
#: accept exactly this version; everything else is a miss.
FORMAT_VERSION = 1

_MAGIC = "demeter-refdb"


def save(path: str | pathlib.Path, db: RefDB, *,
         refdb_fingerprint: str = "", genomes_digest: str = "",
         config_fields: dict | None = None,
         version: int | None = None, parent_version: int | None = None,
         delta: dict | None = None) -> pathlib.Path:
    """Atomically write ``db`` (npz arrays + embedded JSON manifest).

    The archive is staged in a sibling temp file and published with
    ``os.replace`` — a crash mid-write leaves at worst a ``*.tmp-*``
    stray, never a torn entry; concurrent builders race benignly (last
    complete write wins, both are valid).

    Args:
      config_fields: JSON-primitive provenance merged into the manifest
        (the session records the content-determining config: ``space``,
        ``window``, ``stride``).  Core schema keys win on collision.
      version / parent_version / delta: live-update provenance, recorded
        by the serving registry (:mod:`repro.serve.registry`): the
        snapshot's version number, the version it was derived from, and
        the delta that produced it (``{"added": [...], "removed":
        [...]}``).  Omitted from the manifest when None, so plain
        session-cache entries are byte-stable across this change.
    """
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    provenance = {
        k: v for k, v in (("version", version),
                          ("parent_version", parent_version),
                          ("delta", delta))
        if v is not None
    }
    manifest = {
        **(config_fields or {}),
        **provenance,
        "magic": _MAGIC,
        "format_version": FORMAT_VERSION,
        "refdb_fingerprint": refdb_fingerprint,
        "genomes_digest": genomes_digest,
        "num_species": int(db.num_species),
        "num_prototypes": int(db.prototypes.shape[0]),
        "dim_words": int(db.prototypes.shape[1]),
        "species_names": list(db.species_names),
        "genome_lengths": [int(x) for x in np.asarray(db.genome_lengths)],
    }
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".tmp-")
    try:
        # Stream the archive straight into the staging file: no second
        # in-memory copy of a database that may be most of host RAM.
        with os.fdopen(fd, "wb") as f:
            np.savez(
                f,
                manifest=np.frombuffer(
                    json.dumps(manifest, sort_keys=True).encode(),
                    dtype=np.uint8),
                prototypes=np.asarray(db.prototypes),
                proto_species=np.asarray(db.proto_species),
                genome_lengths=np.asarray(db.genome_lengths),
            )
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)           # atomic publish
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def _manifest_from(z) -> dict | None:
    """Decode + magic-check the manifest member of an open archive."""
    try:
        m = json.loads(bytes(z["manifest"]).decode())
    except Exception:
        return None
    if not isinstance(m, dict) or m.get("magic") != _MAGIC:
        return None
    return m


def manifest(path: str | pathlib.Path) -> dict | None:
    """The entry's JSON manifest, or None if unreadable/not this format."""
    try:
        with np.load(path) as z:
            return _manifest_from(z)
    except Exception:
        return None


def load(path: str | pathlib.Path) -> RefDB | None:
    """Load a store entry; None on *any* defect (the auto-rebuild contract).

    A missing file, a legacy pickle from before this format, a truncated
    archive, a wrong ``format_version``, or arrays inconsistent with their
    manifest all return None — callers treat every one as a cache miss
    and rebuild, so a bad entry can never poison later runs.
    """
    path = pathlib.Path(path)
    if not path.exists():
        return None
    try:
        # One archive open for manifest + arrays: a warm-cache session
        # startup shouldn't parse the zip directory twice.
        with np.load(path) as z:
            m = _manifest_from(z)
            if m is None or m.get("format_version") != FORMAT_VERSION:
                return None
            protos = z["prototypes"]
            proto_species = z["proto_species"]
            genome_lengths = z["genome_lengths"]
    except Exception:
        return None
    names = tuple(m.get("species_names", ()))
    if (protos.shape[0] != m.get("num_prototypes")
            or protos.shape[1] != m.get("dim_words")
            or proto_species.shape != (protos.shape[0],)
            or genome_lengths.shape != (len(names),)
            or len(names) != m.get("num_species")):
        return None
    return RefDB(
        prototypes=jnp.asarray(protos),
        proto_species=jnp.asarray(proto_species),
        genome_lengths=jnp.asarray(genome_lengths),
        num_species=len(names),
        species_names=names,
    )


def build_streaming(genomes: dict[str, np.ndarray] |
                    Iterable[tuple[str, np.ndarray]],
                    builder: RefDBBuilder, *,
                    path: str | pathlib.Path | None = None,
                    refdb_fingerprint: str = "", genomes_digest: str = "",
                    config_fields: dict | None = None,
                    on_genome: Callable[[str, int], None] | None = None
                    ) -> RefDB:
    """Build a RefDB genome-by-genome and (optionally) persist it.

    Feeds each ``(name, tokens)`` through ``builder.add_genome`` — raw
    windows for only one genome are live at a time — then assembles the
    RefDB and, when ``path`` is given, publishes it atomically.

    Args:
      on_genome: progress hook ``(name, n_prototypes_so_far)`` per genome.
    """
    items = genomes.items() if isinstance(genomes, dict) else genomes
    total = 0
    for name, toks in items:
        block = builder.add_genome(name, toks)
        total += len(block)
        if on_genome is not None:
            on_genome(name, total)
    db = builder.finish()
    if path is not None:
        save(path, db, refdb_fingerprint=refdb_fingerprint,
             genomes_digest=genomes_digest, config_fields=config_fields)
    return db
