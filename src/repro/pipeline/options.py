"""Declared backend-options schemas: one validation path for every backend.

Every registered backend declares its options — name, kind, default, help,
optional choices and a value check — next to its registry entry
(:func:`repro.pipeline.backend.register_backend` takes the schema).  The
declaration is the single source of truth for three things that used to be
scattered and inconsistent (``pallas_fused`` validated by hand while
``pcm_sim`` built its option list from dataclass fields and the digital
backends silently ignored everything):

* **validation** — unknown names and ill-typed values fail with one
  uniform, friendly :class:`ValueError` on every backend, at session
  construction (never a shape crash or a silent ignore mid-profile);
* **CLI parsing** — ``profile_run --backend-option KEY=VALUE`` coerces the
  raw string through the declared kind (int/float/bool/str), so a typo'd
  key or a non-numeric value is a CLI error naming the option;
* **discovery** — ``profile_run --list-backends`` prints each backend's
  options with kinds and defaults straight from the declarations.

A schema with ``passthrough=True`` (the ``sharded`` wrapper) validates its
own options and forwards the rest to the wrapped backend's schema, so a
misspelled ``pcm_sim`` knob fails identically whether it rides directly or
through ``sharded``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

#: kind -> (accepted python types, human label).  ``bool`` is checked
#: before ``int``/``number`` everywhere because bool subclasses int.
_KINDS: dict[str, tuple[tuple[type, ...], str]] = {
    "int": ((int,), "an integer"),
    "number": ((int, float), "a number"),
    "bool": ((bool,), "a bool"),
    "str": ((str,), "a string"),
}


@dataclasses.dataclass(frozen=True)
class Option:
    """One declared backend option.

    Attributes:
      name: the ``backend_options`` key.
      kind: value kind — ``"int"`` / ``"number"`` / ``"bool"`` / ``"str"``.
        Drives both the type check and the CLI string coercion.
      default: the value used when the option is absent (display only —
        the consuming config owns the real default; keep them in sync).
      help: one-line description for ``--list-backends``.
      choices: optional closed set of allowed values.
      check: optional ``value -> error text | None`` refinement (range,
        divisibility, ...) run after the kind/choices checks pass.
    """

    name: str
    kind: str
    default: object = None
    help: str = ""
    choices: tuple | None = None
    check: Callable[[object], str | None] | None = None

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"option {self.name!r}: unknown kind "
                             f"{self.kind!r}; one of {sorted(_KINDS)}")

    def describe(self) -> str:
        """``name  kind=default  help`` row for ``--list-backends``."""
        spec = self.kind
        if self.choices is not None:
            spec = "|".join(str(c) for c in self.choices)
        return f"{self.name}={spec} (default {self.default!r})" + (
            f"  {self.help}" if self.help else "")


class OptionError(ValueError):
    """An unknown or ill-typed backend option (uniform across backends)."""


@dataclasses.dataclass(frozen=True)
class OptionsSchema:
    """The declared option set of one registered backend.

    ``validate`` applies the one uniform error contract:

    * unknown name  -> ``<backend> got unknown option 'x'; valid options:
      a, b, c`` (or ``takes no options`` for option-less backends);
    * wrong type    -> ``<backend> option 'x' must be an integer, got ...``;
    * bad choice    -> ``<backend> option 'x' must be one of ...``;
    * failed check  -> ``<backend> option 'x' <check's message>``.
    """

    backend: str
    options: tuple[Option, ...] = ()
    #: unknown options are forwarded to a wrapped backend's schema instead
    #: of failing here (the ``sharded`` wrapper).
    passthrough: bool = False

    def __post_init__(self) -> None:
        names = [o.name for o in self.options]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate option names in schema for "
                             f"{self.backend!r}: {names}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(o.name for o in self.options)

    def option(self, name: str) -> Option | None:
        for o in self.options:
            if o.name == name:
                return o
        return None

    def unknown_error(self, name: str) -> OptionError:
        if not self.options:
            return OptionError(
                f"{self.backend} got unknown option {name!r}; "
                f"{self.backend} takes no options")
        return OptionError(
            f"{self.backend} got unknown option {name!r}; valid options: "
            f"{', '.join(sorted(self.names))}")

    def check_value(self, opt: Option, value: object) -> None:
        """Kind + choices + refinement check for one provided value."""
        types, label = _KINDS[opt.kind]
        if isinstance(value, bool) and opt.kind != "bool":
            raise OptionError(f"{self.backend} option {opt.name!r} must be "
                              f"{label}, got {value!r}")
        if not isinstance(value, types):
            raise OptionError(f"{self.backend} option {opt.name!r} must be "
                              f"{label}, got {value!r}")
        if opt.choices is not None and value not in opt.choices:
            raise OptionError(
                f"{self.backend} option {opt.name!r} must be one of "
                f"{list(opt.choices)}, got {value!r}")
        if opt.check is not None:
            msg = opt.check(value)
            if msg:
                raise OptionError(
                    f"{self.backend} option {opt.name!r} {msg}, "
                    f"got {value!r}")

    def validate(self, options: Mapping[str, object]
                 ) -> tuple[dict[str, object], dict[str, object]]:
        """Split provided options into ``(own, rest)`` after checking.

        ``own`` holds the validated options this schema declares; ``rest``
        holds the remainder — empty unless ``passthrough`` (a non-empty
        remainder without passthrough raises the uniform unknown error).
        """
        own: dict[str, object] = {}
        rest: dict[str, object] = {}
        for name, value in dict(options).items():
            opt = self.option(name)
            if opt is None:
                if self.passthrough:
                    rest[name] = value
                    continue
                raise self.unknown_error(name)
            self.check_value(opt, value)
            own[name] = value
        return own, rest

    def parse_cli(self, name: str, raw: str) -> object:
        """Coerce a ``--backend-option`` raw string by the declared kind."""
        opt = self.option(name)
        if opt is None:
            raise self.unknown_error(name)
        value = coerce(raw, opt.kind)
        if value is None:
            _, label = _KINDS[opt.kind]
            raise OptionError(f"{self.backend} option {name!r} must be "
                              f"{label}, got {raw!r}")
        self.check_value(opt, value)
        return value

    def describe(self) -> list[str]:
        """One row per option (empty for option-less backends)."""
        return [o.describe() for o in self.options]


def coerce(raw: str, kind: str) -> object | None:
    """Parse a CLI string as ``kind``; None when it doesn't parse."""
    if kind == "str":
        return raw
    if kind == "bool":
        low = raw.lower()
        if low in ("true", "1", "yes"):
            return True
        if low in ("false", "0", "no"):
            return False
        return None
    try:
        as_int = int(raw)
    except ValueError:
        as_int = None
    if kind == "int":
        return as_int
    # number: prefer the int reading (keeps e.g. seed=3 an int),
    # fall back to float
    if as_int is not None:
        return as_int
    try:
        return float(raw)
    except ValueError:
        return None


# -- common refinement checks (shared across backend declarations) ---------

def positive(v) -> str | None:
    return None if v > 0 else "must be > 0"


def non_negative(v) -> str | None:
    return None if v >= 0 else "must be >= 0"


def unit_interval(v) -> str | None:
    return None if 0.0 <= v <= 1.0 else "must be in [0, 1]"
