"""``pallas_fused``: the fused encode->search backend.

One :func:`repro.kernels.fused_profile.fused_profile` megakernel runs
Demeter steps 3 and 4 together — each read's k-mer stream is encoded
tile-by-tile in VMEM and every finished dim-tile folds straight into the
agreement accumulator against the prototypes' matching tile, so the
``(batch, dim)`` encoded query matrix never round-trips through HBM
(Acc-Demeter's in-memory dataflow, paper §5; same insight as Karunaratne
et al., *In-memory hyperdimensional computing*).

The backend exposes the fusion as the ``tokens_agreement`` capability;
:meth:`~repro.pipeline.session.ProfilingSession.classify_batch` dispatches
to it when present, so both ``profile()`` and the serving layer
(:class:`~repro.serve.profiler_service.ProfilingService`) run the fused
path with no changes of their own.  The Backend-protocol primitives
``encode`` / ``agreement`` remain (the standalone Pallas kernels): the
RefDB build still needs a bare encoder, and a ``sharded`` wrapper calls
``tokens_agreement`` per shard when fusing and ``agreement`` otherwise.

Options (``ProfilerConfig.backend_options``, all validated here so a bad
tile size is a :class:`ValueError` at session construction — never a
Pallas shape crash mid-profile):

    bb  batch-tile rows, power of two (default 8).
    bw  word-tile lanes, positive (default 128; clamped to W).
    bs  prototype rows per kernel call (default 4096) — bounds the
        VMEM-resident prototype tile and agreement accumulator.
"""

from __future__ import annotations

import jax

from repro.pipeline.backend import _BackendBase, register_backend
from repro.pipeline.config import ProfilerConfig

#: option name -> (default, validator description)
_TILE_OPTIONS = ("bb", "bw", "bs")
_DEFAULTS = {"bb": 8, "bw": 128, "bs": 4096}


def _validated_tiles(config: ProfilerConfig) -> dict[str, int]:
    """Read bb/bw/bs from ``backend_options``, failing with friendly errors."""
    tiles = dict(_DEFAULTS)
    for name, value in config.backend_options:
        if name not in _TILE_OPTIONS:
            raise ValueError(
                f"pallas_fused got unknown option {name!r}; it takes only "
                f"tile sizes {_TILE_OPTIONS} (ints)")
        if isinstance(value, bool) or not isinstance(value, int) or value < 1:
            raise ValueError(
                f"pallas_fused option {name!r} must be a positive int, "
                f"got {value!r}")
        tiles[name] = value
    if tiles["bb"] & (tiles["bb"] - 1):
        raise ValueError(
            f"pallas_fused option 'bb' must be a power of two so every "
            f"padded batch tiles evenly, got {tiles['bb']}")
    return tiles


@register_backend("pallas_fused")
class PallasFusedBackend(_BackendBase):
    """Fused encode->search megakernel (interpret mode on CPU)."""

    name = "pallas_fused"

    def __init__(self, config: ProfilerConfig):
        super().__init__(config)
        self.tiles = _validated_tiles(config)

    # -- Backend protocol (standalone kernels; RefDB build + sharded) ------
    def encode(self, tokens: jax.Array, lengths: jax.Array) -> jax.Array:
        from repro.kernels import ops
        return ops.hdc_encode(tokens, lengths, self.im, self.tie, self.space)

    def agreement(self, queries: jax.Array, prototypes: jax.Array
                  ) -> jax.Array:
        from repro.kernels import ops
        return ops.am_agreement(queries, prototypes, self.space.dim,
                                "matmul")

    # -- fused capability (ProfilingSession.classify_batch dispatch) -------
    def tokens_agreement(self, tokens: jax.Array, lengths: jax.Array,
                         prototypes: jax.Array) -> jax.Array:
        """Steps 3+4 fused: ``(B, L)`` tokens -> ``(B, S)`` agreement.

        The encoded queries exist only as VMEM tiles inside the kernel.
        """
        from repro.kernels import ops
        t = self.tiles
        return ops.fused_agreement(
            tokens, lengths, self.im, self.tie, prototypes, self.space,
            bb=t["bb"], bw=min(t["bw"], self.space.num_words), bs=t["bs"])
