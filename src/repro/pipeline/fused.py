"""``pallas_fused``: the fused encode->search backend.

One :func:`repro.kernels.fused_profile.fused_profile` megakernel runs
Demeter steps 3 and 4 together — each read's k-mer stream is encoded
tile-by-tile in VMEM and every finished dim-tile folds straight into the
agreement accumulator against the prototypes' matching tile, so the
``(batch, dim)`` encoded query matrix never round-trips through HBM
(Acc-Demeter's in-memory dataflow, paper §5; same insight as Karunaratne
et al., *In-memory hyperdimensional computing*).

The backend exposes the fusion as the ``tokens_agreement`` capability;
:meth:`~repro.pipeline.session.ProfilingSession.classify_batch` dispatches
to it when present, so both ``profile()`` and the serving layer
(:class:`~repro.serve.profiler_service.ProfilingService`) run the fused
path with no changes of their own.  The Backend-protocol primitives
``encode`` / ``agreement`` remain (the standalone Pallas kernels): the
RefDB build still needs a bare encoder, and a ``sharded`` wrapper calls
``tokens_agreement`` per shard when fusing and ``agreement`` otherwise.

Options (``ProfilerConfig.backend_options``, all validated here so a bad
tile size is a :class:`ValueError` at session construction — never a
Pallas shape crash mid-profile):

    bb  batch-tile rows, power of two (default 8), at most the padded
        configured batch.
    bw  word-tile lanes, positive (default 128; clamped to W).
    bs  prototype rows per kernel chunk, multiple of 128 (default 4096)
        — bounds the VMEM-resident prototype slab and accumulator.
    autotune        bool: resolve bb/bw/bs from the on-disk tile cache
        (:mod:`repro.kernels.autotune`) at the first profiled batch,
        measuring once per (platform, device kind, B, W, S, dim) key.
        Explicit tile options win over autotune (warned once).
    autotune_cache  str: cache file override (else the
        ``REPRO_AUTOTUNE_CACHE`` env var / ``~/.cache/repro/``).
"""

from __future__ import annotations

import warnings

import jax

from repro.pipeline.backend import _BackendBase, register_backend
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.options import Option, OptionsSchema

_TILE_OPTIONS = ("bb", "bw", "bs")
_DEFAULTS = {"bb": 8, "bw": 128, "bs": 4096}

#: warn only once per process when explicit tiles silence autotune
_warned_autotune_override = False


def _pow2_tile(v) -> str | None:
    if v < 1:
        return "must be a positive int"
    if v & (v - 1):
        return "must be a power of two so every padded batch tiles evenly"
    return None


def _positive_tile(v) -> str | None:
    return None if v >= 1 else "must be a positive int"


def _proto_tile(v) -> str | None:
    if v < 1:
        return "must be a positive int"
    if v % 128:
        return "must be a multiple of 128 (the prototype-axis output tile)"
    return None


def _nonempty_path(v) -> str | None:
    return None if v else "must be a non-empty path"


#: Declared next to the registry entry: the single source of truth for
#: ``--list-backends``, CLI coercion, and construction-time validation.
FUSED_OPTIONS = OptionsSchema(backend="pallas_fused", options=(
    Option("bb", "int", default=_DEFAULTS["bb"], check=_pow2_tile,
           help="batch tile (reads per kernel step; power of two)"),
    Option("bw", "int", default=_DEFAULTS["bw"], check=_positive_tile,
           help="window tile (tokens per inner step)"),
    Option("bs", "int", default=_DEFAULTS["bs"], check=_proto_tile,
           help="prototype tile (output columns; multiple of 128)"),
    Option("autotune", "bool", default=False,
           help="measure candidate tilings once per (S, L) shape"),
    Option("autotune_cache", "str", default=None, check=_nonempty_path,
           help="JSON file persisting autotuner picks across processes"),
))


def _validated_options(config: ProfilerConfig
                       ) -> tuple[dict[str, int], set[str], bool,
                                  str | None]:
    """Consume schema-validated options + apply config-dependent checks.

    The per-value checks (types, power-of-two, 128-multiple) already ran
    in :class:`_BackendBase` via :data:`FUSED_OPTIONS`; only the check
    that needs the rest of the config — ``bb`` against the padded batch —
    lives here.  Returns ``(tiles, explicit, autotune, cache_path)`` where
    ``explicit`` names the tile options the user pinned.
    """
    opts = config.options
    tiles = {name: opts.get(name, _DEFAULTS[name]) for name in _TILE_OPTIONS}
    explicit = {name for name in _TILE_OPTIONS if name in opts}
    autotune = bool(opts.get("autotune", False))
    cache_path = opts.get("autotune_cache")
    padded_batch = 8 * ((config.batch_size + 7) // 8)
    if "bb" in explicit and tiles["bb"] > padded_batch:
        raise ValueError(
            f"pallas_fused option 'bb'={tiles['bb']} exceeds the padded "
            f"batch ({config.batch_size} reads pad to {padded_batch}); "
            f"lower bb or raise batch_size")
    return tiles, explicit, autotune, cache_path


@register_backend("pallas_fused", schema=FUSED_OPTIONS)
class PallasFusedBackend(_BackendBase):
    """Fused encode->search megakernel (interpret mode on CPU)."""

    name = "pallas_fused"

    def __init__(self, config: ProfilerConfig):
        super().__init__(config)
        (self.tiles, self._explicit, self._autotune,
         self._autotune_cache) = _validated_options(config)
        if self._autotune and self._explicit:
            global _warned_autotune_override
            if not _warned_autotune_override:
                _warned_autotune_override = True
                warnings.warn(
                    "pallas_fused: explicit tile options "
                    f"{sorted(self._explicit)} override autotune=true; "
                    "the autotuner will not run for this backend",
                    stacklevel=2)
            self._autotune = False
        #: (S, L) shape the cached tuning was resolved for
        self._tuned_for: tuple[int, int] | None = None

    # -- Backend protocol (standalone kernels; RefDB build + sharded) ------
    def encode(self, tokens: jax.Array, lengths: jax.Array) -> jax.Array:
        from repro.kernels import ops
        return ops.hdc_encode(tokens, lengths, self.im, self.tie, self.space)

    def agreement(self, queries: jax.Array, prototypes: jax.Array
                  ) -> jax.Array:
        from repro.kernels import ops
        return ops.am_agreement(queries, prototypes, self.space.dim,
                                "matmul")

    def _resolve_tiles(self, num_prototypes: int, read_len: int
                       ) -> dict[str, int]:
        """Tiles for this batch; runs/reads the autotuner cache lazily.

        The tuner keys on the configured batch plus the live (S, L), so
        the first profiled batch pays the sweep (or a cache read) and
        every later batch — and every other process on the same device
        kind — reuses the same deterministic choice.
        """
        if not self._autotune:
            return self.tiles
        if self._tuned_for != (num_prototypes, read_len):
            from repro.kernels import autotune
            tiles, _ = autotune.tune(
                self.space, batch=self.config.batch_size,
                num_prototypes=num_prototypes, read_len=read_len,
                path=self._autotune_cache)
            self.tiles = {**self.tiles, **tiles}
            self._tuned_for = (num_prototypes, read_len)
        return self.tiles

    # -- fused capability (ProfilingSession.classify_batch dispatch) -------
    def tokens_agreement(self, tokens: jax.Array, lengths: jax.Array,
                         prototypes: jax.Array) -> jax.Array:
        """Steps 3+4 fused: ``(B, L)`` tokens -> ``(B, S)`` agreement.

        The encoded queries exist only as VMEM tiles inside the kernel.
        """
        from repro.kernels import ops
        t = self._resolve_tiles(prototypes.shape[0], tokens.shape[1])
        return ops.fused_agreement(
            tokens, lengths, self.im, self.tie, prototypes, self.space,
            bb=t["bb"], bw=min(t["bw"], self.space.num_words), bs=t["bs"])
