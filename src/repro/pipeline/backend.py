"""Execution backends: named, registered implementations of the hot path.

Demeter's platform-independence claim (paper §3) is that the same
five-step algorithm runs on any substrate — software, Acc-Demeter's PCM
crossbar, a TPU.  A :class:`Backend` is the seam where the substrate
plugs in: it owns exactly the two bit-exact primitives that differ per
platform,

  ``encode(tokens, lengths) -> (B, W)``   packed query HD vectors (step 3)
  ``agreement(queries, prototypes) -> (B, S)``  matching-bit counts (step 4)

while everything around them (windowing, thresholding, species reduction,
abundance) is substrate-independent and lives in ``repro.core`` /
:mod:`repro.pipeline.session`.

Backends are discovered by name through a registry::

    session = ProfilingSession(ProfilerConfig(backend="pallas_matmul"))
    available_backends()   # ("pallas_matmul", "pallas_packed", ...)

Registered backends:

  reference        pure-jnp encoder + ±1 matmul agreement (BLAS on CPU).
  reference_packed pure-jnp encoder + packed XOR+popcount agreement.
  pallas_matmul    Pallas encoder kernel + MXU ±1 matmul kernel.
  pallas_packed    Pallas encoder kernel + VPU popcount kernel.
  pallas_fused     fused encode->search megakernel: the encoded queries
                   never leave VMEM (:mod:`repro.pipeline.fused`).
  pcm_sim          digital encoder + simulated in-memory AM search on the
                   PCM substrate (:mod:`repro.accel`; bit-exact at zero
                   device noise, configurably non-ideal — multi-bit
                   levels, noise, drift, faults — via ``backend_options``).
  racetrack_sim    the same simulated AM search on the racetrack-memory
                   substrate (shift-based access faults + domain-wall
                   read model; Khan et al., see PAPERS.md).
  sharded          prototype-axis model parallelism over a device mesh,
                   wrapping any of the above as its ``base``
                   (:mod:`repro.pipeline.sharded`, built on
                   ``repro.distributed.sharding``).

All are bit-exact twins at default options (enforced by
``tests/test_pipeline.py`` and, across mesh sizes, by
``tests/test_sharded.py``).  Backends may additionally expose two
optional capabilities the session discovers by name:

  ``place_refdb(db) -> RefDB``   device placement after build/load
                                 (pad + distribute across a mesh);
  ``species_scores(queries, prototypes, proto_species, num_species)``
                                 fused agreement + per-species reduction,
                                 merged across shards (skips the
                                 per-prototype agreement round-trip);
  ``tokens_agreement(tokens, lengths, prototypes)``
                                 steps 3+4 fused — raw read tokens to
                                 agreement with no encoded HBM matrix
                                 (``pallas_fused``, and ``sharded``
                                 wrapping a base that has it);
  ``tokens_species_scores(tokens, lengths, prototypes, proto_species,
                          num_species)``
                                 the fully fused form of the above
                                 (``sharded`` over a fused base: encode +
                                 search + species reduction per shard,
                                 one pmax of (B, species) cross-device).
"""

from __future__ import annotations

import functools
from typing import Callable, Protocol, runtime_checkable

import jax

from repro.core import assoc_memory, encoder, item_memory
from repro.core.hd_space import HDSpace
from repro.pipeline.config import ProfilerConfig
from repro.pipeline.options import OptionsSchema


@runtime_checkable
class Backend(Protocol):
    """The two substrate-dependent primitives of the pipeline."""

    name: str
    space: HDSpace

    def encode(self, tokens: jax.Array, lengths: jax.Array) -> jax.Array:
        """Read conversion (step 3): ``(B, L)`` tokens -> ``(B, W)`` packed."""
        ...

    def agreement(self, queries: jax.Array, prototypes: jax.Array
                  ) -> jax.Array:
        """AM search (step 4): ``(B, W) x (S, W)`` -> ``(B, S)`` int32
        matching-bit counts in ``[0, dim]``."""
        ...


BackendFactory = Callable[[ProfilerConfig], Backend]

_REGISTRY: dict[str, BackendFactory] = {}
_SCHEMAS: dict[str, OptionsSchema] = {}

#: Backends that register themselves when their module is imported.  The
#: registry resolves these lazily, so ``available_backends()`` and the
#: unknown-backend error are complete even when only this module (not the
#: ``repro.pipeline`` package, which imports them eagerly) has been
#: imported — e.g. ``profile_run --list-backends`` sees every backend no
#: matter which import path reached the registry first.  Third-party
#: backends registered after import via :func:`register_backend` appear
#: the moment they register (nothing is cached).
_LAZY_MODULES: dict[str, str] = {
    "pallas_fused": "repro.pipeline.fused",
    "pcm_sim": "repro.accel.backend_pcm",
    "racetrack_sim": "repro.accel.backend_pcm",
    "sharded": "repro.pipeline.sharded",
}


def register_backend(name: str, schema: OptionsSchema | None = None
                     ) -> Callable[[BackendFactory], BackendFactory]:
    """Decorator: register a ``ProfilerConfig -> Backend`` factory by name.

    ``schema`` declares the backend's options (displayed by
    ``--list-backends``, enforced uniformly at construction, and used to
    type ``--backend-option`` CLI values).  ``None`` declares an
    option-less backend: *any* provided option fails with the uniform
    unknown-option error instead of being silently ignored.
    """
    def deco(factory: BackendFactory) -> BackendFactory:
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = factory
        _SCHEMAS[name] = (schema if schema is not None
                          else OptionsSchema(backend=name))
        return factory
    return deco


def available_backends() -> tuple[str, ...]:
    """Names of every registered backend (lazy entry points included)."""
    return tuple(sorted(set(_REGISTRY) | set(_LAZY_MODULES)))


def _materialize(name: str) -> None:
    """Import a lazy backend module so its registration runs."""
    if name not in _REGISTRY and name in _LAZY_MODULES:
        import importlib
        importlib.import_module(_LAZY_MODULES[name])  # registers on import


def options_schema(name: str) -> OptionsSchema:
    """The declared options schema of the backend registered as ``name``."""
    _materialize(name)
    try:
        return _SCHEMAS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {available_backends()}"
        ) from None


def resolve_backend(name: str, config: ProfilerConfig) -> Backend:
    """Instantiate the backend registered under ``name`` for ``config``."""
    _materialize(name)
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {available_backends()}"
        ) from None
    return factory(config)


class _BackendBase:
    """Shared state: the per-space item memory and tie-break vector.

    Construction validates ``config.backend_options`` against the options
    schema declared at registration, so every backend — including the
    option-less digital ones, which used to silently ignore typos — fails
    with the same friendly error on an unknown or ill-typed option.
    """

    name = "abstract"

    def __init__(self, config: ProfilerConfig):
        schema = _SCHEMAS.get(config.backend)
        if schema is not None:
            schema.validate(config.options)
        self.config = config
        self.space = config.space
        self.im = item_memory.make_item_memory(self.space)
        self.tie = item_memory.make_tie_break(self.space)


@register_backend("reference")
class ReferenceBackend(_BackendBase):
    """Pure-jnp software path: rolling-gram encoder + ±1 matmul agreement.

    The numerical oracle every other backend must match bit-exactly.  On
    CPU the agreement matmul maps to BLAS; on TPU XLA lowers it to the MXU.
    """

    name = "reference"

    def __init__(self, config: ProfilerConfig):
        super().__init__(config)
        self._encode = jax.jit(
            lambda t, l: encoder.encode(t, l, self.im, self.tie, self.space))
        self._agreement = jax.jit(functools.partial(
            assoc_memory.agreement_matmul, dim=self.space.dim))

    def encode(self, tokens: jax.Array, lengths: jax.Array) -> jax.Array:
        return self._encode(tokens, lengths)

    def agreement(self, queries: jax.Array, prototypes: jax.Array
                  ) -> jax.Array:
        return self._agreement(queries, prototypes)


@register_backend("reference_packed")
class ReferencePackedBackend(ReferenceBackend):
    """Software path with the bandwidth-optimal XOR+popcount agreement."""

    name = "reference_packed"

    def __init__(self, config: ProfilerConfig):
        super().__init__(config)
        self._agreement = jax.jit(functools.partial(
            assoc_memory.agreement_packed_chunked, dim=self.space.dim))


class _PallasBackendBase(_BackendBase):
    """Pallas kernel path (interpret mode on CPU, real kernels on TPU)."""

    formulation = "matmul"

    def encode(self, tokens: jax.Array, lengths: jax.Array) -> jax.Array:
        from repro.kernels import ops
        return ops.hdc_encode(tokens, lengths, self.im, self.tie, self.space)

    def agreement(self, queries: jax.Array, prototypes: jax.Array
                  ) -> jax.Array:
        from repro.kernels import ops
        return ops.am_agreement(queries, prototypes, self.space.dim,
                                self.formulation)


@register_backend("pallas_matmul")
class PallasMatmulBackend(_PallasBackendBase):
    """Pallas encoder kernel + MXU ±1 matmul AM-search kernel."""

    name = "pallas_matmul"
    formulation = "matmul"


@register_backend("pallas_packed")
class PallasPackedBackend(_PallasBackendBase):
    """Pallas encoder kernel + VPU packed-popcount AM-search kernel."""

    name = "pallas_packed"
    formulation = "packed"
