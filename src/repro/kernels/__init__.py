"""Pallas TPU kernels for Demeter's compute hot-spots.

* am_matmul     — AM similarity as +-1 MXU matmul (the PCM crossbar VMM).
* hamming_am    — AM similarity as packed XOR+popcount (VPU, bandwidth-optimal).
* hdc_encoder   — N-gram bind + bundle + majority, one grid cell per
                  (read-block, word-block).

``ops`` holds the jit'd public wrappers, ``ref`` the pure-jnp oracles.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
