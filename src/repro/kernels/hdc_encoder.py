"""Pallas TPU kernel for the Demeter N-gram encoder (bind + bundle).

TPU port of Acc-Demeter's encoder unit (paper §5.3).  Design notes:

* **No gathers.** TPU Pallas has no efficient dynamic gather; the genome
  alphabet has only 4 symbols, so the IM row lookup ``B[c]`` becomes 4
  predicated selects — the VPU equivalent of the paper's one-cycle
  row-major IM read.
* **No runtime permutation.** The rolled item memories ``rho^j(IM)`` for
  j < N are precomputed host-side (N*4*W words, KBs) so every word-block
  of the HD space is fully independent -> embarrassingly parallel grid
  over (batch, word-block), zero cross-block traffic.  This is the TPU
  realization of the "free shift" flip-flop chain.
* **Counters layout** ``(bb, 32, bw)``: the lane dimension stays the
  word-block (multiple of 128); the 32 bit positions of each word sit in
  sublanes.
* Bundling majority (with tie-break vector) and re-packing happen in the
  same kernel — one HBM write of W words per read, nothing else leaves.

Grid: (B/bb, W/bw); the whole gram loop for a read runs inside one grid
cell, mirroring the paper's streaming encoder.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import CompilerParams, VMEM, interpret_default

WORD_BITS = 32


def _unpack(words: jax.Array) -> jax.Array:
    """(bb, bw) uint32 -> (bb, 32, bw) int32 bits (bit b in sublane b)."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :, None]
    return ((words[:, None, :] >> shifts) & jnp.uint32(1)).astype(jnp.int32)


def _pack(bits: jax.Array) -> jax.Array:
    """(bb, 32, bw) {0,1} -> (bb, bw) uint32."""
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return (bits.astype(jnp.uint32) * weights[None, :, None]).sum(
        axis=1, dtype=jnp.uint32)


def _kernel(tokens_ref, len_ref, im_ref, tie_ref, o_ref, counts_ref,
            *, n: int, alphabet: int, g: int):
    toks = tokens_ref[...]                       # (bb, L) int32
    m = jnp.maximum(len_ref[...] - (n - 1), 0)   # (bb, 1) valid grams
    counts_ref[...] = jnp.zeros_like(counts_ref)
    bw = o_ref.shape[-1]
    bb = o_ref.shape[0]

    if g > 0:
        def body(i, _):
            window = jax.lax.dynamic_slice(toks, (0, i), (bb, n))  # (bb, n)
            gram = jnp.zeros((bb, bw), jnp.uint32)
            for j in range(n):                    # bind: XOR of rho^j(B[c])
                tok_j = window[:, j][:, None]     # (bb, 1)
                for a in range(alphabet):         # gather-free IM lookup
                    row = im_ref[j, a, :][None, :]
                    gram = jnp.bitwise_xor(
                        gram, jnp.where(tok_j == a, row, jnp.uint32(0)))
            valid = (i < m[:, 0])[:, None, None]  # (bb, 1, 1)
            counts_ref[...] += jnp.where(valid, _unpack(gram), 0)
            return 0

        jax.lax.fori_loop(0, g, body, 0)

    # Bundle: majority with tie-break (paper's thresholded counters).
    counts = counts_ref[...]                      # (bb, 32, bw)
    twice = 2 * counts
    m_b = m[:, 0][:, None, None]
    tie_bits = _unpack(tie_ref[...])[0:1]         # (1, 32, bw)
    bits = jnp.where(twice == m_b, tie_bits,
                     (twice > m_b).astype(jnp.int32))
    o_ref[...] = _pack(bits)


@functools.partial(jax.jit, static_argnames=("n", "alphabet", "bb", "bw",
                                             "interpret"))
def hdc_encode(tokens: jax.Array, lengths: jax.Array, im_rolled: jax.Array,
               tie: jax.Array, *, n: int, alphabet: int = 4, bb: int = 8,
               bw: int = 128, interpret: bool | None = None) -> jax.Array:
    """Encode a batch of symbol sequences into packed query HD vectors.

    Args:
      tokens: ``(B, L)`` int32 symbol ids in [0, alphabet).
      lengths: ``(B, 1)`` int32 true lengths.
      im_rolled: ``(N, alphabet, W)`` uint32 — ``item_memory.rolled``.
      tie: ``(1, W)`` uint32 tie-break vector.

    Returns:
      ``(B, W)`` uint32 packed HD vectors (majority-bundled n-grams).
    """
    b, length = tokens.shape
    n_im, a_im, w = im_rolled.shape
    assert n_im == n and a_im == alphabet
    g = max(length - n + 1, 0)
    bb, bw = min(bb, b), min(bw, w)
    assert b % bb == 0 and w % bw == 0, (
        f"(B={b}, W={w}) must tile by (bb={bb}, bw={bw}); pad upstream")
    grid = (b // bb, w // bw)

    return pl.pallas_call(
        functools.partial(_kernel, n=n, alphabet=alphabet, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, length), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((n, alphabet, bw), lambda i, j: (0, 0, j)),
            pl.BlockSpec((1, bw), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, w), jnp.uint32),
        scratch_shapes=[VMEM((bb, WORD_BITS, bw), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret_default(interpret),
    )(tokens, lengths, im_rolled, tie)
